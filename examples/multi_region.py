"""Two-region global dispatch demo: spatial carbon routing + temporal
deferral through the placement-plan IR.

A us-west region (efficient hardware, dirtier solar-dipped grid) and a
eu-north region (fast hardware, cleaner overnight-troughed grid) serve one
workload arriving in the evening — both grids off their troughs. The
``GlobalDispatcher`` routes interactive queries to the system with the
lowest carbon cost right now and wraps batch-tier queries in ``DeferPlan``s
targeting the earliest green window across all regions; the unchanged fleet
engines hold those admissions and keep idle-inclusive accounting, so the
printout shows what deferral actually buys at the fleet level.

Run: PYTHONPATH=src python examples/multi_region.py [--queries 200]
"""
import argparse

from repro.configs import get_config
from repro.core import (GlobalDispatcher, PoolSpec, Query, Region,
                        WorkloadSpec, sample_workload, simulate_fleet)
from repro.core.carbon import CarbonProfile
from repro.core.plan import plan_to_json
from repro.core.systems import get_profile


def build_regions():
    eff, perf = get_profile("tpu-v5lite-eff"), get_profile("tpu-v5e-perf")
    west = Region("us-west",
                  {"eff": PoolSpec(eff, instances=2, slots=4)},
                  carbon=CarbonProfile(mean_g_per_kwh=320.0,
                                       trough_hour=13.0))
    east = Region("eu-north",
                  {"perf": PoolSpec(perf, instances=2, slots=4)},
                  carbon=CarbonProfile(mean_g_per_kwh=120.0,
                                       trough_hour=2.0))
    return west, east


def grams_of(run, regions, rids=None):
    region_of = {f"{reg.name}/{p}": reg for reg in regions
                 for p in reg.pools}
    total = 0.0
    for rec in run.records:
        if rids is not None and rec.rid not in rids:
            continue
        total += region_of[rec.pool].carbon.grams(rec.energy_j, rec.t_start)
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    west, east = build_regions()

    # evening arrivals: interactive chat + a batch tier (n > 256)
    t0 = 18 * 3600.0
    chat = sample_workload(args.queries, seed=0,
                           spec=WorkloadSpec(mu_in=5.0, mu_out=3.5,
                                             rate_qps=2.0))
    chat = [Query(q.m, q.n, t0 + q.arrival_s) for q in chat]
    batch = [Query(256, 1024, t0 + 60.0 * i) for i in range(10)]
    qs = sorted(chat + batch, key=lambda q: q.arrival_s)
    # chat outputs clamp at n=512, so this threshold defers ONLY the batch
    # tier — interactive traffic keeps its arrival-time co-batching discount
    thr = 600

    # ---- 1. what the dispatcher decides -------------------------------------
    sched = GlobalDispatcher(cfg, [west, east], defer_out_threshold=thr)
    print("== plans at 18:00 (both regions off-trough) ==")
    for q in (Query(64, 16, t0), Query(256, 1024, t0)):
        plan = sched.dispatch(q, None)
        print(f"  (m={q.m}, n={q.n}) -> {plan_to_json(plan)}")

    # ---- 2. run it through the fleet engines, regions flattened -------------
    print("\n== two-region fleet run ==")
    run = simulate_fleet(cfg, qs, regions=[west, east],
                         scheduler=GlobalDispatcher(cfg, [west, east],
                                                    defer_out_threshold=thr))
    deferred = {r.rid for r in run.records
                if r.t_start > r.t_arrival + 3600.0}
    print(f"  {len(run.records)} requests, {len(deferred)} deferred "
          f">1h into a green window")
    print(f"  fleet energy (idle-inclusive): {run.fleet_energy_j:,.0f} J, "
          f"horizon {run.horizon_s - t0:,.0f} s")
    print(f"  carbon at execution time: {grams_of(run, [west, east]):,.3f} g")

    # ---- 3. same workload, no deferral (run-now global routing) -------------
    now = simulate_fleet(
        cfg, qs, regions=[west, east],
        scheduler=GlobalDispatcher(cfg, [west, east],
                                   defer_out_threshold=10**9))
    print("\n== same workload, deferral disabled ==")
    print(f"  fleet energy (idle-inclusive): {now.fleet_energy_j:,.0f} J")
    print(f"  carbon at execution time: {grams_of(now, [west, east]):,.3f} g")
    g_def = grams_of(run, [west, east], deferred)
    g_now = grams_of(now, [west, east], deferred)
    print(f"\nBatch tier alone: {g_def:.4f} g deferred vs {g_now:.4f} g "
          f"run-now ({100 * (1 - g_def / g_now):.1f}% lower inside the green "
          "window). Deferral trades horizon (and the idle floor burned while "
          "waiting) for grams at execution time.")


if __name__ == "__main__":
    main()
