"""Reproduce the paper's Figures 4-5 (threshold sweeps) and print the
ASCII-rendered energy curve with the single-hardware baselines.

Run: PYTHONPATH=src python examples/threshold_sweep.py [--axis in|out]
"""
import argparse

from repro.configs import get_config
from repro.core import (SingleSystemScheduler, Query, alpaca_like,
                        optimal_threshold, paper_fleet, simulate,
                        threshold_sweep)


def bar(value, lo, hi, width=50):
    n = int((value - lo) / (hi - lo + 1e-9) * width)
    return "#" * max(1, n)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--axis", default="in", choices=("in", "out"))
    ap.add_argument("--model", default="llama2-7b")
    ap.add_argument("--queries", type=int, default=10000)
    args = ap.parse_args()

    cfg = get_config(args.model)
    eff, perf = paper_fleet()
    qs = alpaca_like(args.queries, seed=0)
    pinned = [Query(q.m, 32) if args.axis == "in" else Query(32, q.n) for q in qs]
    e_eff = simulate(cfg, pinned, SingleSystemScheduler(cfg, eff)).total_energy_j
    e_perf = simulate(cfg, pinned, SingleSystemScheduler(cfg, perf)).total_energy_j
    sweep = threshold_sweep(cfg, qs, eff, perf, axis=args.axis)
    best = optimal_threshold(sweep)

    lo = min(p.energy_j for p in sweep) * 0.95
    hi = max(e_eff, e_perf, *(p.energy_j for p in sweep))
    print(f"total energy vs T_{args.axis} ({args.model}, {args.queries} "
          f"Alpaca-like queries, Eq. {'9' if args.axis == 'in' else '10'}):\n")
    print(f"  all-{eff.name:14s} {e_eff / 1e3:9.1f} kJ {bar(e_eff, lo, hi)}")
    print(f"  all-{perf.name:14s} {e_perf / 1e3:9.1f} kJ {bar(e_perf, lo, hi)}")
    print()
    for p in sweep:
        mark = "  <-- optimal (paper: 32)" if p.threshold == best.threshold else ""
        print(f"  T={p.threshold:5d}  {p.energy_j / 1e3:9.1f} kJ "
              f"{bar(p.energy_j, lo, hi)}{mark}")


if __name__ == "__main__":
    main()
