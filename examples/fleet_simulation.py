"""Discrete-event fleet simulation demo: the same dispatch policies the
static analysis compares, now under time — arrivals, queueing, finite
instance counts, and continuous-batching service.

Shows (1) the zero-load limit collapsing onto the static accounting,
(2) a bursty MMPP stream where queue-aware dispatch wins p99 latency at
lower fleet energy, and (3) routed *execution* through the FleetRouter's
per-pool ContinuousBatcher backend with EOS-aware completion.

Run: PYTHONPATH=src python examples/fleet_simulation.py [--queries 200]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, PoolSpec, ThresholdScheduler,
                        WorkloadSpec, paper_fleet, sample_workload, simulate,
                        simulate_fleet)
from repro.core.pricing import normalized_cost_params
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=200)
    ap.add_argument("--arch", default="llama2-7b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    eff, perf = paper_fleet()

    # ---- 1. zero-load limit == static accounting -----------------------------
    calm = sample_workload(50, seed=3, spec=WorkloadSpec(rate_qps=1e-3))
    sched = ThresholdScheduler(cfg, eff, perf, t_in=32)
    static = simulate(cfg, calm, sched)
    fleet0 = simulate_fleet(cfg, calm, {"eff": PoolSpec(eff, 50, 1),
                                        "perf": PoolSpec(perf, 50, 1)}, sched)
    rel = abs(fleet0.total_energy_j - static.total_energy_j) / static.total_energy_j
    print(f"zero-load: static={static.total_energy_j:.1f} J, "
          f"event-driven={fleet0.total_energy_j:.1f} J (rel err {rel:.1e})")

    # ---- 2. bursty stream: static threshold vs queue-aware dispatch ----------
    burst = sample_workload(args.queries, seed=7,
                            spec=WorkloadSpec(rate_qps=3.0),
                            arrival_process="mmpp")
    pools = {"eff": PoolSpec(eff, 4, 2), "perf": PoolSpec(perf, 2, 4)}
    cp = normalized_cost_params(cfg, perf, lam=0.9)
    print(f"\nbursty MMPP stream ({args.queries} queries @ 3 qps mean):")
    for name, s in (("threshold T_in=32", ThresholdScheduler(cfg, eff, perf, t_in=32)),
                    ("capacity-aware", CapacityAwareScheduler(
                        cfg, [eff, perf], {eff.name: 4, perf.name: 2}, cp))):
        r = simulate_fleet(cfg, burst, pools, s, policy_name=name)
        u = {k: f"{p.utilization:.0%}" for k, p in r.per_pool.items()}
        print(f"  {name:20s} fleet E={r.fleet_energy_j:9.0f} J  "
              f"p50={r.p50_latency_s:7.2f}s  p99={r.p99_latency_s:7.2f}s  util={u}")

    # ---- 3. routed execution via per-pool continuous batching ----------------
    ecfg = get_config("smollm-360m").reduced()
    params = M.init_params(ecfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(ecfg, params, max_len=96)
    router = FleetRouter(ecfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    router.attach_batchers(slots=2)
    rng = np.random.default_rng(0)
    routed = [router.submit(rng.integers(0, ecfg.vocab_size, size=8 + 8 * (i % 5)),
                            max_new_tokens=8, eos_id=0)
              for i in range(8)]
    router.drain()
    done = sum(1 for rr in routed if rr.request is not None and rr.request.done)
    print(f"\nrouted execution: {done}/{len(routed)} requests served "
          f"(EOS-aware), split={ {n: s['queries'] for n, s in router.fleet_report().items()} }")


if __name__ == "__main__":
    main()
