"""Million-request fleet simulation: a full diurnal day on a 2000-instance
hybrid fleet, simulated in about a minute on one CPU core.

This is the scale the paper's fleet-level questions live at — how much
energy a heterogeneous fleet spends across a real day of load, where the
peak-hour latency tail sits, and how the efficiency pool's utilization
swings — and it is only reachable because the vectorized engine
(``core.fleet_vec``) settles whole pools of residents in batched numpy
sweeps instead of stepping per-request events. The legacy event engine
(``--engine event``) produces bit-identical results but needs hours at
this size; run it on a small ``--queries`` to see for yourself.

Run: PYTHONPATH=src python examples/fleet_scale.py [--queries 1000000]
"""
import argparse
import time

from repro.configs import get_config
from repro.core import (CostOptimalScheduler, PoolSpec, WorkloadSpec,
                        sample_workload, simulate_fleet)
from repro.core.systems import SystemProfile


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=1_000_000)
    ap.add_argument("--instances", type=int, default=1000,
                    help="instances per pool (two pools)")
    ap.add_argument("--rate", type=float, default=8000.0,
                    help="mean arrival rate over the diurnal day, queries/s")
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--engine", default="vectorized",
                    choices=("event", "vectorized"))
    args = ap.parse_args()

    cfg = get_config(args.arch)
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=90e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=220.0,
                        power_idle_w=60.0, overhead_s=0.02, sat_ctx=4096.0)
    perf = SystemProfile(name="perf", kind="perf", chips=2, peak_flops=200e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=60.0, overhead_s=0.01, sat_ctx=None)

    print(f"sampling {args.queries} arrivals (diurnal, "
          f"{args.rate:g} qps mean) ...")
    qs = sample_workload(args.queries, seed=0,
                         spec=WorkloadSpec(rate_qps=args.rate),
                         arrival_process="diurnal")
    pools = {"eff": PoolSpec(eff, instances=args.instances, slots=8),
             "perf": PoolSpec(perf, instances=args.instances, slots=8)}

    print(f"simulating on {2 * args.instances} instances "
          f"({args.engine} engine) ...")
    t0 = time.perf_counter()
    r = simulate_fleet(cfg, qs, pools, CostOptimalScheduler(cfg, [eff, perf]),
                       engine=args.engine)
    wall_s = time.perf_counter() - t0

    print(f"\n{args.queries} requests over a {r.horizon_s / 3600:.1f} h day "
          f"simulated in {wall_s:.1f} s wall "
          f"({args.queries / wall_s:,.0f} req/s)")
    print(f"fleet energy: {r.fleet_energy_j / 3.6e6:.1f} kWh "
          f"({r.fleet_j_per_token:.3f} J/token idle-inclusive, "
          f"{r.j_per_token:.3f} J/token request-attributed)")
    print(f"latency: p50 {r.p50_latency_s:.2f} s, p99 {r.p99_latency_s:.2f} s, "
          f"mean wait {r.mean_wait_s:.2f} s")
    for name, pp in r.per_pool.items():
        print(f"  pool {name}: {pp.queries} requests, "
              f"utilization {pp.utilization:.2f}, "
              f"{pp.energy_j / 3.6e6:.1f} kWh attributed")


if __name__ == "__main__":
    main()
