"""Expert-parallel MoE inference example: shows the MoE architectures running
with top-k routing and reports router load balance — the substrate the paper's
scheduler prices via active-vs-total parameter counts.

Run: PYTHONPATH=src python examples/moe_expert_parallel.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import energy, tpu_fleet
from repro.models import model as M
from repro.models import moe as MOE


def main():
    for arch in ("phi3.5-moe-42b-a6.6b", "grok-1-314b"):
        full = get_config(arch)
        cfg = full.reduced()
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
        logits, aux = M.forward_train(params, cfg, {"tokens": tok})
        # router statistics from the first layer
        lp = jax.tree.map(lambda x: x[0], params["layers"])
        h = params["embed"]["emb"][tok]
        route_logits = h.reshape(-1, cfg.d_model) @ lp["moe"]["router"]["w"]
        choice = jnp.argmax(route_logits, -1)
        counts = jnp.bincount(choice, length=cfg.moe.num_experts)
        eff, perf = tpu_fleet()
        print(f"{arch}:")
        print(f"  total params {full.param_count() / 1e9:6.1f}B, "
              f"active {full.active_param_count() / 1e9:5.1f}B "
              f"(top-{full.moe.num_experts_per_tok} of {full.moe.num_experts})")
        print(f"  reduced fwd OK, aux load-balance loss {float(aux):.4f}, "
              f"layer-0 expert loads {counts.tolist()}")
        print(f"  E(128in,64out): eff {energy(full, 128, 64, eff):7.1f} J | "
              f"perf {energy(full, 128, 64, perf):7.1f} J "
              f"(priced on ACTIVE FLOPs, TOTAL weight bytes)\n")


if __name__ == "__main__":
    main()
