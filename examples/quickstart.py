"""Quickstart: the paper's scheduling technique in five minutes.

1. Price a query on two device classes with the unified CostModel.
2. Find the energy-optimal threshold on an Alpaca-like workload (paper: 32).
3. Serve real tokens through the hybrid router on a reduced model.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import (CostModel, CostOptimalScheduler, TableOracle,
                        alpaca_like, headline, optimal_threshold, paper_fleet,
                        simulate, threshold_sweep)
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter


def main():
    # ---- 1. the cost model: E(m, n, s) and R(m, n, s) ------------------------
    cfg = get_config("llama2-7b")       # one of the paper's three models
    eff, perf = paper_fleet()           # M1-Pro, 8xA100 (paper Table 1)
    model = CostModel(cfg)              # analytic oracle; swap in a
    #                                     TableOracle/CalibratedOracle to
    #                                     re-price every consumer at once
    for m in (8, 64, 512):
        ee, ep = model.energy(m, 32, eff), model.energy(m, 32, perf)
        print(f"query ({m:4d} in, 32 out): M1-Pro {ee:7.1f} J vs A100 {ep:7.1f} J "
              f"-> {'efficiency' if ee < ep else 'performance'} pool")
    # same numbers through a precomputed interpolation table (fleet-sweep
    # hot-path backend):
    table = CostModel(cfg, TableOracle(cfg))
    print(f"table-oracle check at (100, 70): analytic "
          f"{model.runtime(100, 70, perf):.3f}s vs interpolated "
          f"{table.runtime(100, 70, perf):.3f}s")

    # ---- 2. the paper's Section 6 analysis -----------------------------------
    qs = alpaca_like(5000, seed=0)
    sweep = threshold_sweep(cfg, qs, eff, perf, axis="in")
    best = optimal_threshold(sweep)
    hd = headline(cfg, qs, eff, perf, t_in=best.threshold)
    print(f"\noptimal input threshold T* = {best.threshold} (paper: 32)")
    print(f"hybrid energy savings vs best workload-unaware baseline: "
          f"{hd.savings_vs_best_baseline:.1%} (paper: 7.5%)")
    print(f"runtime penalty vs all-A100: {hd.runtime_penalty_frac_vs_all_perf:.0%} "
          "(the paper's energy/runtime trade-off)")

    # ---- 3. route + execute real tokens --------------------------------------
    small = get_config("smollm-360m").reduced()
    params = M.init_params(small, jax.random.PRNGKey(0))
    engine = InferenceEngine(small, params, max_len=128)
    router = FleetRouter(small, {eff.name: eff, perf.name: perf},
                         {eff.name: engine, perf.name: engine},
                         policy="threshold", t_in=32)
    for m in (8, 100):
        r = router.submit(np.arange(m) % small.vocab_size, 8)
        print(f"\nserved {m}-token prompt on [{r.pool}]: tokens {r.output.tolist()}")
    print("\nfleet report:", router.fleet_report())


if __name__ == "__main__":
    main()
