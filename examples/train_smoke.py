"""End-to-end training driver (deliverable b): train a ~100M-param model for
a few hundred steps on the synthetic learnable task and watch the loss drop.

Run: PYTHONPATH=src python examples/train_smoke.py [--steps 300]
The default model is mamba2-130m at FULL config (130M params) — feasible on
CPU at short sequence length; pass --reduced for a fast demo.
"""
import argparse
import time

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"training {cfg.name} ({cfg.param_count() / 1e6:.0f}M params) for "
          f"{args.steps} steps on the affine-recurrence task")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = OPT.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    stream = D.arithmetic_stream(cfg, args.batch_size, args.seq_len, args.steps)
    t0 = time.time()
    _, _, hist = train_loop(cfg, params, stream, opt,
                            log_every=max(args.steps // 15, 1))
    print(f"done in {time.time() - t0:.0f}s; loss {hist[0][1]:.3f} -> "
          f"{hist[-1][1]:.3f} ({'LEARNED' if hist[-1][1] < 1.0 else 'improving'})")


if __name__ == "__main__":
    main()
