"""Energy-proportional fleet demo: power states + SLO-aware autoscaling.

A diurnal workload (day/night arrival rate) runs against the same fleet
three ways: static provisioning (every instance awake for the whole
makespan — the paper's setting), linger-based sleep (instances drained of
work descend to the profile's ``sleep`` power state and wake on demand),
and a target-utilization autoscaler driving the awake-instance count at a
control-loop cadence. The request-attributed energy barely moves; the
allocated-idle energy — the dominant term at trough utilization — is what
the power machine removes.

Run: PYTHONPATH=src python examples/autoscaling.py [--queries 300]
"""
import argparse

from repro.configs import get_config
from repro.core import (PoolSpec, QueueDepthAutoscaler, SingleSystemScheduler,
                        TargetUtilizationAutoscaler, WorkloadSpec,
                        paper_fleet, sample_workload, simulate_fleet)

SLO_S = 30.0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=300)
    ap.add_argument("--arch", default="llama2-7b")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    _, perf = paper_fleet()
    # compressed day/night cycle so a few hundred queries span two troughs
    qs = sample_workload(args.queries, seed=5, spec=WorkloadSpec(rate_qps=1.0),
                         arrival_process="diurnal", period_s=240.0,
                         amplitude=0.9)

    configs = [
        ("static fleet", PoolSpec(perf, 4, 2), None),
        ("linger 20s", PoolSpec(perf, 4, 2, linger_s=20.0), None),
        ("target-util autoscaler", PoolSpec(perf, 4, 2, linger_s=20.0),
         TargetUtilizationAutoscaler(period_s=10.0, min_instances=1,
                                     target_util=0.6)),
        ("queue-depth autoscaler", PoolSpec(perf, 4, 2, linger_s=20.0),
         QueueDepthAutoscaler(period_s=10.0, min_instances=1)),
    ]

    print(f"diurnal workload: {args.queries} queries, mean 1 qps, "
          f"amplitude 0.9, period 240s — pool: 4x {perf.name} (2 slots)\n")
    print(f"{'config':24s} {'fleet J/tok':>11s} {'attrib':>7s} {'idle':>9s} "
          f"{'p99 s':>7s} {'SLO@30s':>7s} {'wakes':>5s} {'asleep':>6s}")
    base, best = None, None
    for name, spec, scaler in configs:
        r = simulate_fleet(cfg, qs, {"perf": spec},
                           SingleSystemScheduler(cfg, perf),
                           policy_name=name, autoscaler=scaler)
        p = r.per_pool["perf"]
        asleep = p.sleep_s / (spec.instances * r.horizon_s)
        if base is None:
            base = r.fleet_j_per_token
        if best is None or r.fleet_j_per_token < best[1]:
            best = (name, r.fleet_j_per_token)
        print(f"{name:24s} {r.fleet_j_per_token:11.3f} "
              f"{r.total_energy_j:7.0f} {r.idle_energy_j:9.0f} "
              f"{r.p99_latency_s:7.2f} {r.slo_attainment(SLO_S):7.2f} "
              f"{p.wake_count:5d} {asleep:6.0%}")
    print(f"\nsame requests, same routing: the power machine only removes "
          f"allocated-idle draw\n(best fleet J/token vs static: "
          f"-{1 - best[1] / base:.0%}, {best[0]}).")


if __name__ == "__main__":
    main()
