"""End-to-end driver (deliverable b): serve a batched Alpaca-like request
stream through the hybrid fleet with continuous batching on the performance
pool, comparing the paper's threshold policy against baselines.

Run: PYTHONPATH=src python examples/hybrid_serving.py [--requests 40]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import (CostOptimalScheduler, SingleSystemScheduler,
                        ThresholdScheduler, sample_workload, simulate,
                        tpu_fleet)
from repro.models import model as M
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.engine import InferenceEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    eff, perf = tpu_fleet()
    queries = sample_workload(args.requests, seed=1)

    # ---- policy comparison on the analytic fleet model -----------------------
    print("policy comparison (energy / runtime on the TPU hybrid fleet):")
    for name, sched in (
            ("all-performance", SingleSystemScheduler(cfg, perf)),
            ("all-efficiency", SingleSystemScheduler(cfg, eff)),
            ("paper threshold T=32", ThresholdScheduler(cfg, eff, perf, t_in=32)),
            ("cost-optimal (ours)", CostOptimalScheduler(cfg, [eff, perf]))):
        r = simulate(cfg, queries, sched, name)
        print(f"  {name:24s} E={r.total_energy_j:10.1f} J  "
              f"R={r.total_runtime_s:8.1f} s  split={r.per_system_queries}")

    # ---- real execution: continuous batching on the perf pool ----------------
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = InferenceEngine(cfg, params, max_len=256)
    batcher = ContinuousBatcher(engine, slots=args.slots)
    rng = np.random.default_rng(0)
    sched = ThresholdScheduler(cfg, eff, perf, t_in=32)
    routed_perf = [q for q in queries if sched.choose(q) is perf]
    print(f"\nexecuting the {len(routed_perf)} performance-pool requests with "
          f"continuous batching ({args.slots} slots):")
    reqs = []
    for i, q in enumerate(routed_perf):
        prompt = rng.integers(0, cfg.vocab_size, size=min(q.m, 128))
        reqs.append(Request(i, prompt, max_new_tokens=min(q.n, 12)))
        batcher.submit(reqs[-1])
    batcher.run()
    assert all(r.done for r in reqs)
    print(f"  all {len(reqs)} requests served; sample outputs:")
    for r in reqs[:3]:
        print(f"    req{r.rid}: prompt_len={len(r.tokens)} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
