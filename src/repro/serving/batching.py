"""Continuous batching: fixed-slot dense loop and the paged-KV runtime.

Two engines loops share one Request/queue interface:

  * ``ContinuousBatcher`` — the original dense loop: ``slots`` decode lanes
    over one ``(layers, slots, heads, max_len, hd)`` cache; finished lanes
    are refilled by whole-prompt prefill into a spliced lane region.
  * ``PagedContinuousBatcher`` — vLLM-style paged runtime: a shared block
    pool + per-lane block tables (``model.init_paged_cache``), with

      - **memory-aware admission**: a request is admitted only when its
        worst-case context (prompt + token budget) fits in free blocks, so
        "how many requests fit" is governed by KV memory, not the slot count;
      - **chunked prefill**: a queued prompt enters ``chunk`` tokens per tick
        into its blocks while resident lanes keep decoding — a long prompt no
        longer stalls the whole loop;
      - **prefix-block sharing**: full prompt blocks are content-addressed
        and refcounted, so n requests sharing a prompt prefix hold one
        physical copy of its K/V.

This module is deliberately single-model; cross-pool routing lives in
``router.py`` (the paper's scheduler).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import kv_blocks_needed
from repro.models import model as M
from repro.models.model import NULL_BLOCK
from repro.serving.engine import InferenceEngine


@dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (m,) prompt
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    eos_id: Optional[int] = None    # stop early when this token is emitted
    hold: bool = False              # prefill only; decode waits for a handoff


class _BatcherBase:
    """Queue/lane state and the tick loop shared by both runtimes. The
    EOS-retirement predicate in particular must stay ONE definition — the
    dense/paged token-parity gate depends on identical completion rules."""

    def __init__(self, engine: InferenceEngine, slots: int):
        self.engine = engine
        self.slots = slots
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self._last_tok = jnp.zeros((slots,), jnp.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    def _finished(self, req: Request) -> bool:
        """EOS-aware completion: a request retires when it emits its eos_id
        or exhausts its token budget, whichever comes first."""
        if req.eos_id is not None and req.out_tokens and \
                req.out_tokens[-1] == req.eos_id:
            return True
        return len(req.out_tokens) >= req.max_new_tokens

    def step(self) -> None:
        raise NotImplementedError

    def run(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while self.busy and ticks < max_ticks:
            self.step()
            ticks += 1


class ContinuousBatcher(_BatcherBase):
    """Fixed-slot continuous batching loop on one engine (dense cache)."""

    def __init__(self, engine: InferenceEngine, slots: int = 4):
        super().__init__(engine, slots)
        self.cache = engine.new_cache(slots)

    def _retire(self, i: int) -> None:
        self.active[i].done = True
        self.active[i] = None
        self.cache = _clear_lane(self.cache, i)

    def _fill_slots(self) -> None:
        admitted: List[int] = []
        tok_devs: List[jnp.ndarray] = []
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                # per-request prefill into a fresh single-lane cache, then
                # splice the lane into the batched cache
                lane_cache = self.engine.new_cache(1)
                batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
                logits, lane_cache = self.engine.prefill(batch, lane_cache)
                tok_devs.append(jnp.argmax(logits, axis=-1)[0]
                                .astype(jnp.int32))
                admitted.append(i)
                self.cache = _splice_lane(self.cache, lane_cache, i)
        if not admitted:
            return
        # seed next tick's decode input on device, then ONE batched host
        # sync for all admissions this tick (was one blocking int() each)
        tok_dev = jnp.stack(tok_devs)
        self._last_tok = self._last_tok.at[jnp.asarray(admitted)].set(tok_dev)
        toks = np.asarray(tok_dev)  # repro-lint: allow[jax-host-sync]
        for i, tok in zip(admitted, toks):
            req = self.active[i]
            req.out_tokens.append(int(tok))
            if self._finished(req):       # eos on the very first token
                self._retire(i)

    def step(self) -> None:
        """One scheduler tick: refill empty lanes, one batched decode step."""
        self._fill_slots()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return
        logits, self.cache = self.engine.decode(self._last_tok[:, None], self.cache)
        # the argmax stays on device as next tick's input (dead lanes pick up
        # garbage — harmless, refill overwrites before any read); one host
        # sync per tick for the bookkeeping below
        tok_dev = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._last_tok = tok_dev
        toks = np.asarray(tok_dev)  # repro-lint: allow[jax-host-sync]
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(toks[i]))
            if self._finished(req):
                self._retire(i)


# ===========================================================================
# paged runtime
# ===========================================================================
class BlockAllocator:
    """Host-side refcounted free-list over the shared pool.

    Block 0 (``model.NULL_BLOCK``) is reserved as the garbage sink for
    redirected writes and is never handed out; usable capacity is
    ``num_blocks - 1``. Refcounts > 1 arise from prefix sharing — a block is
    returned to the free list only when its last reference drops.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is reserved)")
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))   # pop() yields low ids
        self.refcount = [0] * num_blocks
        self.total_allocs = 0          # fresh blocks ever handed out
        self.peak_used = 0

    @property
    def total_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return self.total_blocks - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh blocks at refcount 1, or None if they don't fit."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self.refcount[b] = 1
        self.total_allocs += n
        self.peak_used = max(self.peak_used, self.used_blocks)
        return out

    def incref(self, blocks: List[int]) -> None:
        for b in blocks:
            if self.refcount[b] <= 0:
                raise ValueError(f"incref of free block {b}")
            self.refcount[b] += 1

    def decref(self, blocks: List[int]) -> None:
        for b in blocks:
            self.refcount[b] -= 1
            if self.refcount[b] < 0:
                raise ValueError(f"double free of block {b}")
            if self.refcount[b] == 0:
                self._free.append(b)


class PrefixBlockCache:
    """Content-addressed map of fully-written prompt blocks -> pool blocks.

    Keys chain parent-hash + the block's tokens, so a hit at depth d implies
    hits at all shallower depths (radix-tree semantics in a flat dict). Each
    entry holds one owned reference; ``evict`` releases entries whose only
    remaining reference is the cache's own, oldest first.
    """

    def __init__(self, allocator: BlockAllocator):
        self.allocator = allocator
        self._map: Dict[Tuple, int] = {}     # chain key -> block id
        self.hits = 0                        # blocks reused via sharing

    @staticmethod
    def _chain(prompt: np.ndarray, block_size: int, upto_blocks: int):
        key: Tuple = ()
        for b in range(upto_blocks):
            key = (key, tuple(int(t) for t in
                              prompt[b * block_size:(b + 1) * block_size]))
            yield key

    def match(self, prompt: np.ndarray, block_size: int) -> List[int]:
        """Longest shared prefix as a list of pool block ids. Matches at most
        ``(m - 1) // block_size`` blocks so every admitted request computes at
        least its final prompt token (whose logits seed decode)."""
        limit = (len(prompt) - 1) // block_size
        out: List[int] = []
        for key in self._chain(prompt, block_size, limit):
            blk = self._map.get(key)
            if blk is None:
                break
            out.append(blk)
        if out:
            self.allocator.incref(out)
            self.hits += len(out)
        return out

    def register(self, prompt: np.ndarray, block_size: int,
                 table: List[int], lo_block: int, hi_block: int) -> None:
        """Pin prompt blocks [lo_block, hi_block) — now fully written — under
        their content keys. Idempotent per key; the pin is an owned ref."""
        for b, key in enumerate(self._chain(prompt, block_size, hi_block)):
            if b < lo_block or key in self._map:
                continue
            self._map[key] = table[b]
            self.allocator.incref([table[b]])

    def evict(self, need: int) -> None:
        """Drop pinned-only entries (refcount == 1) until ``need`` blocks are
        free or nothing more can be released. Deepest chain entries go first:
        evicting a shallow key would orphan its descendants — ``match`` stops
        at the first miss, so they could never be reached again, yet would
        stay pinned."""
        if need <= self.allocator.free_blocks:
            return
        for key in reversed(list(self._map)):
            blk = self._map[key]
            if self.allocator.refcount[blk] == 1:
                del self._map[key]
                self.allocator.decref([blk])
                if self.allocator.free_blocks >= need:
                    return


@dataclass
class _LaneState:
    """Host-side bookkeeping for one decode lane of the paged batcher."""
    blocks: List[int]            # this request's block-table prefix (owned refs)
    prefilled: int               # prompt tokens already written (incl. shared)
    registered: int              # full prompt blocks already in the prefix map


class PagedContinuousBatcher(_BatcherBase):
    """Paged-KV continuous batching: block-table cache, chunked prefill
    interleaved with decode ticks, refcounted prefix sharing, and
    memory-aware admission.

    Interface-compatible with ``ContinuousBatcher`` (submit/step/run/busy)
    plus the observable memory state (``free_blocks``/``total_blocks``) the
    router exports to schedulers via ``PoolSnapshot``.
    """

    def __init__(self, engine: InferenceEngine, slots: int = 4, *,
                 num_blocks: int = 64, block_size: int = 16, chunk: int = 32,
                 prefix_sharing: bool = True):
        super().__init__(engine, slots)
        self.block_size = block_size
        self.chunk = chunk
        self.cache = engine.new_paged_cache(slots, num_blocks, block_size)
        self.allocator = BlockAllocator(num_blocks)
        self.prefix = PrefixBlockCache(self.allocator) if prefix_sharing else None
        self.max_blocks_per_lane = kv_blocks_needed(engine.max_len, block_size)
        self._lane: List[Optional[_LaneState]] = [None] * slots

    # ---------------------------------------------------------------- state
    @property
    def total_blocks(self) -> int:
        return self.allocator.total_blocks

    @property
    def free_blocks(self) -> int:
        """Admission headroom: free-list blocks plus what prefix eviction
        could reclaim (pinned-only entries)."""
        return self.allocator.free_blocks + self._evictable()

    def _evictable(self) -> int:
        if self.prefix is None:
            return 0
        return sum(1 for blk in self.prefix._map.values()
                   if self.allocator.refcount[blk] == 1)

    def submit(self, req: Request) -> None:
        need = self._blocks_needed(req)
        if need > min(self.max_blocks_per_lane, self.allocator.total_blocks):
            raise ValueError(
                f"request {req.rid}: worst-case context "
                f"{len(req.tokens) + req.max_new_tokens} tokens needs {need} "
                f"blocks, but a lane holds at most "
                f"{min(self.max_blocks_per_lane, self.allocator.total_blocks)}")
        super().submit(req)

    def _blocks_needed(self, req: Request) -> int:
        return kv_blocks_needed(len(req.tokens) + req.max_new_tokens,
                                self.block_size)

    # ------------------------------------------------------------ admission
    def _admit(self) -> None:
        """Memory-aware lane refill: FIFO head admitted only when its
        worst-case block need fits (after prefix reuse and eviction)."""
        for i in range(self.slots):
            if self.active[i] is not None or not self.queue:
                continue
            req = self.queue[0]
            prompt = np.asarray(req.tokens)
            need = self._blocks_needed(req)
            shared: List[int] = []
            if self.prefix is not None:
                shared = self.prefix.match(prompt, self.block_size)
            fresh_need = need - len(shared)
            if self.prefix is not None:
                self.prefix.evict(fresh_need)
            fresh = self.allocator.alloc(fresh_need)
            if fresh is None:                     # memory-bound: head waits
                if shared:
                    self.allocator.decref(shared)
                break
            self.queue.pop(0)
            self.active[i] = req
            blocks = shared + fresh
            self._lane[i] = _LaneState(blocks=blocks,
                                       prefilled=len(shared) * self.block_size,
                                       registered=len(shared))
            row = np.full((self.cache["block_tables"].shape[1],), NULL_BLOCK,
                          np.int32)
            row[:len(blocks)] = blocks
            self.cache = dict(
                self.cache,
                block_tables=self.cache["block_tables"].at[i].set(
                    jnp.asarray(row)),
                pos=self.cache["pos"].at[i].set(len(shared) * self.block_size))

    # ------------------------------------------------------------- prefill
    def _prefill_tick(self) -> None:
        """Advance every still-prefilling lane by one chunk. The final chunk
        yields the first output token, exactly like a dense prefill."""
        done_lanes: List[int] = []
        tok_devs: List[jnp.ndarray] = []
        for i in range(self.slots):
            req, lane = self.active[i], self._lane[i]
            if req is None or lane.prefilled >= len(req.tokens):
                continue
            prompt = np.asarray(req.tokens)
            m = len(prompt)
            c = min(self.chunk, m - lane.prefilled)
            buf = np.zeros((self.chunk,), np.int32)
            buf[:c] = prompt[lane.prefilled:lane.prefilled + c]
            logits, self.cache = self.engine.prefill_chunk(
                jnp.asarray(buf)[None], self.cache, i, c)
            lane.prefilled += c
            if self.prefix is not None:
                full = min(lane.prefilled, m) // self.block_size
                if full > lane.registered:
                    self.prefix.register(prompt, self.block_size, lane.blocks,
                                         lane.registered, full)
                    lane.registered = full
            if lane.prefilled >= m:
                done_lanes.append(i)
                tok_devs.append(jnp.argmax(logits, axis=-1)[0]
                                .astype(jnp.int32))
        if not done_lanes:
            return
        # seed the decode input with a device-side scatter (the previous
        # device->host->device round trip stalled the tick), then ONE
        # batched host sync for all completions (was one blocking int()
        # per completing lane)
        tok_dev = jnp.stack(tok_devs)
        self._last_tok = self._last_tok.at[jnp.asarray(done_lanes)].set(
            tok_dev)
        toks = np.asarray(tok_dev)  # repro-lint: allow[jax-host-sync]
        for i, tok in zip(done_lanes, toks):
            req = self.active[i]
            req.out_tokens.append(int(tok))
            if self._finished(req):               # eos on the very first token
                self._retire(i)

    # -------------------------------------------------------------- decode
    def _decode_lanes(self) -> List[int]:
        """Lanes with complete prompts, excluding held ones: a held request
        has prefilled here but decodes elsewhere — its first token (seeded by
        the final prefill chunk) waits in ``out_tokens`` until ``adopt_lane``
        moves the KV to the decode pool."""
        return [i for i, r in enumerate(self.active)
                if r is not None and not r.hold
                and self._lane[i].prefilled >= len(r.tokens)]

    def step(self) -> None:
        """One tick: admit, one prefill chunk per filling lane, one batched
        decode step for lanes with complete prompts. Decode lanes advance
        even while another lane's long prompt is mid-prefill."""
        self._admit()
        self._prefill_tick()
        live = self._decode_lanes()
        if not live:
            return
        mask = np.zeros((self.slots,), bool)
        mask[live] = True
        logits, self.cache = self.engine.decode_paged(
            self._last_tok[:, None], self.cache, jnp.asarray(mask))
        # argmax stays on device as next tick's input; dead/prefilling lanes
        # pick up garbage, which is harmless — prefill completion re-seeds
        # them before any read. One host sync per tick.
        tok_dev = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._last_tok = tok_dev
        toks = np.asarray(tok_dev)  # repro-lint: allow[jax-host-sync]
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(toks[i]))
            if self._finished(req):
                self._retire(i)

    def _retire(self, i: int) -> None:
        self.active[i].done = True
        self.release_lane(i)

    def release_lane(self, i: int) -> None:
        """Free lane ``i`` without completing its request: drop the owned
        block refs (prefix-shared blocks stay pinned) and null the device
        row. ``_retire`` is release + done; a disaggregated handoff releases
        the prefill-side lane after ``adopt_lane`` copied its blocks out,
        leaving the request alive on the decode pool."""
        lane = self._lane[i]
        self.active[i] = None
        self._lane[i] = None
        self.allocator.decref(lane.blocks)        # shared blocks stay pinned
        mb = self.cache["block_tables"].shape[1]
        self.cache = dict(
            self.cache,
            block_tables=self.cache["block_tables"].at[i].set(
                jnp.full((mb,), NULL_BLOCK, jnp.int32)),
            pos=self.cache["pos"].at[i].set(0))

    # ------------------------------------------------------------- handoff
    def adopt_lane(self, req: Request, src: "PagedContinuousBatcher",
                   src_i: int) -> Optional[int]:
        """Resume a held request here: copy its prefilled KV blocks from
        ``src`` and seat it in a free decode lane.

        The request must have finished prefill on ``src`` (its first output
        token, seeded by the final prefill chunk, is in ``out_tokens``; the
        source lane's KV therefore holds exactly the ``m`` prompt tokens —
        the held lane never entered decode). Blocks are copied, not stolen:
        prefix-shared source blocks keep serving the source pool, and the
        caller releases the source lane afterwards (``src.release_lane``).

        Returns the KV payload bytes moved, or ``None`` when no free lane or
        not enough free blocks exist yet — the caller retries next tick, so
        a migration racing admission on a block-starved target degrades to
        waiting, never to a partial copy.
        """
        lane_src = src._lane[src_i]
        if src.active[src_i] is not req or not req.out_tokens or \
                lane_src.prefilled < len(req.tokens):
            raise ValueError(f"request {req.rid}: adopt_lane before its "
                             f"prefill completed on the source pool")
        if self.block_size != src.block_size:
            raise ValueError(
                f"KV migration needs equal block sizes "
                f"(src {src.block_size}, dst {self.block_size})")
        slot = next((i for i, r in enumerate(self.active) if r is None), None)
        if slot is None:
            return None
        ctx = len(req.tokens)                 # prompt only; see docstring
        need = self._blocks_needed(req)       # worst-case full-context hold
        if self.prefix is not None:
            self.prefix.evict(need)
        fresh = self.allocator.alloc(need)
        if fresh is None:                     # block-starved: retry next tick
            return None
        n_copy = kv_blocks_needed(ctx, self.block_size)
        self.cache, moved = migrate_kv_blocks(
            src.cache, lane_src.blocks[:n_copy], self.cache, fresh[:n_copy])
        self.active[slot] = req
        # migrated blocks are private copies — nothing registered for sharing
        self._lane[slot] = _LaneState(blocks=fresh, prefilled=ctx, registered=0)
        row = np.full((self.cache["block_tables"].shape[1],), NULL_BLOCK,
                      np.int32)
        row[:len(fresh)] = fresh
        self.cache = dict(
            self.cache,
            block_tables=self.cache["block_tables"].at[slot].set(
                jnp.asarray(row)),
            pos=self.cache["pos"].at[slot].set(ctx))
        self._last_tok = self._last_tok.at[slot].set(req.out_tokens[-1])
        req.hold = False
        return moved

    def stats(self) -> Dict[str, int]:
        return {
            "total_blocks": self.total_blocks,
            "free_blocks": self.allocator.free_blocks,
            "fresh_allocs": self.allocator.total_allocs,
            "peak_used": self.allocator.peak_used,
            "prefix_hits": self.prefix.hits if self.prefix else 0,
        }


# --------------------------------------------------------------------- lane ops
# Paged-pool tensors subject to KV migration: the K/V block pools and, when
# the cache is int8-quantized, their per-row scale pools. ``pos`` and
# ``block_tables`` are per-lane (not per-block) and stay host-managed.
_KV_POOL_KEYS = ("kp", "vp", "kp_scale", "vp_scale")


def migrate_kv_blocks(src_cache: Dict, src_blocks: List[int],
                      dst_cache: Dict, dst_blocks: List[int]) -> Tuple[Dict, int]:
    """Device-side KV-block migration between two paged pools.

    Gathers ``src_blocks`` along the pool axis (axis 1 of every
    ``(layers, num_blocks, Hkv, block_size, hd)`` pool tensor) from
    ``src_cache`` and scatters them into ``dst_blocks`` of ``dst_cache`` —
    the serving realisation of the bytes the pricing model charges via
    ``CostModel.migration_terms``. The source pool is read, never written
    (copy, not steal), so blocks shared through a ``PrefixBlockCache`` keep
    serving the source pool. Returns ``(new_dst_cache, payload_bytes)``
    where payload_bytes counts the K/V (+scale) bytes moved once.
    """
    if len(src_blocks) != len(dst_blocks):
        raise ValueError(f"block list length mismatch: {len(src_blocks)} "
                         f"source vs {len(dst_blocks)} destination")
    if not src_blocks:
        return dst_cache, 0
    src_ids = jnp.asarray(src_blocks, jnp.int32)
    dst_ids = jnp.asarray(dst_blocks, jnp.int32)
    out = dict(dst_cache)
    moved = 0
    for k in _KV_POOL_KEYS:
        if k not in src_cache:
            continue
        sv, dv = src_cache[k], dst_cache.get(k)
        if dv is None or sv.shape[:1] + sv.shape[2:] != dv.shape[:1] + dv.shape[2:] \
                or sv.dtype != dv.dtype:
            raise ValueError(
                f"pool geometry mismatch on {k!r}: migration needs the same "
                f"model/block_size/dtype on both ends")
        payload = sv[:, src_ids]
        out[k] = dv.at[:, dst_ids].set(payload)
        moved += payload.size * payload.dtype.itemsize
    return out, moved
# Cache keys whose leading axis is the batch (everything else produced by
# M.init_cache is layer-leading with batch at axis 1). Explicit metadata, not
# a shape heuristic: comparing v.shape[0] == lv.shape[0] misclassifies
# batch-leading tensors whenever slots == 1 (or slots == n_layers), silently
# corrupting the spliced cache.
_BATCH_LEADING_KEYS = frozenset({"pos"})


def _batch_axis(key: str, v) -> int:
    return 0 if key in _BATCH_LEADING_KEYS or v.ndim == 1 else 1


def _splice_lane(cache: Dict, lane: Dict, i: int) -> Dict:
    """Copy single-lane cache (batch dim 1) into batch position i."""
    out = dict(cache)
    for k, v in cache.items():
        lv = lane[k]
        if _batch_axis(k, v) == 0:
            out[k] = v.at[i].set(lv[0])
        else:
            out[k] = v.at[:, i].set(lv[:, 0])
    return out


def _clear_lane(cache: Dict, i: int) -> Dict:
    """Free a lane. Only ``pos`` needs resetting: the decode kernels mask by
    kv_len, so stale KV rows are unreachable; SSM states are overwritten by
    the next splice."""
    return dict(cache, pos=cache["pos"].at[i].set(0))
