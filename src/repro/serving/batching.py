"""Continuous batching over a fixed-slot decode batch.

Slot-based engine loop (vLLM-style, TPU-friendly static shapes):
  * ``slots`` decode lanes share one jit'd decode_step;
  * finished/empty lanes are refilled by prefilling queued requests into the
    lane's cache region (prefill runs per-request, decode runs batched);
  * per-lane kv_len rides in the cache's ``pos`` vector, so ragged contexts
    are handled by the decode-attention kernel's length masking.

This module is deliberately single-model; cross-pool routing lives in
``router.py`` (the paper's scheduler).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.serving.engine import InferenceEngine


@dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (m,) prompt
    max_new_tokens: int
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    eos_id: Optional[int] = None    # stop early when this token is emitted


class ContinuousBatcher:
    """Fixed-slot continuous batching loop on one engine."""

    def __init__(self, engine: InferenceEngine, slots: int = 4):
        self.engine = engine
        self.slots = slots
        self.queue: List[Request] = []
        self.active: List[Optional[Request]] = [None] * slots
        self.cache = engine.new_cache(slots)
        self._last_tok = jnp.zeros((slots,), jnp.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.active)

    def _finished(self, req: Request) -> bool:
        """EOS-aware completion: a request retires when it emits its eos_id
        or exhausts its token budget, whichever comes first."""
        if req.eos_id is not None and req.out_tokens and \
                req.out_tokens[-1] == req.eos_id:
            return True
        return len(req.out_tokens) >= req.max_new_tokens

    def _retire(self, i: int) -> None:
        self.active[i].done = True
        self.active[i] = None
        self.cache = _clear_lane(self.cache, i)

    def _fill_slots(self) -> None:
        for i in range(self.slots):
            if self.active[i] is None and self.queue:
                req = self.queue.pop(0)
                self.active[i] = req
                m = len(req.tokens)
                # per-request prefill into a fresh single-lane cache, then
                # splice the lane into the batched cache
                lane_cache = M.init_cache(self.engine.cfg, 1, self.engine.max_len,
                                          self.engine.dtype,
                                          enc_len=self.engine.cfg.encoder_seq_len or None)
                batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None]}
                logits, lane_cache = self.engine.prefill(batch, lane_cache)
                tok = int(jnp.argmax(logits, axis=-1)[0])
                req.out_tokens.append(tok)
                self._last_tok = self._last_tok.at[i].set(tok)
                self.cache = _splice_lane(self.cache, lane_cache, i)
                if self._finished(req):       # eos on the very first token
                    self._retire(i)

    def step(self) -> None:
        """One scheduler tick: refill empty lanes, one batched decode step."""
        self._fill_slots()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return
        logits, self.cache = self.engine.decode(self._last_tok[:, None], self.cache)
        toks = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for i in live:
            req = self.active[i]
            req.out_tokens.append(int(toks[i]))
            self._last_tok = self._last_tok.at[i].set(int(toks[i]))
            if self._finished(req):
                self._retire(i)

    def run(self, max_ticks: int = 10_000) -> None:
        ticks = 0
        while self.busy and ticks < max_ticks:
            self.step()
            ticks += 1


# --------------------------------------------------------------------- lane ops
# Cache keys whose leading axis is the batch (everything else produced by
# M.init_cache is layer-leading with batch at axis 1). Explicit metadata, not
# a shape heuristic: comparing v.shape[0] == lv.shape[0] misclassifies
# batch-leading tensors whenever slots == 1 (or slots == n_layers), silently
# corrupting the spliced cache.
_BATCH_LEADING_KEYS = frozenset({"pos"})


def _batch_axis(key: str, v) -> int:
    return 0 if key in _BATCH_LEADING_KEYS or v.ndim == 1 else 1


def _splice_lane(cache: Dict, lane: Dict, i: int) -> Dict:
    """Copy single-lane cache (batch dim 1) into batch position i."""
    out = dict(cache)
    for k, v in cache.items():
        lv = lane[k]
        if _batch_axis(k, v) == 0:
            out[k] = v.at[i].set(lv[0])
        else:
            out[k] = v.at[:, i].set(lv[:, 0])
    return out


def _clear_lane(cache: Dict, i: int) -> Dict:
    """Free a lane. Only ``pos`` needs resetting: the decode kernels mask by
    kv_len, so stale KV rows are unreachable; SSM states are overwritten by
    the next splice."""
    return dict(cache, pos=cache["pos"].at[i].set(0))
