"""FleetRouter: the paper's scheduler as a first-class serving feature.

A fleet is a set of *pools*; each pool is (SystemProfile, engine-or-batcher,
instance count). Incoming requests carry (m, expected_n); the router prices
them with the unified ``CostModel`` (``core.pricing``) and dispatches through
the same uniform ``Scheduler.dispatch(query, fleet_state)`` API the
discrete-event fleet simulator uses — so a policy validated in simulation
drops into serving unchanged, and swapping the perf oracle (analytic / table
/ calibrated) re-prices serving decisions in one place. Execution on this
CPU container is functional (every pool runs the same JAX engine);
energy/runtime are accounted analytically per the assigned pool's profile —
exactly the quantity the paper optimizes.

Execution backends per pool:
  * engine  — immediate, blocking ``generate`` per request;
  * batcher — a ``ContinuousBatcher`` (vLLM-style slots, EOS-aware): requests
    queue, ``drain()`` runs all pools' decode loops to completion.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import DeferPlan, SplitPlan
from repro.core.pricing import CostModel, CostParams, PerfOracle
from repro.core.scheduler import (CapacityAwareScheduler, CostOptimalScheduler,
                                  DisaggregatedScheduler, FleetState,
                                  PoolSnapshot, Scheduler, ThresholdScheduler)
from repro.core.settlement import (reconcile_deltas, reconcile_split_deltas,
                                   resolve_plan, route_bookings)
from repro.core.systems import SystemProfile
from repro.core.workload import Query
from repro.serving.batching import (ContinuousBatcher, PagedContinuousBatcher,
                                    Request)
from repro.serving.engine import InferenceEngine


@dataclass
class PoolStats:
    """Per-pool accounting. ``expected_*`` is booked at routing time from the
    request's declared (m, expected_n); the unprefixed totals are reconciled
    against the tokens actually emitted (EOS can retire a request early), so
    they are the execution-faithful numbers. For route()-only flows with no
    execution backend the two coincide."""
    queries: int = 0
    energy_j: float = 0.0
    runtime_s: float = 0.0
    tokens: int = 0
    expected_energy_j: float = 0.0
    expected_runtime_s: float = 0.0
    expected_tokens: int = 0


@dataclass
class RoutedRequest:
    rid: int
    pool: str
    energy_j: float
    runtime_s: float
    output: Optional[np.ndarray] = None
    request: Optional[Request] = None     # set when executed via a batcher


class FleetRouter:
    def __init__(self, cfg: ModelConfig, pools: Dict[str, SystemProfile],
                 engines: Optional[Dict[str, InferenceEngine]] = None, *,
                 policy: str = "threshold", t_in: int = 32, t_out: int = 32,
                 axis: str = "in", lam: float = 1.0,
                 counts: Optional[Dict[str, int]] = None,
                 oracle: Optional[PerfOracle] = None,
                 model: Optional[CostModel] = None):
        self.cfg = cfg
        self.pools = pools
        self.engines = engines or {}
        self.batchers: Dict[str, ContinuousBatcher] = {}
        self.counts = counts or {s.name: 1 for s in pools.values()}
        self.stats = {name: PoolStats() for name in pools}
        systems = list(pools.values())
        if model is not None:
            if oracle is not None:
                raise ValueError("pass either model= or oracle=, not both "
                                 "(the model already carries its oracle)")
            if lam != 1.0 and lam != model.cp.lam:
                raise ValueError(f"conflicting lam: lam={lam} but the given "
                                 f"model prices with lam={model.cp.lam}")
        else:
            model = CostModel(cfg, oracle, CostParams(lam=lam))
        self.model = model
        if policy == "threshold":
            eff = next(s for s in systems if s.kind == "eff")
            perf = next(s for s in systems if s.kind == "perf")
            self.scheduler: Scheduler = ThresholdScheduler(
                cfg, eff, perf, t_in=t_in, t_out=t_out, axis=axis, model=model)
        elif policy == "cost_optimal":
            self.scheduler = CostOptimalScheduler(cfg, systems, model=model)
        elif policy == "capacity_aware":
            self.scheduler = CapacityAwareScheduler(cfg, systems, self.counts,
                                                    model=model)
        elif policy == "disaggregated":
            self.scheduler = DisaggregatedScheduler(cfg, systems, model=model)
        else:
            raise ValueError(policy)
        self._name_of = {s.name: n for n, s in pools.items()}
        self._system_of = {s.name: s for s in pools.values()}
        if len(self._name_of) != len(pools):
            raise ValueError("pools must use distinct SystemProfile names: "
                             "dispatch maps a chosen system back to its pool "
                             "by name")
        self._rid = 0
        # batcher-executed requests awaiting actual-token reconciliation:
        # (pool, m, expected_n, Request, decode-pool-or-None)
        self._pending: List[tuple] = []
        # decode pool chosen by the most recent route() when it picked a
        # split plan, else None — submit() reads it to arm the handoff
        self._last_split: Optional[str] = None
        # rid -> (prefill pool, decode pool, Request) awaiting KV handoff
        self._handoffs: Dict[int, tuple] = {}

    # ------------------------------------------------------------- batchers
    def attach_batchers(self, slots: int = 4, *, paged: bool = False,
                        num_blocks: int = 64, block_size: int = 16,
                        chunk: int = 32, prefix_sharing: bool = True) -> None:
        """Give every engine-backed pool a continuous-batching backend.

        ``paged=True`` attaches ``PagedContinuousBatcher`` instances
        (block-table cache, chunked prefill, memory-aware admission); their
        block occupancy is then exported to schedulers via the
        ``PoolSnapshot`` free/total-block fields."""
        for name, eng in self.engines.items():
            if paged:
                self.batchers[name] = PagedContinuousBatcher(
                    eng, slots=slots, num_blocks=num_blocks,
                    block_size=block_size, chunk=chunk,
                    prefix_sharing=prefix_sharing)
            else:
                self.batchers[name] = ContinuousBatcher(eng, slots=slots)

    def _fleet_state(self, now: float = 0.0) -> FleetState:
        """Observable per-pool queue state for the dispatch API. Pools run a
        single batcher instance here; est_wait is the queued backlog PLUS the
        residual decode of active lanes (a busy pool with empty queue still
        has work in flight), spread over its slots. Paged batchers also
        report block occupancy so memory-aware policies see the real
        capacity limit."""
        snaps = {}
        for name, sysp in self.pools.items():
            cb = self.batchers.get(name)
            busy = queue_len = 0
            slots = cb.slots if cb is not None else 1
            est_wait = 0.0
            if cb is not None:
                busy = sum(1 for r in cb.active if r is not None)
                queue_len = len(cb.queue)
                # batched pricing: one runtime_batch over the queue and one
                # price_batch over the active lanes replace the per-request
                # scalar calls; summing the per-request terms left-to-right
                # in queue-then-active order reproduces the scalar
                # accumulation bit-for-bit
                vals: List[float] = []
                if cb.queue:
                    m_arr = np.fromiter((len(r.tokens) for r in cb.queue),
                                        np.int64, queue_len)
                    n_arr = np.fromiter((r.max_new_tokens for r in cb.queue),
                                        np.int64, queue_len)
                    vals += self.model.runtime_batch(m_arr, n_arr,
                                                     sysp).tolist()
                act = [r for r in cb.active if r is not None]
                if act:                        # residual decode of residents
                    m_arr = np.fromiter((len(r.tokens) for r in act),
                                        np.int64, len(act))
                    n_arr = np.fromiter((r.max_new_tokens for r in act),
                                        np.int64, len(act))
                    rem = np.fromiter(
                        (max(0, r.max_new_tokens - len(r.out_tokens))
                         for r in act), np.int64, len(act))
                    ph = self.model.price_batch(m_arr, n_arr, sysp, batch=1)
                    vals += (ph.t_decode / np.maximum(1, n_arr)
                             * rem).tolist()
                est_wait = sum(vals) / max(1, slots)
            # mirror the fleet simulator's awake-count view: serving pools
            # run hot (no power machine in front of a live batcher), so every
            # instance is awake and waking capacity is never pending — but
            # policies validated against power-managed simulations read the
            # same fields here and need no serving-side special case.
            n_inst = self.counts.get(sysp.name, 1)
            snaps[name] = PoolSnapshot(
                system=sysp, instances=n_inst,
                slots_per_instance=slots, busy_slots=busy,
                queue_len=queue_len, est_wait_s=est_wait,
                free_blocks=getattr(cb, "free_blocks", None),
                total_blocks=getattr(cb, "total_blocks", None),
                block_size=getattr(cb, "block_size", 0),
                awake_instances=n_inst, asleep_instances=0,
                wake_delay_s=0.0)
        return FleetState(time_s=now, pools=snaps)

    # --------------------------------------------------------------- routing
    def route(self, m: int, expected_n: int, arrival_s: float = 0.0) -> str:
        """Pick a pool for an (m, n) request; update accounting.

        Both expected and actual totals are booked here at ``expected_n``;
        execution paths reconcile the actual totals once the emitted token
        count is known (``_reconcile``), so EOS-retired requests no longer
        overcount pool energy/runtime."""
        q = Query(m, expected_n, arrival_s)
        # Build the snapshot only when the policy actually reads it: without
        # an execution backend there is no observable queue state (stateful
        # policies then fall back to their reservation model), and policies
        # using the base workload-only dispatch never look at it.
        fleet = None
        if self.batchers and type(self.scheduler).dispatch is not Scheduler.dispatch:
            fleet = self._fleet_state(arrival_s)
        plan = resolve_plan(self.scheduler.dispatch(q, fleet), q, self._name_of)
        self.scheduler.observe(q, plan)
        self._last_split = None
        if isinstance(plan, DeferPlan):
            # live serving cannot time-shift an in-flight request: the inner
            # placement runs immediately (the defer window is a simulation /
            # global-dispatch concern)
            plan = plan.inner
        if isinstance(plan, SplitPlan):
            name_a = self._name_of[plan.pool_prefill]
            self._last_split = self._name_of[plan.pool_decode]
            bs = getattr(self.batchers.get(name_a), "block_size", 0)
        else:
            name_a = self._name_of[plan.pool]
            bs = 0
        for b in route_bookings(self.model, plan, q, self._system_of,
                                block_size=bs):
            st = self.stats[self._name_of[b.pool]]
            st.queries += b.queries
            st.energy_j += b.energy_j
            st.runtime_s += b.runtime_s
            st.tokens += b.tokens
            st.expected_energy_j += b.energy_j
            st.expected_runtime_s += b.runtime_s
            st.expected_tokens += b.tokens
        return name_a

    def _reconcile_split(self, name_a: str, name_b: str, m: int,
                         expected_n: int, actual_n: int) -> None:
        """Split-plan analogue of ``_reconcile``: re-book each phase term on
        its own pool at the emitted token count (deltas from
        ``core.settlement``). Migration depends only on ``m`` and needs no
        adjustment."""
        if actual_n == expected_n:
            return
        (da_e, da_r), (db_e, db_r), dn = reconcile_split_deltas(
            self.model, m, expected_n, actual_n,
            self.pools[name_a], self.pools[name_b])
        st_a, st_b = self.stats[name_a], self.stats[name_b]
        st_a.energy_j += da_e
        st_a.runtime_s += da_r
        st_b.energy_j += db_e
        st_b.runtime_s += db_r
        st_b.tokens += dn

    def _reconcile(self, name: str, m: int, expected_n: int,
                   actual_n: int) -> None:
        """Replace a request's expected-(m, n) booking in the ACTUAL totals
        with its emitted token count (expected_* keeps the routing-time
        view)."""
        if actual_n == expected_n:
            return
        d_e, d_r, dn = reconcile_deltas(self.model, m, expected_n, actual_n,
                                        self.pools[name])
        st = self.stats[name]
        st.energy_j += d_e
        st.runtime_s += d_r
        st.tokens += dn

    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               arrival_s: float = 0.0,
               eos_id: Optional[int] = None) -> RoutedRequest:
        """Route AND execute.

        If the pool has an attached ContinuousBatcher the request is queued
        (EOS-aware; call ``drain()`` to run the decode loops). Otherwise, if
        an engine is attached, it generates immediately.
        """
        self._rid += 1
        name = self.route(len(tokens), max_new_tokens, arrival_s)
        split_to = self._last_split
        out, req = None, None
        if name in self.batchers:
            req = Request(self._rid, np.asarray(tokens), max_new_tokens,
                          eos_id=eos_id)
            src, dst = self.batchers[name], self.batchers.get(split_to)
            if (split_to is not None
                    and isinstance(src, PagedContinuousBatcher)
                    and isinstance(dst, PagedContinuousBatcher)
                    and src.block_size == dst.block_size):
                # live handoff: prefill on `name`, hold, then adopt_lane
                # migrates the KV blocks to `split_to` during drain()
                req.hold = True
                self._handoffs[self._rid] = (name, split_to, req)
            else:
                # split plan priced/booked but not executable on these
                # backends (dense batcher or block-size mismatch): the
                # request runs entirely on the prefill pool — execution here
                # is functional, the booking keeps the priced plan
                split_to = None
            src.submit(req)
            self._pending.append((name, len(tokens), max_new_tokens, req,
                                  split_to))
        elif name in self.engines:
            import jax.numpy as jnp
            res = self.engines[name].generate(
                {"tokens": jnp.asarray(tokens, jnp.int32)[None]}, max_new_tokens,
                eos_id=eos_id)
            out = res.tokens[0]
            if split_to is not None:
                self._reconcile_split(name, split_to, len(tokens),
                                      max_new_tokens, len(out))
            else:
                self._reconcile(name, len(tokens), max_new_tokens, len(out))
        sysp = self.pools[name]
        return RoutedRequest(self._rid, name,
                             self.model.energy(len(tokens), max_new_tokens, sysp),
                             self.model.runtime(len(tokens), max_new_tokens, sysp),
                             out, req)

    def drain(self, max_ticks: int = 10_000) -> None:
        """Run every pool's continuous-batching loop until all requests done,
        then reconcile PoolStats against the tokens actually emitted (EOS may
        have retired requests before their declared budget).

        With handoffs pending the pools are ticked in lock-step so a held
        request can finish prefill on one pool and resume decode on another
        mid-drain; without any, each pool just runs to completion."""
        if self._handoffs:
            ticks = 0
            while ticks < max_ticks and (
                    self._handoffs
                    or any(cb.busy for cb in self.batchers.values())):
                for cb in self.batchers.values():
                    if cb.busy:
                        cb.step()
                if self._handoffs:
                    self._do_handoffs()
                ticks += 1
        else:
            for cb in self.batchers.values():
                cb.run(max_ticks)
        for name, m, expected_n, req, split_to in self._pending:
            if req.done:
                if split_to is None:
                    self._reconcile(name, m, expected_n, len(req.out_tokens))
                else:
                    self._reconcile_split(name, split_to, m, expected_n,
                                          len(req.out_tokens))
        self._pending = [p for p in self._pending if not p[3].done]

    def _do_handoffs(self) -> None:
        """Adopt every held request whose prefill has finished: the decode
        pool copies its KV blocks (``adopt_lane``) and the prefill-side lane
        is released. A lane-starved or block-starved decode pool leaves the
        handoff pending — retried next tick, after its own retirements have
        freed capacity."""
        remaining: Dict[int, tuple] = {}
        for rid, (src_name, dst_name, req) in self._handoffs.items():
            src = self.batchers[src_name]
            if req.done:
                # EOS on the very first token, mid-prefill: nothing decodes
                # and the booked migration never happens — undo it in the
                # execution-faithful totals (expected_* keeps the plan)
                bs = getattr(src, "block_size", 0)
                _, mig_s, mig_j = self.model.migration_terms(
                    len(req.tokens), self.pools[src_name],
                    self.pools[dst_name], block_size=bs)
                self.stats[src_name].energy_j -= mig_j
                self.stats[src_name].runtime_s -= mig_s
                continue
            src_i = next((i for i, r in enumerate(src.active) if r is req),
                         None)
            if src_i is None or not req.out_tokens or \
                    src._lane[src_i].prefilled < len(req.tokens):
                remaining[rid] = (src_name, dst_name, req)   # still prefilling
                continue
            if self.batchers[dst_name].adopt_lane(req, src, src_i) is None:
                remaining[rid] = (src_name, dst_name, req)   # target starved
                continue
            src.release_lane(src_i)
        self._handoffs = remaining

    def fleet_report(self) -> Dict[str, Dict]:
        return {n: vars(s) for n, s in self.stats.items()}
