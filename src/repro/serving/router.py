"""FleetRouter: the paper's scheduler as a first-class serving feature.

A fleet is a set of *pools*; each pool is (SystemProfile, engine-or-batcher,
instance count). Incoming requests carry (m, expected_n); the router prices
them with the core cost model and dispatches per the configured policy
(threshold / cost-optimal / capacity-aware). Execution on this CPU container
is functional (every pool runs the same JAX engine); energy/runtime are
accounted analytically per the assigned pool's profile — exactly the
quantity the paper optimizes.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost import CostParams
from repro.core.energy import energy
from repro.core.perf_model import runtime
from repro.core.scheduler import (CapacityAwareScheduler, CostOptimalScheduler,
                                  Scheduler, ThresholdScheduler)
from repro.core.systems import SystemProfile
from repro.core.workload import Query
from repro.serving.engine import InferenceEngine


@dataclass
class PoolStats:
    queries: int = 0
    energy_j: float = 0.0
    runtime_s: float = 0.0
    tokens: int = 0


@dataclass
class RoutedRequest:
    rid: int
    pool: str
    energy_j: float
    runtime_s: float
    output: Optional[np.ndarray] = None


class FleetRouter:
    def __init__(self, cfg: ModelConfig, pools: Dict[str, SystemProfile],
                 engines: Optional[Dict[str, InferenceEngine]] = None, *,
                 policy: str = "threshold", t_in: int = 32, t_out: int = 32,
                 axis: str = "in", lam: float = 1.0,
                 counts: Optional[Dict[str, int]] = None):
        self.cfg = cfg
        self.pools = pools
        self.engines = engines or {}
        self.stats = {name: PoolStats() for name in pools}
        systems = list(pools.values())
        cp = CostParams(lam=lam)
        if policy == "threshold":
            eff = next(s for s in systems if s.kind == "eff")
            perf = next(s for s in systems if s.kind == "perf")
            self.scheduler: Scheduler = ThresholdScheduler(
                cfg, eff, perf, t_in=t_in, t_out=t_out, axis=axis, cp=cp)
        elif policy == "cost_optimal":
            self.scheduler = CostOptimalScheduler(cfg, systems, cp)
        elif policy == "capacity_aware":
            self.scheduler = CapacityAwareScheduler(
                cfg, systems, counts or {s.name: 1 for s in systems}, cp)
        else:
            raise ValueError(policy)
        self._name_of = {id(s): n for n, s in pools.items()}
        self._rid = 0

    def route(self, m: int, expected_n: int, arrival_s: float = 0.0) -> str:
        """Pick a pool for an (m, n) request; update accounting."""
        q = Query(m, expected_n, arrival_s)
        sys = self.scheduler.choose(q) if hasattr(self.scheduler, "choose") else \
            self.scheduler.assign([q])[0].system
        name = self._name_of[id(sys)]
        st = self.stats[name]
        st.queries += 1
        st.energy_j += energy(self.cfg, m, expected_n, sys)
        st.runtime_s += runtime(self.cfg, m, expected_n, sys)
        st.tokens += m + expected_n
        return name

    def submit(self, tokens: np.ndarray, max_new_tokens: int,
               arrival_s: float = 0.0) -> RoutedRequest:
        """Route AND execute (if an engine is attached to the pool)."""
        self._rid += 1
        name = self.route(len(tokens), max_new_tokens, arrival_s)
        out = None
        if name in self.engines:
            import jax.numpy as jnp
            res = self.engines[name].generate(
                {"tokens": jnp.asarray(tokens, jnp.int32)[None]}, max_new_tokens)
            out = res.tokens[0]
        sysp = self.pools[name]
        return RoutedRequest(self._rid, name,
                             energy(self.cfg, len(tokens), max_new_tokens, sysp),
                             runtime(self.cfg, len(tokens), max_new_tokens, sysp),
                             out)

    def fleet_report(self) -> Dict[str, Dict]:
        return {n: vars(s) for n, s in self.stats.items()}
