"""Inference engine: jit'd prefill / decode steps over the model zoo.

The engine owns params + compiled step functions for one architecture on one
(logical) system. Generation is greedy (argmax) by default; sampling hooks
accept a temperature. Energy/runtime accounting per request is attached via
the core analytic model so the FleetRouter can report fleet-level totals.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import kv_blocks_needed
from repro.models import model as M


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_out) generated tokens
    prompt_len: int
    steps: int


class InferenceEngine:
    """Single-model engine with a fixed max context and batch size."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 backend: str = "auto", dtype=jnp.float32,
                 kv_quant: bool = False):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.backend = backend
        self.dtype = dtype
        self.kv_quant = kv_quant
        self._prefill = jax.jit(functools.partial(M.prefill, cfg=cfg, backend=backend))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg=cfg, backend=backend))
        self._prefill_chunk = jax.jit(
            functools.partial(M.prefill_paged_chunk, cfg=cfg, backend=backend))
        self._decode_paged = jax.jit(
            functools.partial(M.decode_step_paged, cfg=cfg, backend=backend))

    # ------------------------------------------------------------------ api
    def new_cache(self, batch_size: int):
        return M.init_cache(self.cfg, batch_size, self.max_len, self.dtype,
                            enc_len=self.cfg.encoder_seq_len or None,
                            kv_quant=self.kv_quant)

    def new_paged_cache(self, lanes: int, num_blocks: int, block_size: int):
        """Paged cache sized so one lane can hold up to ``max_len`` context."""
        mb = kv_blocks_needed(self.max_len, block_size)
        return M.init_paged_cache(self.cfg, lanes, num_blocks, block_size,
                                  self.dtype, max_blocks_per_lane=mb,
                                  kv_quant=self.kv_quant)

    def prefill_chunk(self, tokens: jnp.ndarray, cache, lane: int, n_valid: int):
        """Chunked prefill of one lane (see ``model.prefill_paged_chunk``).
        ``lane``/``n_valid`` trace as 0-d arrays: one compilation per chunk
        shape, not per lane or valid count."""
        return self._prefill_chunk(params=self.params, tokens=tokens,
                                   cache=cache, lane=lane, n_valid=n_valid)

    def decode_paged(self, tokens: jnp.ndarray, cache, live: jnp.ndarray):
        return self._decode_paged(params=self.params, tokens=tokens,
                                  cache=cache, live=live)

    def prefill(self, batch: Dict[str, jnp.ndarray], cache=None):
        B = batch["tokens"].shape[0]
        if cache is None:
            cache = self.new_cache(B)
        logits, cache = self._prefill(params=self.params, batch=batch, cache=cache)
        return logits, cache

    def decode(self, tokens: jnp.ndarray, cache):
        return self._decode(params=self.params, tokens=tokens, cache=cache)

    def generate(self, batch: Dict[str, jnp.ndarray], max_new_tokens: int = 32,
                 *, temperature: float = 0.0, key=None,
                 eos_id: Optional[int] = None) -> GenerationResult:
        """Greedy (or sampled) generation. All requests share prompt length.

        With temperature > 0 and no explicit key, a fixed seeded PRNGKey is
        used so sampled generation is reproducible by default (previously
        key=None crashed inside jax.random.fold_in).
        """
        if temperature > 0.0 and key is None:
            key = jax.random.PRNGKey(0)
        B, S = batch["tokens"].shape
        logits, cache = self.prefill(batch)
        out = []
        tok = self._select(logits, temperature, key, 0)
        out.append(tok)
        for i in range(max_new_tokens - 1):
            logits, cache = self.decode(tok[:, None], cache)
            tok = self._select(logits, temperature, key, i + 1)
            out.append(tok)
            # deliberate per-token sync: early EOS exit saves whole decode
            # steps, which dwarfs the transfer cost at batch scale
            if eos_id is not None and bool(  # repro-lint: allow[jax-host-sync]
                    jnp.all(tok == eos_id)):
                break
        toks = np.stack([np.asarray(t) for t in out], axis=1)
        return GenerationResult(tokens=toks, prompt_len=S, steps=toks.shape[1])

    @staticmethod
    def _select(logits, temperature, key, step):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        k = jax.random.fold_in(key, step)
        return jax.random.categorical(k, logits / temperature).astype(jnp.int32)
