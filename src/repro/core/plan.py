"""Placement-plan IR: the one typed value a scheduler hands to its callers.

Nine PRs grew four ad-hoc encodings of "where should this query run" —
bare ``SystemProfile`` returns, ``(prefill, decode)`` tuples from the
disaggregated policy, reservation side-state, and route-now-vs-defer
special-casing inside the carbon scheduler. This module closes that set
into a small IR that every engine and the live router settle identically
(``core.settlement``):

  * ``RunPlan(pool)``                         — run both phases on one pool;
  * ``SplitPlan(pool_prefill, pool_decode)``  — prefill here, migrate the KV
                                                prefix, decode there;
  * ``DeferPlan(until_s, inner)``             — admit the inner plan at a
                                                later clock (batch tiers
                                                riding a green window).

Pools are referenced by **system name** (the key both fleet engines and the
router already map back to their runtime pools), which keeps every plan a
plain JSON-serializable value: ``plan_to_json`` / ``plan_from_json``
round-trip each variant exactly.

Plans carry optional ``PlanTerms`` — the priced energy/runtime/wait
components (from ``CostModel``) behind the decision, plus the Eq. 1 cost
the scheduler minimized. Terms are advisory: settlement re-prices bookings
through the same ``CostModel`` seam, so a stale or absent ``terms`` never
desynchronizes accounting.

Legacy returns (a bare ``SystemProfile`` or an ``(a, b)`` profile tuple)
are coerced by ``as_plan`` one release behind a ``DeprecationWarning`` —
third-party schedulers keep working while they migrate.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Union

__all__ = ["PlanTerms", "RunPlan", "SplitPlan", "DeferPlan", "Plan",
           "as_plan", "plan_to_json", "plan_from_json"]


@dataclass(frozen=True)
class PlanTerms:
    """Priced components behind a placement decision (Eq. 1 operands):
    request energy and runtime on the chosen pool(s) — for a split, the
    prefill + migration + decode sum — the queue/defer wait the scheduler
    priced in, and the scalar cost it minimized."""
    energy_j: float
    runtime_s: float
    wait_s: float = 0.0
    cost: float = 0.0


@dataclass(frozen=True)
class RunPlan:
    """Run both phases on one pool (referenced by system name)."""
    pool: str
    terms: Optional[PlanTerms] = None

    @property
    def kind(self) -> str:
        return "run"


@dataclass(frozen=True)
class SplitPlan:
    """Disaggregated plan: prefill on ``pool_prefill``, migrate the KV
    prefix (``mig_bytes`` as priced at dispatch time), decode on
    ``pool_decode``. Engines re-derive the migration charge at handoff
    through the same ``CostModel.migration_terms`` seam, so ``mig_bytes``
    here is the plan's priced estimate, not the booked value."""
    pool_prefill: str
    pool_decode: str
    mig_bytes: float = 0.0
    terms: Optional[PlanTerms] = None

    @property
    def kind(self) -> str:
        return "split"


@dataclass(frozen=True)
class DeferPlan:
    """Admit ``inner`` at clock ``until_s`` instead of now (temporal
    arbitrage: batch tiers wait for a low-carbon / low-price window).
    ``inner`` must be a ``RunPlan`` or ``SplitPlan`` — deferrals do not
    nest (one admission clock per request)."""
    until_s: float
    inner: Union[RunPlan, SplitPlan]

    def __post_init__(self):
        if not isinstance(self.inner, (RunPlan, SplitPlan)):
            raise TypeError("DeferPlan.inner must be a RunPlan or SplitPlan, "
                            f"got {type(self.inner).__name__}")

    @property
    def kind(self) -> str:
        return "defer"

    @property
    def terms(self) -> Optional[PlanTerms]:
        return self.inner.terms


Plan = Union[RunPlan, SplitPlan, DeferPlan]

_LEGACY_WARNING = (
    "schedulers returning a bare SystemProfile (or an (a, b) profile tuple) "
    "from dispatch are deprecated; return a core.plan RunPlan/SplitPlan — "
    "the legacy encoding is coerced for one release")


def as_plan(target, *, warn: bool = True) -> Plan:
    """Coerce a scheduler ``dispatch`` return into the plan IR.

    Plans pass through untouched. A bare ``SystemProfile``-like (anything
    with a ``.name``) becomes ``RunPlan(name)``; an ``(a, b)`` tuple of two
    profile-likes becomes ``SplitPlan(a.name, b.name)``. Legacy encodings
    warn (``DeprecationWarning``) unless ``warn=False``."""
    if isinstance(target, (RunPlan, SplitPlan, DeferPlan)):
        return target
    if isinstance(target, tuple) and len(target) == 2 \
            and all(hasattr(x, "name") for x in target):
        if warn:
            warnings.warn(_LEGACY_WARNING, DeprecationWarning, stacklevel=3)
        return SplitPlan(target[0].name, target[1].name)
    name = getattr(target, "name", None)
    if isinstance(name, str):
        if warn:
            warnings.warn(_LEGACY_WARNING, DeprecationWarning, stacklevel=3)
        return RunPlan(name)
    raise TypeError(f"cannot interpret {target!r} as a placement plan")


# ----------------------------------------------------------------- JSON (de)ser
def _terms_to_json(terms: Optional[PlanTerms]) -> Optional[Dict]:
    if terms is None:
        return None
    return {"energy_j": terms.energy_j, "runtime_s": terms.runtime_s,
            "wait_s": terms.wait_s, "cost": terms.cost}


def _terms_from_json(d: Optional[Dict]) -> Optional[PlanTerms]:
    if d is None:
        return None
    return PlanTerms(energy_j=d["energy_j"], runtime_s=d["runtime_s"],
                     wait_s=d.get("wait_s", 0.0), cost=d.get("cost", 0.0))


def plan_to_json(plan: Plan) -> Dict:
    """Kind-tagged plain-dict form of a plan (inverse: ``plan_from_json``)."""
    if isinstance(plan, RunPlan):
        return {"kind": "run", "pool": plan.pool,
                "terms": _terms_to_json(plan.terms)}
    if isinstance(plan, SplitPlan):
        return {"kind": "split", "pool_prefill": plan.pool_prefill,
                "pool_decode": plan.pool_decode, "mig_bytes": plan.mig_bytes,
                "terms": _terms_to_json(plan.terms)}
    if isinstance(plan, DeferPlan):
        return {"kind": "defer", "until_s": plan.until_s,
                "inner": plan_to_json(plan.inner)}
    raise TypeError(f"not a plan: {plan!r}")


def plan_from_json(d: Dict) -> Plan:
    kind = d.get("kind")
    if kind == "run":
        return RunPlan(d["pool"], terms=_terms_from_json(d.get("terms")))
    if kind == "split":
        return SplitPlan(d["pool_prefill"], d["pool_decode"],
                         mig_bytes=d.get("mig_bytes", 0.0),
                         terms=_terms_from_json(d.get("terms")))
    if kind == "defer":
        return DeferPlan(d["until_s"], plan_from_json(d["inner"]))
    raise ValueError(f"unknown plan kind {kind!r}")
