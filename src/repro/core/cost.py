"""The paper's cost function (Eq. 1):

    U(m, n, s) = lambda * E(m, n, s) + (1 - lambda) * R(m, n, s)

lambda in [0, 1] trades energy (J) against runtime (s). As in the paper the
two terms carry different units; optional normalizers express both relative
to a reference system so lambda is dimensionless in practice.

This module is now a thin deprecation shim over the unified pricing layer
(``core.pricing.CostModel``): the free functions price through a shared
per-config analytic ``CostModel``, so their values are bit-for-bit what they
always were. New code should take a ``CostModel`` directly.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.pricing import CostParams, default_cost_model
from repro.core.systems import SystemProfile

__all__ = ["CostParams", "cost", "normalized_cost_params"]


def cost(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
         cp: CostParams = CostParams(), batch: int = 1) -> float:
    """Deprecated shim: ``CostModel(cfg, cp=cp).cost(m, n, s)``."""
    model = default_cost_model(cfg)
    e = model.energy(m, n, s, batch) / cp.e_norm
    r = model.runtime(m, n, s, batch) / cp.r_norm
    return cp.lam * e + (1.0 - cp.lam) * r


def normalized_cost_params(cfg: ModelConfig, ref: SystemProfile,
                           lam: float, m: int = 128, n: int = 128) -> CostParams:
    """CostParams normalized so E and R are O(1) on the reference system at a
    representative query size — makes lambda behave as a true preference.
    Deprecated shim: see ``CostModel.normalized``."""
    model = default_cost_model(cfg)
    return CostParams(lam=lam,
                      e_norm=max(model.energy(m, n, ref), 1e-9),
                      r_norm=max(model.runtime(m, n, ref), 1e-9))
