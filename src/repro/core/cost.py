"""The paper's cost function (Eq. 1):

    U(m, n, s) = lambda * E(m, n, s) + (1 - lambda) * R(m, n, s)

lambda in [0, 1] trades energy (J) against runtime (s). As in the paper the
two terms carry different units; optional normalizers express both relative
to a reference system so lambda is dimensionless in practice.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.energy import energy
from repro.core.perf_model import runtime
from repro.core.systems import SystemProfile


@dataclass(frozen=True)
class CostParams:
    lam: float = 1.0                     # 1.0 = pure energy (paper's Section 6)
    e_norm: float = 1.0                  # J scale
    r_norm: float = 1.0                  # s scale


def cost(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
         cp: CostParams = CostParams(), batch: int = 1) -> float:
    e = energy(cfg, m, n, s, batch) / cp.e_norm
    r = runtime(cfg, m, n, s, batch) / cp.r_norm
    return cp.lam * e + (1.0 - cp.lam) * r


def normalized_cost_params(cfg: ModelConfig, ref: SystemProfile,
                           lam: float, m: int = 128, n: int = 128) -> CostParams:
    """CostParams normalized so E and R are O(1) on the reference system at a
    representative query size — makes lambda behave as a true preference."""
    return CostParams(lam=lam,
                      e_norm=max(energy(cfg, m, n, ref), 1e-9),
                      r_norm=max(runtime(cfg, m, n, ref), 1e-9))
