"""Region-lifted control plane: named fleets under one global dispatcher.

The paper optimizes placement *within* one heterogeneous cluster; its
motivation (grid carbon intensity, electricity price) is a property of the
*region* the cluster sits in. This module lifts the fleet simulator one
level: a ``Region`` is a named set of pools plus the region's carbon
intensity trace, electricity price trace, and inter-region link.
``simulate_fleet(cfg, queries, regions=[...], scheduler=...)`` flattens the
regions into one pool dict (pool and system names become
``<region>/<name>``) and runs the existing engines unchanged — so fleet
accounting stays idle-inclusive across every region's pools.

``GlobalDispatcher`` is the minimal cross-region policy the plan IR makes
expressible: interactive queries route spatially to the system with the
lowest carbon (optionally price-weighted) cost *right now*; batch-tier
queries (the paper's own "overnight batch" use case) are deferred —
``DeferPlan`` — into the earliest green window across all regions and run
on the system that will be cheapest when that window opens.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.carbon import CarbonProfile, next_green_window
from repro.core.fleet import PoolSpec
from repro.core.plan import DeferPlan, Plan, RunPlan
from repro.core.pricing import CostModel, CostParams
from repro.core.scheduler import FleetState, Scheduler
from repro.core.systems import SystemProfile
from repro.core.workload import Query

__all__ = ["RegionLink", "PriceProfile", "Region", "flatten_regions",
           "GlobalDispatcher"]


@dataclass(frozen=True)
class RegionLink:
    """Wide-area link out of a region (KV/state migration pricing input)."""
    bw_gbps: float = 100.0


@dataclass(frozen=True)
class PriceProfile:
    """Sinusoidal daily electricity price (USD/kWh), overnight-trough
    shaped — the temporal twin of ``CarbonProfile``."""
    mean_usd_per_kwh: float = 0.10
    swing: float = 0.30              # peak-to-mean fractional swing
    trough_hour: float = 3.0         # overnight demand trough

    def price(self, t_s: float) -> float:
        hours = (t_s / 3600.0) % 24.0
        phase = 2.0 * math.pi * (hours - self.trough_hour) / 24.0
        return self.mean_usd_per_kwh * (1.0 - self.swing * math.cos(phase))


@dataclass(frozen=True)
class Region:
    """A named fleet plus the traces that make its location matter."""
    name: str
    pools: Mapping[str, PoolSpec]
    carbon: CarbonProfile = CarbonProfile()
    price: PriceProfile = PriceProfile()
    link: RegionLink = RegionLink()


def flatten_regions(regions: Sequence[Region]) -> Dict[str, PoolSpec]:
    """One flat pool dict for the single-fleet engines: pool keys AND the
    embedded system names become ``<region>/<name>`` (system names must stay
    unique fleet-wide — dispatch maps systems back to pools by name)."""
    flat: Dict[str, PoolSpec] = {}
    seen = set()
    for reg in regions:
        if reg.name in seen:
            raise ValueError(f"duplicate region name {reg.name!r}")
        seen.add(reg.name)
        for pname, spec in reg.pools.items():
            flat[f"{reg.name}/{pname}"] = replace(
                spec, system=replace(spec.system,
                                     name=f"{reg.name}/{spec.system.name}"))
    return flat


class GlobalDispatcher(Scheduler):
    """Cross-region routing + temporal deferral over a flattened fleet.

    Interactive queries (``n <= defer_out_threshold``) run now on the
    globally cheapest system, where "cheap" is the region-local carbon cost
    of the query's energy (plus ``price_weight`` x its electricity cost).
    Batch-tier queries are deferred into the earliest green window across
    all regions — the window where some region's intensity first dips below
    ``defer_below`` x its own mean — and planned onto the best system of
    that window's region, wrapped in a ``DeferPlan`` so the engines hold
    admission (idle-inclusive fleet accounting still charges every pool's
    idle floor while the work waits).
    """

    def __init__(self, cfg: ModelConfig, regions: Sequence[Region], *,
                 defer_out_threshold: int = 256, defer_below: float = 0.85,
                 max_defer_s: float = 24 * 3600.0, price_weight: float = 0.0,
                 cp: CostParams = CostParams(),
                 model: Optional[CostModel] = None):
        self.regions = list(regions)
        flat = flatten_regions(self.regions)
        systems = [spec.system for spec in flat.values()]
        super().__init__(cfg, systems, cp, model=model)
        self._region_of: Dict[str, Region] = {}
        self._region_systems: Dict[str, List[SystemProfile]] = {}
        by_flat_name = {s.name: s for s in systems}
        for reg in self.regions:
            regional = [by_flat_name[f"{reg.name}/{spec.system.name}"]
                        for spec in reg.pools.values()]
            self._region_systems[reg.name] = regional
            for s in regional:
                self._region_of[s.name] = reg
        self.defer_out_threshold = defer_out_threshold
        self.defer_below = defer_below
        self.max_defer_s = max_defer_s
        self.price_weight = price_weight

    # ------------------------------------------------------------- scoring
    def _score(self, q: Query, s: SystemProfile, t_exec_s: float) -> float:
        """Region-local cost of running ``q`` on ``s`` at ``t_exec_s``:
        grams of CO2, optionally plus weighted electricity dollars."""
        reg = self._region_of[s.name]
        e_j = self.model.energy(q.m, q.n, s)
        score = reg.carbon.grams(e_j, t_exec_s)
        if self.price_weight:
            score += self.price_weight * (e_j / 3.6e6) \
                * reg.price.price(t_exec_s)
        return score

    def _deferrable(self, q: Query) -> bool:
        return q.n > self.defer_out_threshold

    def _green_windows(self, now: float) -> List[Tuple[float, Region]]:
        """Per-region ``(window_s, region)`` rows: the earliest green window
        each region opens after ``now``."""
        return [(next_green_window(reg.carbon, now, below=self.defer_below,
                                   max_defer_s=self.max_defer_s), reg)
                for reg in self.regions]

    # ------------------------------------------------------------ dispatch
    def choose(self, q: Query) -> SystemProfile:
        """Workload-only decision: run-now spatial argmin at the query's own
        arrival clock."""
        return min(self.systems,
                   key=lambda s: self._score(q, s, q.arrival_s))

    def dispatch(self, q: Query, fleet: Optional[FleetState] = None) -> Plan:
        now = fleet.time_s if fleet is not None else q.arrival_s
        if self._deferrable(q):
            # candidate = each region's best system at that region's own
            # green window; judged by actual execution-time score (hardware
            # joules x window intensity), NOT window intensity alone — a
            # dirtier grid with far more efficient hardware can still win.
            # Ties break toward the earlier window.
            best = None
            for w, reg in self._green_windows(now):
                s = min(self._region_systems[reg.name],
                        key=lambda x: self._score(q, x, w))
                key = (self._score(q, s, w), w)
                if best is None or key < best[0]:
                    best = (key, w, s)
            _, w, s = best
            inner = RunPlan(s.name, self._price_terms(q, s, wait_s=w - now))
            if w > now:
                return DeferPlan(until_s=w, inner=inner)
            return inner
        s = min(self.systems, key=lambda x: self._score(q, x, now))
        return RunPlan(s.name, self._price_terms(q, s))
