"""Analytic roofline performance model: R(m, n, s) per architecture.

The paper measures R and E empirically per (model, system); we derive them
from the architecture config and the system profile so the scheduler can
price *any* of the 10 assigned architectures on *any* system. The model is
the standard two-phase LLM-inference roofline:

  prefill:  t = max(FLOPs / peak_flops, weight+activation bytes / hbm_bw)
  decode:   per-token t at context c, memory term dominated by weight
            streaming (amortized over batch) + KV/state reads.

The same FLOPs/bytes functions feed the §Roofline analysis — the dry-run's
compiled cost_analysis validates them (see benchmarks/roofline.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

from repro.configs.base import ModelConfig
from repro.core.systems import SystemProfile

BYTES_PER_PARAM = 2.0   # bf16 weights
BYTES_PER_ACT = 2.0


@dataclass(frozen=True)
class QueryPhases:
    """Per-phase seconds and utilization for one query."""
    t_prefill: float
    t_decode: float
    t_overhead: float
    util_prefill: float
    util_decode: float

    @property
    def total(self) -> float:
        return self.t_prefill + self.t_decode + self.t_overhead


# --------------------------------------------------------------------- FLOPs/bytes
def flops_prefill(cfg: ModelConfig, m: int) -> float:
    """Forward FLOPs to process m prompt tokens."""
    n_act = cfg.active_param_count()
    f = 2.0 * n_act * m
    # causal attention: 2 matmuls (QK^T, PV) x 2 FLOPs, halved by causal mask
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        layers = cfg.num_layers if cfg.family != "audio" else cfg.num_layers + cfg.encoder_layers
        eff_ctx = min(m, cfg.sliding_window) if cfg.sliding_window else m
        f += 2.0 * layers * m * eff_ctx * d_attn
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        # SSD state algebra: ~ 6 * d_inner * N per token per layer
        f += 6.0 * cfg.num_layers * m * cfg.d_inner * s.state_dim
    return f


def flops_decode_token(cfg: ModelConfig, ctx: int) -> float:
    """FLOPs to emit one token at context length ctx."""
    n_act = cfg.active_param_count()
    f = 2.0 * n_act
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        n_attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
        f += 4.0 * n_attn_layers * eff_ctx * d_attn
    if cfg.family in ("ssm", "hybrid"):
        f += 6.0 * cfg.num_layers * cfg.d_inner * cfg.ssm.state_dim
    return f


def kv_bytes_per_token_ctx(cfg: ModelConfig, ctx: int) -> float:
    """KV-cache bytes read to emit one token at context ctx."""
    if cfg.is_attention_free:
        s = cfg.ssm
        return cfg.num_layers * cfg.ssm_heads * s.head_dim * s.state_dim * 4.0
    hd = cfg.resolved_head_dim
    eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    n_attn_layers = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
        ssm_bytes = cfg.num_layers * cfg.ssm_heads * cfg.ssm.head_dim * cfg.ssm.state_dim * 4.0
        return 2.0 * n_attn_layers * cfg.num_kv_heads * hd * eff_ctx * BYTES_PER_ACT + ssm_bytes
    return 2.0 * n_attn_layers * cfg.num_kv_heads * hd * eff_ctx * BYTES_PER_ACT


def weight_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BYTES_PER_PARAM


# --------------------------------------------------------------------- time model
def query_phases(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
                 batch: int = 1) -> QueryPhases:
    """Roofline time for one query of m input / n output tokens on system s,
    amortizing weight streaming over `batch` concurrent queries."""
    peak = s.instance_peak_flops * s.compute_eff
    bw = s.instance_hbm_bw * s.mem_eff
    wb = weight_bytes(cfg)

    # ---- prefill ----
    f_pf = flops_prefill(cfg, m)
    b_pf = wb / batch + 2.0 * m * cfg.d_model * BYTES_PER_ACT * cfg.num_layers
    t_pf_compute = f_pf / peak
    t_pf_mem = b_pf / bw
    t_pf = max(t_pf_compute, t_pf_mem) * s.degradation(m)
    util_pf = min(1.0, t_pf_compute / max(t_pf, 1e-12))

    # ---- decode: integrate per-token time at mid-context (trapezoid approx) ----
    t_dec = 0.0
    util_dec = 0.0
    if n > 0:
        ctx_mid = m + n / 2.0
        f_tok = flops_decode_token(cfg, int(ctx_mid))
        b_tok = wb / batch + kv_bytes_per_token_ctx(cfg, int(ctx_mid))
        t_tok_compute = f_tok / peak
        t_tok_mem = b_tok / bw
        t_tok = max(t_tok_compute, t_tok_mem) * s.degradation(ctx_mid)
        t_dec = n * t_tok
        util_dec = min(1.0, t_tok_compute / max(t_tok, 1e-12))

    return QueryPhases(t_prefill=t_pf, t_decode=t_dec, t_overhead=s.overhead_s,
                       util_prefill=util_pf, util_decode=util_dec)


def runtime(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
            batch: int = 1) -> float:
    """R(m, n, s) in seconds (Eq. 1's runtime term)."""
    return query_phases(cfg, m, n, s, batch).total


def throughput(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
               batch: int = 1) -> float:
    """tokens/s processed+generated for one query."""
    return (m + n) / runtime(cfg, m, n, s, batch)
