"""Analytic roofline performance model: R(m, n, s) per architecture.

The paper measures R and E empirically per (model, system); we derive them
from the architecture config and the system profile so the scheduler can
price *any* of the 10 assigned architectures on *any* system. The model is
the standard two-phase LLM-inference roofline:

  prefill:  t = max(FLOPs / peak_flops, weight+activation bytes / hbm_bw)
  decode:   per-token t at context c, memory term dominated by weight
            streaming (amortized over batch) + KV/state reads.

The same FLOPs/bytes functions feed the §Roofline analysis — the dry-run's
compiled cost_analysis validates them (see benchmarks/roofline.py).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.systems import SystemProfile

BYTES_PER_PARAM = 2.0   # bf16 weights
BYTES_PER_ACT = 2.0


@dataclass(frozen=True)
class QueryPhases:
    """Per-phase seconds and utilization for one query."""
    t_prefill: float
    t_decode: float
    t_overhead: float
    util_prefill: float
    util_decode: float

    @property
    def total(self) -> float:
        return self.t_prefill + self.t_decode + self.t_overhead


# --------------------------------------------------------------------- FLOPs/bytes
def flops_prefill(cfg: ModelConfig, m: int) -> float:
    """Forward FLOPs to process m prompt tokens."""
    n_act = cfg.active_param_count()
    f = 2.0 * n_act * m
    # causal attention: 2 matmuls (QK^T, PV) x 2 FLOPs, halved by causal mask
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        layers = cfg.num_layers if cfg.family != "audio" else cfg.num_layers + cfg.encoder_layers
        eff_ctx = min(m, cfg.sliding_window) if cfg.sliding_window else m
        f += 2.0 * layers * m * eff_ctx * d_attn
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        # SSD state algebra: ~ 6 * d_inner * N per token per layer
        f += 6.0 * cfg.num_layers * m * cfg.d_inner * s.state_dim
    return f


def flops_decode_token(cfg: ModelConfig, ctx: int) -> float:
    """FLOPs to emit one token at context length ctx."""
    n_act = cfg.active_param_count()
    f = 2.0 * n_act
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        n_attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
        f += 4.0 * n_attn_layers * eff_ctx * d_attn
    if cfg.family in ("ssm", "hybrid"):
        f += 6.0 * cfg.num_layers * cfg.d_inner * cfg.ssm.state_dim
    return f


def kv_bytes_per_token_ctx(cfg: ModelConfig, ctx: int) -> float:
    """KV-cache bytes read to emit one token at context ctx."""
    if cfg.is_attention_free:
        s = cfg.ssm
        return cfg.num_layers * cfg.ssm_heads * s.head_dim * s.state_dim * 4.0
    hd = cfg.resolved_head_dim
    eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    n_attn_layers = cfg.num_layers
    if cfg.family == "hybrid":
        n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
        ssm_bytes = cfg.num_layers * cfg.ssm_heads * cfg.ssm.head_dim * cfg.ssm.state_dim * 4.0
        return 2.0 * n_attn_layers * cfg.num_kv_heads * hd * eff_ctx * BYTES_PER_ACT + ssm_bytes
    return 2.0 * n_attn_layers * cfg.num_kv_heads * hd * eff_ctx * BYTES_PER_ACT


def weight_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * BYTES_PER_PARAM


# --------------------------------------------------------------------- time model
def query_phases(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
                 batch: int = 1) -> QueryPhases:
    """Roofline time for one query of m input / n output tokens on system s,
    amortizing weight streaming over `batch` concurrent queries."""
    peak = s.instance_peak_flops * s.compute_eff
    bw = s.instance_hbm_bw * s.mem_eff
    wb = weight_bytes(cfg)

    # ---- prefill ----
    f_pf = flops_prefill(cfg, m)
    b_pf = wb / batch + 2.0 * m * cfg.d_model * BYTES_PER_ACT * cfg.num_layers
    t_pf_compute = f_pf / peak
    t_pf_mem = b_pf / bw
    t_pf = max(t_pf_compute, t_pf_mem) * s.degradation(m)
    util_pf = min(1.0, t_pf_compute / max(t_pf, 1e-12))

    # ---- decode: integrate per-token time at mid-context (trapezoid approx) ----
    t_dec = 0.0
    util_dec = 0.0
    if n > 0:
        ctx_mid = m + n / 2.0
        f_tok = flops_decode_token(cfg, int(ctx_mid))
        b_tok = wb / batch + kv_bytes_per_token_ctx(cfg, int(ctx_mid))
        t_tok_compute = f_tok / peak
        t_tok_mem = b_tok / bw
        t_tok = max(t_tok_compute, t_tok_mem) * s.degradation(ctx_mid)
        t_dec = n * t_tok
        util_dec = min(1.0, t_tok_compute / max(t_tok, 1e-12))

    return QueryPhases(t_prefill=t_pf, t_decode=t_dec, t_overhead=s.overhead_s,
                       util_prefill=util_pf, util_decode=util_dec)


@dataclass(frozen=True)
class BatchPhases:
    """Vectorized `QueryPhases`: one float64 array per field, aligned by index.

    Produced by `query_phases_batch`; every element is bit-for-bit identical to
    the corresponding scalar `query_phases` result (same operand values, same
    operation order, same IEEE-754 double ops).
    """
    t_prefill: np.ndarray
    t_decode: np.ndarray
    t_overhead: np.ndarray
    util_prefill: np.ndarray
    util_decode: np.ndarray

    @property
    def total(self) -> np.ndarray:
        # same association as QueryPhases.total: (t_prefill + t_decode) + t_overhead
        return (self.t_prefill + self.t_decode) + self.t_overhead


def query_phases_batch(cfg: ModelConfig, m, n, s: SystemProfile,
                       batch: int = 1) -> BatchPhases:
    """Vectorized `query_phases` over arrays of (m, n) token counts.

    Elementwise bit-identical to the scalar path: every expression below
    transcribes the scalar code with the same left-to-right operand order, so
    each IEEE-754 op sees the same operands in the same association. The only
    rewrites are `int(x)` -> `np.trunc(x)` (equal for the non-negative context
    lengths here) and `min`/`max` -> `np.minimum`/`np.maximum`.
    """
    m_arr = np.asarray(m, dtype=np.float64)
    n_arr = np.asarray(n, dtype=np.float64)
    peak = s.instance_peak_flops * s.compute_eff
    bw = s.instance_hbm_bw * s.mem_eff
    wb = weight_bytes(cfg)
    n_act = cfg.active_param_count()

    def _eff_ctx(ctx: np.ndarray) -> np.ndarray:
        return np.minimum(ctx, cfg.sliding_window) if cfg.sliding_window else ctx

    # ---- prefill (mirrors flops_prefill) ----
    f_pf = 2.0 * n_act * m_arr
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        layers = cfg.num_layers if cfg.family != "audio" else cfg.num_layers + cfg.encoder_layers
        f_pf = f_pf + 2.0 * layers * m_arr * _eff_ctx(m_arr) * d_attn
    if cfg.family in ("ssm", "hybrid"):
        ssm = cfg.ssm
        f_pf = f_pf + 6.0 * cfg.num_layers * m_arr * cfg.d_inner * ssm.state_dim
    b_pf = wb / batch + 2.0 * m_arr * cfg.d_model * BYTES_PER_ACT * cfg.num_layers
    t_pf_compute = f_pf / peak
    t_pf_mem = b_pf / bw
    if s.sat_ctx is None:
        degr_pf = np.ones_like(m_arr)
    else:
        degr_pf = 1.0 + m_arr / s.sat_ctx
    t_pf = np.maximum(t_pf_compute, t_pf_mem) * degr_pf
    util_pf = np.minimum(1.0, t_pf_compute / np.maximum(t_pf, 1e-12))

    # ---- decode at mid-context (mirrors flops_decode_token / kv_bytes_per_token_ctx) ----
    ctx_mid = m_arr + n_arr / 2.0
    ctx_i = np.trunc(ctx_mid)          # == float(int(ctx_mid)) elementwise
    f_tok = np.full_like(m_arr, 2.0 * n_act)
    if not cfg.is_attention_free:
        d_attn = cfg.num_heads * cfg.resolved_head_dim
        n_attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
        f_tok = f_tok + 4.0 * n_attn_layers * _eff_ctx(ctx_i) * d_attn
    if cfg.family in ("ssm", "hybrid"):
        f_tok = f_tok + 6.0 * cfg.num_layers * cfg.d_inner * cfg.ssm.state_dim
    if cfg.is_attention_free:
        ssm = cfg.ssm
        kv_tok = np.full_like(
            m_arr, cfg.num_layers * cfg.ssm_heads * ssm.head_dim * ssm.state_dim * 4.0)
    else:
        hd = cfg.resolved_head_dim
        n_attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            n_attn_layers = max(1, cfg.num_layers // max(1, cfg.hybrid_attn_every))
            ssm_bytes = cfg.num_layers * cfg.ssm_heads * cfg.ssm.head_dim * cfg.ssm.state_dim * 4.0
            kv_tok = 2.0 * n_attn_layers * cfg.num_kv_heads * hd * _eff_ctx(ctx_i) * BYTES_PER_ACT + ssm_bytes
        else:
            kv_tok = 2.0 * n_attn_layers * cfg.num_kv_heads * hd * _eff_ctx(ctx_i) * BYTES_PER_ACT
    b_tok = wb / batch + kv_tok
    t_tok_compute = f_tok / peak
    t_tok_mem = b_tok / bw
    if s.sat_ctx is None:
        degr_tok = np.ones_like(m_arr)
    else:
        degr_tok = 1.0 + ctx_mid / s.sat_ctx   # float mid-context, as in the scalar path
    t_tok = np.maximum(t_tok_compute, t_tok_mem) * degr_tok
    has_decode = n_arr > 0
    t_dec = np.where(has_decode, n_arr * t_tok, 0.0)
    util_dec = np.where(
        has_decode, np.minimum(1.0, t_tok_compute / np.maximum(t_tok, 1e-12)), 0.0)

    return BatchPhases(t_prefill=t_pf, t_decode=t_dec,
                       t_overhead=np.full_like(m_arr, s.overhead_s),
                       util_prefill=util_pf, util_decode=util_dec)


def runtime(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
            batch: int = 1) -> float:
    """R(m, n, s) in seconds (Eq. 1's runtime term)."""
    return query_phases(cfg, m, n, s, batch).total


def throughput(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
               batch: int = 1) -> float:
    """tokens/s processed+generated for one query."""
    return (m + n) / runtime(cfg, m, n, s, batch)
