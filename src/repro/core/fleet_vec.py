"""Vectorized (struct-of-arrays) fleet-sim engine.

``core.fleet`` keeps the reference one-event-at-a-time loop with per-request
``RequestRecord``/``_Resident`` objects; this module is the same discrete-event
system with the hot state transposed into preallocated numpy arrays:

  * request state (arrival/start/decode/done times, token counts, KV blocks,
    energy) lives in rid-indexed arrays, priced ONCE per pool up front via
    ``CostModel.price_batch`` (Eq. 1 over arrays, bypassing the per-call
    LRU memo);
  * instance state (power-machine state, wake deadlines, linger clocks,
    busy slot-seconds, decode-group size) is one array per field per pool;
  * residents are compact per-instance slot rows, so pool-wide settlement
    (``_settle``) advances every busy instance in one batched numpy pass
    instead of a Python loop over instances and residents.

Event *semantics* are unchanged: the same heap orders the same epochs with
the same sequence numbers, FIFO/SJF queue keys, KV-block admission,
power-state transitions and autoscaler CONTROL ticks are transcribed
operation-for-operation, and every float expression keeps the reference
engine's operand order and association — so results are bit-for-bit equal
to ``FleetSimulator`` (the equivalence gate in tests/test_fleet_vec.py runs
both engines across seeds x disciplines x {autoscaler, paged blocks} and
asserts identical ``summary()`` dicts and per-request records).

Use via ``simulate_fleet(..., engine="vectorized")`` or the benchmarks'
``--engine`` flag. Speedup at fleet scale (1M requests, 1k instances) is
tracked in BENCH_fleet.json (benchmarks/fleet_bench.py).
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fleet import (ADMIT, ARRIVAL, AWAKE, CONTROL, INSTANCE,
                              MIGRATE, OFF, ROLE_DEC, ROLE_FULL, ROLE_PF,
                              SLEEP, WAKING, AutoscalerPolicy, FleetSimResult,
                              PoolResult, PoolSpec)
from repro.core.plan import Plan, RunPlan
from repro.core.pricing import AnalyticOracle, CostModel
from repro.core.scheduler import FleetState, PoolSnapshot, Scheduler
from repro.core.settlement import migration_charge, plan_legs, resolve_plan
from repro.core.workload import Query

# integer power-machine state codes (array-friendly); <= _WAKING means
# "provisioned" (awake_like in the reference engine)
_AWAKE, _WAKING, _SLEEP, _OFF = 0, 1, 2, 3
_STATE_NAME = {_AWAKE: AWAKE, _WAKING: WAKING, _SLEEP: SLEEP, _OFF: OFF}
_STATE_CODE = {v: k for k, v in _STATE_NAME.items()}

# sentinel for masked argmin over instance loads
_HUGE = np.iinfo(np.int64).max


class _VecPool:
    """Struct-of-arrays twin of ``fleet._PoolRuntime`` + its instances."""

    def __init__(self, idx: int, name: str, spec: PoolSpec):
        self.idx = idx
        self.name = name
        self.spec = spec
        self.power_managed = bool(np.isfinite(spec.linger_s))
        self.linger_s = float(spec.linger_s)
        self.linger_finite = math.isfinite(self.linger_s)
        self.target_awake: Optional[int] = None
        n_inst, slots = spec.instances, spec.slots
        self.n_inst = n_inst
        self.slots = slots
        # ---- per-instance arrays ----
        self.state = np.zeros(n_inst, np.int8)           # all start AWAKE
        self.wake_done_s = np.zeros(n_inst)
        self.empty_since_s = np.zeros(n_inst)
        self.last_t_s = np.zeros(n_inst)
        self.busy_slot_s = np.zeros(n_inst)
        self.version = [0] * n_inst        # Python ints: hot scalar reads
        self.n_res = np.zeros(n_inst, np.int64)
        self.blocks_in_use = np.zeros(n_inst, np.int64)
        self.wake_energy_j = np.zeros(n_inst)
        self.n_wakes = np.zeros(n_inst, np.int64)
        self.b_cached = np.zeros(n_inst, np.int64)       # decode group at refresh
        self.timeline: List[List[Tuple[float, str]]] = \
            [[(0.0, AWAKE)] for _ in range(n_inst)]
        # ---- per-resident slot rows (compact: columns 0..n_res-1 in use) ----
        self.r_rid = np.full((n_inst, slots), -1, np.int64)
        self.r_rem = np.zeros((n_inst, slots))           # remaining out tokens
        self.r_pf_end_s = np.zeros((n_inst, slots))      # prefill-done epoch
        self.r_t_tok = np.zeros((n_inst, slots))         # s/token at r_b
        self.r_p_w = np.zeros((n_inst, slots))           # decode power at r_b
        self.r_b = np.zeros((n_inst, slots), np.int64)   # occupancy of cache
        self.r_blocks = np.zeros((n_inst, slots), np.int64)
        self.r_role = np.zeros((n_inst, slots), np.int8)  # ROLE_FULL/_PF/_DEC
        # ---- queue + counters ----
        # (key, seq, rid, svc, role)
        self.queue: List[Tuple[float, int, int, float, int]] = []
        self.queued_service_s = 0.0
        self.busy = 0                                    # total residents
        # O(1) power-state census, maintained at every transition; residents
        # only ever live on AWAKE instances, so the pool's free awake slots
        # are ``n_awake * slots - busy`` without scanning the state array
        self.n_awake = n_inst
        self.n_waking = 0
        self.result = PoolResult()
        # ---- rid-indexed precomputed pricing (price_batch, filled in run) ----
        self.svc_s: Optional[np.ndarray] = None          # batch=1 runtime
        self.pf_s: Optional[np.ndarray] = None           # t_prefill
        self.ov_s: Optional[np.ndarray] = None           # t_overhead
        self.dec_s: Optional[np.ndarray] = None          # t_decode (DEC svc)
        self.svc_pf_s: Optional[np.ndarray] = None       # overhead + prefill
        self.prefill_power_w: Optional[np.ndarray] = None
        self.blocks_need: Optional[np.ndarray] = None
        self.blocks_need_pf: Optional[np.ndarray] = None  # prefill-only need
        # lazy per-occupancy decode tables: batch size b -> rid-indexed
        # (seconds/token, decode utilization) arrays, one price_batch per b
        self.t_tok_by_b: Dict[int, np.ndarray] = {}
        self.p_w_by_b: Dict[int, np.ndarray] = {}


class VectorizedFleetSimulator:
    """Drop-in, bit-for-bit equivalent of ``fleet.FleetSimulator`` with
    numpy-batched event settlement. Same constructor contract; single-shot."""

    def __init__(self, cfg: ModelConfig, pools: Dict[str, PoolSpec],
                 scheduler: Scheduler, *, queue_discipline: str = "fifo",
                 model: Optional[CostModel] = None,
                 autoscaler: Union[AutoscalerPolicy,
                                   Dict[str, AutoscalerPolicy], None] = None):
        if queue_discipline not in ("fifo", "sjf"):
            raise ValueError(f"unknown queue discipline {queue_discipline!r}")
        self.cfg = cfg
        self.model = model if model is not None \
            else getattr(scheduler, "model", None) or CostModel(cfg, AnalyticOracle())
        self.pools: Dict[str, _VecPool] = {
            n: _VecPool(i, n, spec) for i, (n, spec) in enumerate(pools.items())}
        self._pool_list = list(self.pools.values())
        if autoscaler is None:
            self._autoscalers: Dict[str, AutoscalerPolicy] = {}
        elif isinstance(autoscaler, dict):
            unknown = set(autoscaler) - set(pools)
            if unknown:
                raise KeyError(f"autoscaler for unknown pool(s) {sorted(unknown)}")
            self._autoscalers = dict(autoscaler)
        else:
            self._autoscalers = {n: autoscaler for n in pools}
        for name in self._autoscalers:
            self.pools[name].power_managed = True
        self.scheduler = scheduler
        self.queue_discipline = queue_discipline
        self._by_system = {spec.system.name: n for n, spec in pools.items()}
        if len(self._by_system) != len(pools):
            raise ValueError("pools must use distinct SystemProfile names: "
                             "dispatch maps a chosen system back to its pool "
                             "by name")
        self._ran = False
        self.events_processed = 0    # heap pops + arrivals (incl. stale events)

    # ------------------------------------------------------------------ run
    def run(self, queries: Sequence[Query],
            policy_name: Optional[str] = None) -> FleetSimResult:
        if self._ran:
            raise RuntimeError("VectorizedFleetSimulator is single-shot "
                               "(instances hold clock state); build a new "
                               "one per run")
        self._ran = True
        qs = sorted(queries, key=lambda q: q.arrival_s)
        n_req = len(qs)
        self._queries = qs
        self.m_tok = np.fromiter((q.m for q in qs), np.int64, n_req)
        self.n_tok = np.fromiter((q.n for q in qs), np.int64, n_req)
        arrival_s = np.fromiter((q.arrival_s for q in qs), np.float64, n_req)
        self.t_arrival_s = arrival_s
        self.t_start_s = np.zeros(n_req)
        self.t_decode_s = np.zeros(n_req)
        self.t_done_s = np.zeros(n_req)
        self.energy_j = np.zeros(n_req)
        self.pool_code = np.full(n_req, -1, np.int16)
        self.pool2_code = np.full(n_req, -1, np.int16)   # decode pool (split)
        self.mig_bytes = np.zeros(n_req)
        self._n_tok_f = self.n_tok.astype(np.float64)

        # ---- batched pricing: one price_batch per pool over every rid ----
        for pool in self._pool_list:
            self._precompute_pool(pool, n_req)

        # ---- batched dispatch for (m, n)-only policies ----
        # When the policy neither reads fleet state (base dispatch) nor
        # keeps per-commit state (base observe), its choices are a pure
        # function of (m, n): precompute them all in one choose_batch pass
        # and skip the per-arrival FleetState snapshot entirely. Snapshots
        # are pure, so skipping them is unobservable — results stay
        # bit-for-bit those of the event engine.
        sched = self.scheduler
        self._base_dispatch = type(sched).dispatch is Scheduler.dispatch
        self._pre_pool: Optional[np.ndarray] = None
        if (self._base_dispatch and n_req
                and type(sched).observe is Scheduler.observe):
            sys_idx = sched.choose_batch(self.m_tok, self.n_tok)
            if sys_idx is not None:
                pool_of_sys = np.array(
                    [self.pools[name].idx if name is not None else -1
                     for name in (self._by_system.get(s.name)
                                  for s in sched.systems)])
                pre = pool_of_sys[sys_idx]
                # a choice mapping to no pool must raise at the same arrival
                # the event engine raises at: leave it to the scalar path
                if not (pre < 0).any():
                    self._pre_pool = pre

        # Fleet-aware policies that expose table-backed dispatch (e.g.
        # CapacityAware) price every rid once up front; per-arrival work
        # drops to table reads plus the queue-state terms.
        self._rid_dispatch = self._rid_observe = None
        if (not self._base_dispatch and n_req
                and callable(getattr(sched, "prepare_batch", None))
                and callable(getattr(sched, "dispatch_rid", None))):
            sched.prepare_batch(self.m_tok, self.n_tok)
            self._rid_dispatch = sched.dispatch_rid
            self._rid_observe = getattr(sched, "observe_rid", None)

        # arrivals are pre-sorted and merged against the heap instead of
        # being pushed individually; they own sequence numbers 0..n_req-1
        # conceptually, so the counter starts at n_req and an arrival wins
        # every same-epoch tie (exactly the reference heap order)
        seq = itertools.count(n_req)
        events: List[Tuple[float, int, int, object]] = []
        self._next_idx = 0
        self._n_req = n_req
        self._horizon_s = 0.0

        for pool in self._pool_list:
            if pool.power_managed and np.isfinite(pool.spec.linger_s):
                for i in range(pool.n_inst):
                    self._reschedule(pool, i, 0.0, events, seq)
        for name, policy in self._autoscalers.items():
            heapq.heappush(events, (policy.period_s, next(seq), CONTROL, name))

        arrivals = arrival_s.tolist()      # Python floats: faster merge loop
        while events or self._next_idx < n_req:
            if self._next_idx < n_req and (
                    not events or arrivals[self._next_idx] <= events[0][0]):
                rid = self._next_idx
                self._next_idx += 1
                self.events_processed += 1
                self._arrival(rid, arrivals[rid], events, seq)
                continue
            t, _, kind, payload = heapq.heappop(events)
            self.events_processed += 1
            if kind == INSTANCE:
                pool, i, version = payload
                if version != pool.version[i]:
                    continue                             # stale event
                # a WAKING instance holds no residents, so finishing the
                # wake before the (no-op) advance+complete is order-neutral
                if pool.n_waking and pool.state[i] == _WAKING \
                        and t >= pool.wake_done_s[i] - 1e-12:
                    self._finish_wake(pool, i, t)
                self._advance_complete_row(pool, i, t, events, seq)
                if pool.queue:
                    self._refill(pool, t, events, seq)
                if pool.power_managed:
                    self._maybe_descend(pool, i, t)
                self._reschedule(pool, i, t, events, seq)
            elif kind == MIGRATE:                        # KV handoff landed
                rid = payload
                pool = self._pool_list[self.pool2_code[rid]]
                svc_s = float(pool.dec_s[rid])
                key = svc_s if self.queue_discipline == "sjf" else t
                heapq.heappush(pool.queue,
                               (key, next(seq), rid, svc_s, ROLE_DEC))
                pool.queued_service_s += svc_s
                self._refill(pool, t, events, seq)
            elif kind == ADMIT:                          # DeferPlan clock
                pool, rid, svc_s, role = payload
                key = svc_s if self.queue_discipline == "sjf" else t
                heapq.heappush(pool.queue, (key, next(seq), rid, svc_s, role))
                pool.queued_service_s += svc_s
                self._refill(pool, t, events, seq)
            else:                                        # CONTROL tick
                self._control(self.pools[payload], t, events, seq)

        return self._finalize(policy_name or type(self.scheduler).__name__)

    # ------------------------------------------------------------ precompute
    def _precompute_pool(self, pool: _VecPool, n_req: int) -> None:
        spec = pool.spec
        s = spec.system
        if n_req == 0:
            zero = np.zeros(0)
            pool.svc_s = pool.pf_s = pool.ov_s = pool.prefill_power_w = zero
            pool.dec_s = pool.svc_pf_s = zero
            pool.blocks_need = np.zeros(0, np.int64)
            pool.blocks_need_pf = np.zeros(0, np.int64)
            return
        ph = self.model.price_batch(self.m_tok, self.n_tok, s, batch=1)
        pool.pf_s = ph.t_prefill
        pool.ov_s = ph.t_overhead
        pool.svc_s = (ph.t_prefill + ph.t_decode) + ph.t_overhead
        # split-phase service times, associated exactly as the scalar
        # CostModel.split_runtime the event engine prices queue entries with
        pool.dec_s = ph.t_decode
        pool.svc_pf_s = ph.t_overhead + ph.t_prefill
        # blended overhead+prefill power (same expression as _Instance.advance)
        u = np.minimum(np.maximum(ph.util_prefill, 0.0), 1.0)
        p_pf_w = s.chips * (s.power_idle_w
                            + (s.power_peak_w - s.power_idle_w) * u)
        t_total_s = ph.t_overhead + ph.t_prefill
        pool.prefill_power_w = (
            (ph.t_overhead * s.power(0.0) + ph.t_prefill * p_pf_w)
            / np.maximum(t_total_s, 1e-12))
        if spec.kv_blocks:
            tokens = self.m_tok + self.n_tok
            pool.blocks_need = -(-tokens // spec.block_size)
            pool.blocks_need_pf = -(-self.m_tok // spec.block_size)
        else:
            pool.blocks_need = np.zeros(n_req, np.int64)
            pool.blocks_need_pf = np.zeros(n_req, np.int64)

    # --------------------------------------------------------------- arrival
    def _arrival(self, rid: int, t: float, events, seq) -> None:
        q = self._queries[rid]
        if self._pre_pool is not None:
            # precomputed (m, n)-only decision: pool known without a plan
            # object (the choose_batch fast path is run-now, single-pool)
            pool = self._pool_list[self._pre_pool[rid]]
            dst, role, until_s = None, ROLE_FULL, 0.0
        else:
            plan = self._plan(q, rid, t)
            pool_sys, dec_sys, role, until_s = plan_legs(plan, q)
            pool = self.pools[self._by_system[pool_sys]]
            dst = (self.pools[self._by_system[dec_sys]]
                   if dec_sys is not None else None)
        if dst is not None:                      # split: prefill here...
            self._check_admissible(pool, int(pool.blocks_need_pf[rid]), q)
            self._check_admissible(dst, int(dst.blocks_need[rid]), q)
            self.pool2_code[rid] = dst.idx
            svc_s = float(pool.svc_pf_s[rid])
        else:
            self._check_admissible(pool, int(pool.blocks_need[rid]), q)
            svc_s = float(pool.svc_s[rid])
        self.pool_code[rid] = pool.idx
        pool.result.queries += 1
        if until_s > t:                          # deferred admission
            heapq.heappush(events, (until_s, next(seq), ADMIT,
                                    (pool, rid, svc_s, role)))
            return
        key = svc_s if self.queue_discipline == "sjf" else t
        heapq.heappush(pool.queue, (key, next(seq), rid, svc_s, role))
        pool.queued_service_s += svc_s
        self._refill(pool, t, events, seq)

    @staticmethod
    def _check_admissible(pool: _VecPool, need: int, q: Query) -> None:
        if need > pool.spec.kv_blocks > 0:
            raise ValueError(
                f"query (m={q.m}, n={q.n}) needs {need} KV blocks but "
                f"pool {pool.name!r} instances hold only "
                f"{pool.spec.kv_blocks}: it can never be admitted")

    def _fleet_state(self, now: float) -> FleetState:
        return FleetState(time_s=now,
                          pools={p.name: self._snapshot(p, now)
                                 for p in self._pool_list})

    def _plan(self, q: Query, rid: int, now: float) -> Plan:
        """Twin of ``fleet.FleetSimulator._dispatch``: same settlement seam
        (``resolve_plan`` + ``observe``), with the engine's fast paths in
        front — a base-dispatch policy's ``choose`` skips the (pure,
        unobserved) snapshot and wraps directly into a ``RunPlan``; a
        table-backed policy dispatches through ``dispatch_rid``."""
        if self._base_dispatch:
            raw: object = RunPlan(self.scheduler.choose(q).name)
        elif self._rid_dispatch is not None:
            raw = self._rid_dispatch(rid, q, self._fleet_state(now))
        else:
            raw = self.scheduler.dispatch(q, self._fleet_state(now))
        plan = resolve_plan(raw, q, self._by_system)
        if self._rid_observe is not None:
            self._rid_observe(rid, q, plan)
        else:
            self.scheduler.observe(q, plan)
        return plan

    # ------------------------------------------------------------- snapshots
    def _snapshot(self, pool: _VecPool, now: float) -> PoolSnapshot:
        spec = pool.spec
        kv = spec.kv_blocks
        n_prov = pool.n_awake + pool.n_waking
        free_awake = pool.n_awake * spec.slots - pool.busy
        wake_delay_s = self._wake_delay(pool, now, free_awake)
        return PoolSnapshot(
            system=spec.system,
            instances=spec.instances,
            slots_per_instance=spec.slots,
            busy_slots=pool.busy,
            queue_len=len(pool.queue),
            est_wait_s=self._est_wait(pool, now, n_prov, free_awake,
                                      wake_delay_s),
            free_blocks=int(kv - pool.blocks_in_use.min()) if kv else None,
            total_blocks=kv if kv else None,
            block_size=spec.block_size if kv else 0,
            awake_instances=n_prov,
            asleep_instances=spec.instances - n_prov,
            wake_delay_s=wake_delay_s,
        )

    def _wake_delay(self, pool: _VecPool, now: float,
                    free_awake: int) -> float:
        if free_awake > 0:
            return 0.0
        st = pool.state
        cands: List[float] = []
        if pool.n_waking:
            waking = st == _WAKING
            cands.append(float(np.maximum(
                0.0, pool.wake_done_s[waking] - now).min()))
        if pool.n_inst - pool.n_awake - pool.n_waking:
            table = pool.spec.system.states()
            if (st == _SLEEP).any():
                cands.append(table.state(SLEEP).wake_s)
            if (st == _OFF).any():
                cands.append(table.state(OFF).wake_s)
        return min(cands) if cands else 0.0

    def _est_wait(self, pool: _VecPool, now: float,
                  n_prov: int, free_awake: int, wake_delay_s: float) -> float:
        total_slots = n_prov * pool.spec.slots
        backlog_s = pool.queued_service_s / max(1, total_slots)
        if free_awake > 0:
            return backlog_s
        nxt = self._next_event_times(pool, now)
        cand = nxt[pool.state <= _WAKING]
        cand = cand[np.isfinite(cand)]
        vals = cand.tolist()
        if wake_delay_s > 0:
            vals.append(now + wake_delay_s)
        next_free_s = (min(vals) - now) if vals else 0.0
        return max(0.0, next_free_s) + backlog_s

    def _next_event_times(self, pool: _VecPool, now: float) -> np.ndarray:
        """Per-instance ``next_event_time`` (inf = none), with the decode
        group recomputed at ``now`` — an arrival landing exactly on a
        resident's prefill_end sees it decoding before the instance's own
        crossing event runs, so stale cached per-token times are fixed up
        (into temporaries: the caches stay keyed to each instance's last
        settle epoch, which pending advances still need)."""
        out = np.full(pool.n_inst, np.inf)
        st = pool.state
        waking = st == _WAKING
        out[waking] = pool.wake_done_s[waking]
        awake = st == _AWAKE
        empty = awake & (pool.n_res == 0)
        if pool.power_managed and np.isfinite(pool.spec.linger_s) and empty.any():
            out[empty] = pool.empty_since_s[empty] + pool.spec.linger_s
        busy_idx = np.where(awake & (pool.n_res > 0))[0]
        if len(busy_idx) == 0:
            return out
        pf = pool.r_pf_end_s[busy_idx]
        valid = np.arange(pool.slots) < pool.n_res[busy_idx, None]
        dec = valid & (pf <= now + 1e-12)
        b_now = dec.sum(1)
        t_tok = pool.r_t_tok[busy_idx]
        stale = np.where(b_now != pool.b_cached[busy_idx])[0]
        if len(stale):
            t_tok = t_tok.copy()
            for j in stale:
                ks = dec[j]
                t_tab, _ = self._decode_table(pool, int(b_now[j]))
                t_tok[j, ks] = t_tab[pool.r_rid[busy_idx[j], ks]]
        cand = np.where(dec, now + pool.r_rem[busy_idx] * t_tok,
                        np.where(valid, pf, np.inf))
        out[busy_idx] = cand.min(1)
        return out

    def _decode_table(self, pool: _VecPool,
                      b: int) -> Tuple[np.ndarray, np.ndarray]:
        """rid-indexed (s/token, decode power W) at occupancy ``b`` — the
        pool analogue of ``_Resident.tok_time_util``'s per-b memo, computed
        for every rid in one ``price_batch`` pass the first time ``b``
        occurs. Power is pre-applied (``s.power(util)`` elementwise) so the
        settle loops never call the scalar ``power``."""
        t_tab = pool.t_tok_by_b.get(b)
        if t_tab is None:
            s = pool.spec.system
            ph = self.model.price_batch(self.m_tok, self.n_tok, s, batch=b)
            t_tab = ph.t_decode / np.maximum(1, self.n_tok)
            u = np.minimum(np.maximum(ph.util_decode, 0.0), 1.0)
            pool.t_tok_by_b[b] = t_tab
            pool.p_w_by_b[b] = s.chips * (
                s.power_idle_w + (s.power_peak_w - s.power_idle_w) * u)
        return t_tab, pool.p_w_by_b[b]

    # ------------------------------------------------------------ settlement
    def _advance_row(self, pool: _VecPool, i: int, now: float) -> None:
        """Scalar-row twin of ``_Instance.advance`` (one instance). Row
        slices are pulled into Python lists once: per-element float math on
        lists is several times faster than repeated numpy scalar indexing
        and bitwise identical (``tolist`` round-trips float64 exactly)."""
        t0 = float(pool.last_t_s[i])
        dt = now - t0
        pool.last_t_s[i] = now
        nr = int(pool.n_res[i])
        if dt <= 0 or nr == 0:
            return
        pool.busy_slot_s[i] += nr * dt
        thr = t0 + 1e-12
        pf = pool.r_pf_end_s[i, :nr].tolist()
        dec_ks = [k for k in range(nr) if pf[k] <= thr]
        b = len(dec_ks)
        if b:
            rids = pool.r_rid[i, :nr].tolist()
            t_toks = pool.r_t_tok[i, :nr].tolist()
            rems = pool.r_rem[i, :nr].tolist()
            # math.ulp == np.spacing for positive finite floats
            snap_eps = 4.0 * math.ulp(max(now, 1.0))
            energy_j = self.energy_j
            stale = [k for k in dec_ks if pool.r_b[i, k] != b]
            if stale:
                t_tab, p_tab = self._decode_table(pool, b)
                for k in stale:
                    rid = rids[k]
                    t_toks[k] = float(t_tab[rid])
                    pool.r_t_tok[i, k] = t_toks[k]
                    pool.r_p_w[i, k] = p_tab[rid]
                    pool.r_b[i, k] = b
            p_ws = pool.r_p_w[i, :nr].tolist()
            for k in dec_ks:
                t_tok = t_toks[k]
                rem = rems[k]
                steps = dt / t_tok if t_tok > 0 else rem
                steps = min(steps, rem)
                rem -= steps
                energy_j[rids[k]] += steps * t_tok * p_ws[k] / b
                if rem * t_tok <= snap_eps:
                    rem = 0.0
                pool.r_rem[i, k] = rem
        if b < nr:
            energy_j = self.energy_j
            prefill_power_w = pool.prefill_power_w
            for k in range(nr):
                if pf[k] > thr:                     # overhead+prefill phase
                    span = min(now, pf[k]) - t0
                    if span > 0:
                        rid = int(pool.r_rid[i, k])
                        inc_j = span * prefill_power_w[rid]
                        # target is the rid-indexed energy_j array
                        energy_j[rid] += inc_j  # repro-lint: allow[unit-derived-name]

    def _advance_batch(self, pool: _VecPool, idx: np.ndarray,
                       now: float) -> None:
        """Batched ``advance`` over many instances at once (same elementwise
        float ops as ``_advance_row``; each rid receives at most one decode
        and one prefill increment per settle, so scatter order is moot)."""
        t0 = pool.last_t_s[idx].copy()
        pool.last_t_s[idx] = now
        act = (now - t0 > 0) & (pool.n_res[idx] > 0)
        idx, t0 = idx[act], t0[act]
        if len(idx) == 0:
            return
        dt = now - t0
        pool.busy_slot_s[idx] += pool.n_res[idx] * dt
        valid = np.arange(pool.slots) < pool.n_res[idx, None]
        pf = pool.r_pf_end_s[idx]
        dec = valid & (pf <= t0[:, None] + 1e-12)
        b = dec.sum(1)
        t_tok = pool.r_t_tok[idx]
        p_w = pool.r_p_w[idx]
        rids = pool.r_rid[idx]
        stale = dec & (pool.r_b[idx] != b[:, None])
        if stale.any():
            rb = pool.r_b[idx]
            for bb in np.unique(b[stale.any(1)]):
                sel = stale & (b[:, None] == bb)
                t_tab, p_tab = self._decode_table(pool, int(bb))
                t_tok[sel] = t_tab[rids[sel]]
                p_w[sel] = p_tab[rids[sel]]
                rb[sel] = bb
            pool.r_t_tok[idx] = t_tok
            pool.r_p_w[idx] = p_w
            pool.r_b[idx] = rb
        rem = pool.r_rem[idx]
        pos = dec & (t_tok > 0)
        steps = np.where(dec, rem, 0.0)             # t_tok == 0 -> rem
        np.divide(np.broadcast_to(dt[:, None], steps.shape), t_tok,
                  out=steps, where=pos)
        steps = np.minimum(steps, rem)
        new_rem = rem - steps
        with np.errstate(invalid="ignore"):
            inc_j = steps * t_tok * p_w / b[:, None]
        np.add.at(self.energy_j, rids[dec], inc_j[dec])
        snap_eps = 4.0 * np.spacing(max(now, 1.0))
        new_rem = np.where(dec & (new_rem * t_tok <= snap_eps), 0.0, new_rem)
        pool.r_rem[idx] = np.where(dec, new_rem, rem)
        pre = valid & ~dec
        if pre.any():
            span = np.minimum(now, pf) - t0[:, None]
            hot = pre & (span > 0)
            if hot.any():
                inc_pf_j = span[hot] * pool.prefill_power_w[rids[hot]]
                np.add.at(self.energy_j, rids[hot], inc_pf_j)

    def _advance_complete_row(self, pool: _VecPool, i: int, now: float,
                              events, seq) -> bool:
        """``_advance_row`` followed by ``_complete_row``, sharing one read
        of the resident rows (the hot per-event path; same float ops)."""
        t0 = float(pool.last_t_s[i])
        dt = now - t0
        pool.last_t_s[i] = now
        nr = int(pool.n_res[i])
        if nr == 0:
            return False
        pf = pool.r_pf_end_s[i, :nr].tolist()
        rems = pool.r_rem[i, :nr].tolist()
        if dt > 0:
            pool.busy_slot_s[i] += nr * dt
            thr0 = t0 + 1e-12
            dec_ks = [k for k in range(nr) if pf[k] <= thr0]
            b = len(dec_ks)
            if b:
                rids = pool.r_rid[i, :nr].tolist()
                t_toks = pool.r_t_tok[i, :nr].tolist()
                rbs = pool.r_b[i, :nr].tolist()
                p_ws = pool.r_p_w[i, :nr].tolist()
                snap_eps = 4.0 * math.ulp(max(now, 1.0))
                energy_j = self.energy_j
                stale = [k for k in dec_ks if rbs[k] != b]
                if stale:
                    t_tab, p_tab = self._decode_table(pool, b)
                    for k in stale:
                        rid = rids[k]
                        t_toks[k] = float(t_tab[rid])
                        p_ws[k] = float(p_tab[rid])
                        rbs[k] = b
                    pool.r_t_tok[i, :nr] = t_toks
                    pool.r_p_w[i, :nr] = p_ws
                    pool.r_b[i, :nr] = rbs
                for k in dec_ks:
                    t_tok = t_toks[k]
                    rem = rems[k]
                    steps = dt / t_tok if t_tok > 0 else rem
                    steps = min(steps, rem)
                    rem -= steps
                    energy_j[rids[k]] += steps * t_tok * p_ws[k] / b
                    if rem * t_tok <= snap_eps:
                        rem = 0.0
                    rems[k] = rem
                pool.r_rem[i, :nr] = rems
            if b < nr:
                energy_j = self.energy_j
                prefill_power_w = pool.prefill_power_w
                for k in range(nr):
                    if pf[k] > thr0:                # overhead+prefill phase
                        span = min(now, pf[k]) - t0
                        if span > 0:
                            rid = int(pool.r_rid[i, k])
                            inc_j = span * prefill_power_w[rid]
                            # target is the rid-indexed energy_j array
                            energy_j[rid] += inc_j  # repro-lint: allow[unit-derived-name]
        thr = now + 1e-12
        done = [k for k in range(nr)
                if rems[k] <= 1e-6 and pf[k] <= thr]
        if not done:
            return False
        self._pop_done(pool, i, nr, done, now, events, seq)
        return True

    def _complete_row(self, pool: _VecPool, i: int, now: float,
                      events, seq) -> bool:
        """``pop_finished`` + ``_complete`` for one instance; True if any
        resident finished (slots/blocks freed)."""
        nr = int(pool.n_res[i])
        if nr == 0:
            return False
        rem = pool.r_rem[i, :nr].tolist()
        pf = pool.r_pf_end_s[i, :nr].tolist()
        thr = now + 1e-12
        done = [k for k in range(nr)
                if rem[k] <= 1e-6 and pf[k] <= thr]
        if not done:
            return False
        self._pop_done(pool, i, nr, done, now, events, seq)
        return True

    def _pop_done(self, pool: _VecPool, i: int, nr: int, done: List[int],
                  now: float, events, seq) -> None:
        """Finish the ``done`` slots of one instance row and compact it —
        the shared tail of both completion paths (reference: the ``done``
        loop in ``fleet.FleetSimulator._complete`` + ``pop_finished``'s
        removal). Prefill-only residents hand off instead of finishing."""
        for k in done:
            rid = int(pool.r_rid[i, k])
            if pool.r_role[i, k] == ROLE_PF:
                self._handoff(rid, pool, now, events, seq)
            else:
                self.t_done_s[rid] = now
                self._horizon_s = max(self._horizon_s, now)
            pool.blocks_in_use[i] -= pool.r_blocks[i, k]
        keep = [k for k in range(nr) if k not in done]
        for dst, src in enumerate(keep):
            if dst != src:
                pool.r_rid[i, dst] = pool.r_rid[i, src]
                pool.r_rem[i, dst] = pool.r_rem[i, src]
                pool.r_pf_end_s[i, dst] = pool.r_pf_end_s[i, src]
                pool.r_t_tok[i, dst] = pool.r_t_tok[i, src]
                pool.r_p_w[i, dst] = pool.r_p_w[i, src]
                pool.r_b[i, dst] = pool.r_b[i, src]
                pool.r_blocks[i, dst] = pool.r_blocks[i, src]
                pool.r_role[i, dst] = pool.r_role[i, src]
        pool.r_rid[i, len(keep):nr] = -1
        pool.n_res[i] = len(keep)
        pool.busy -= len(done)
        if not keep:
            pool.empty_since_s[i] = now        # linger clock starts on drain

    def _handoff(self, rid: int, src: _VecPool, now: float,
                 events, seq) -> None:
        """Transcribed ``FleetSimulator._handoff``: the SAME shared
        ``migration_charge`` settlement call, so the priced
        bytes/seconds/joules are bit-identical between engines."""
        q = self._queries[rid]
        spec = src.spec
        bs = spec.block_size if spec.kv_blocks else 0
        dst = self._pool_list[self.pool2_code[rid]]
        nbytes, t_mig, e_mig = migration_charge(
            self.model, q.m, spec.system, dst.spec.system,
            block_size=bs, rid=rid)
        self.energy_j[rid] += e_mig
        self.mig_bytes[rid] = nbytes
        heapq.heappush(events, (now + t_mig, next(seq), MIGRATE, rid))

    def _refill(self, pool: _VecPool, now: float, events, seq) -> None:
        """Transcribed ``FleetSimulator._refill``: admit queue head to the
        least-loaded awake instance that fits (slots AND blocks), settle the
        pool on a stuck head, demand-wake if still stuck."""
        spec = pool.spec
        kv = spec.kv_blocks
        while pool.queue:
            head_rid, head_role = pool.queue[0][2], pool.queue[0][4]
            need = int((pool.blocks_need_pf if head_role == ROLE_PF
                        else pool.blocks_need)[head_rid])
            if pool.n_awake * spec.slots - pool.busy <= 0:
                i = -1              # no awake slot free: provably stuck
            elif not kv and pool.n_awake == pool.n_inst:
                # every instance is awake and a free slot exists, so the
                # globally least-loaded instance is admissible — and argmin
                # is the first minimal one, exactly min() over instance order
                i = int(pool.n_res.argmin())
            else:
                ready = (pool.state == _AWAKE) & (pool.n_res < spec.slots)
                if kv:
                    ready &= need <= kv - pool.blocks_in_use
                if ready.any():
                    load = np.where(ready, pool.n_res, _HUGE)
                    i = int(np.argmin(load))    # first least-loaded, as min()
                else:
                    i = -1
            if i < 0:
                if self._settle(pool, now, events, seq):
                    continue        # freed capacity: re-evaluate the head
                self._demand_wake(pool, now, events, seq)
                break
            key, _, rid, svc_s, role = heapq.heappop(pool.queue)
            pool.queued_service_s -= svc_s
            self._advance_complete_row(pool, i, now, events, seq)
            slot = int(pool.n_res[i])
            pool.r_rid[i, slot] = rid
            pool.r_role[i, slot] = role
            if role == ROLE_PF:     # twin of _Resident's role branches
                pool.r_rem[i, slot] = 0.0
                pf_end_s = (now + float(pool.ov_s[rid])) + float(pool.pf_s[rid])
            elif role == ROLE_DEC:
                pool.r_rem[i, slot] = float(self._n_tok_f[rid])
                pf_end_s = now
            else:
                pool.r_rem[i, slot] = float(self._n_tok_f[rid])
                pf_end_s = (now + float(pool.ov_s[rid])) + float(pool.pf_s[rid])
            pool.r_pf_end_s[i, slot] = pf_end_s
            pool.r_b[i, slot] = -1              # t_tok not yet priced
            pool.r_blocks[i, slot] = need
            if role != ROLE_DEC:    # DEC keeps the prefill pool's anchors
                self.t_start_s[rid] = now
                self.t_decode_s[rid] = pf_end_s
            pool.n_res[i] += 1
            pool.blocks_in_use[i] += need
            pool.busy += 1
            if pool.busy > pool.result.peak_residents:
                pool.result.peak_residents = pool.busy
            self._reschedule(pool, i, now, events, seq)

    def _settle(self, pool: _VecPool, now: float, events, seq) -> bool:
        """Advance + complete every resident-holding instance to ``now``
        (batched) and report whether any slot or block freed; changed
        instances are rescheduled in index order (the reference engine's
        sequence-number order)."""
        idx = np.where(pool.n_res > 0)[0]
        if len(idx) == 0:
            return False
        if len(idx) > 8:
            self._advance_batch(pool, idx, now)
        else:
            for i in idx:
                self._advance_row(pool, int(i), now)
        freed = False
        for i in idx:
            if self._complete_row(pool, int(i), now, events, seq):
                self._reschedule(pool, int(i), now, events, seq)
                freed = True
        return freed

    # ----------------------------------------------------------- power moves
    def _demand_wake(self, pool: _VecPool, now: float, events, seq) -> None:
        if not pool.power_managed or not pool.queue:
            return
        incoming = pool.n_waking * pool.slots
        self._wake_sleeping(pool, len(pool.queue) - incoming, now, events, seq)

    def _wake_sleeping(self, pool: _VecPool, slot_deficit: int,
                       now: float, events, seq) -> None:
        if slot_deficit <= 0:
            return
        if pool.n_inst - pool.n_awake - pool.n_waking == 0:
            return
        table = pool.spec.system.states()
        asleep = np.where(pool.state >= _SLEEP)[0]
        if len(asleep) == 0:
            return
        wake_s = np.where(pool.state[asleep] == _SLEEP,
                          table.state(SLEEP).wake_s, table.state(OFF).wake_s)
        for i in asleep[np.argsort(wake_s, kind="stable")]:
            if slot_deficit <= 0:
                break
            self._begin_wake(pool, int(i), now)
            self._reschedule(pool, int(i), now, events, seq)
            slot_deficit -= pool.slots

    def _begin_wake(self, pool: _VecPool, i: int, now: float) -> None:
        st = pool.spec.system.states().state(_STATE_NAME[int(pool.state[i])])
        pool.wake_done_s[i] = now + st.wake_s
        pool.wake_energy_j[i] += st.wake_j
        pool.n_wakes[i] += 1
        pool.state[i] = _WAKING
        pool.n_waking += 1
        pool.timeline[i].append((now, WAKING))

    def _finish_wake(self, pool: _VecPool, i: int, now: float) -> None:
        pool.state[i] = _AWAKE
        pool.n_waking -= 1
        pool.n_awake += 1
        pool.empty_since_s[i] = now
        pool.timeline[i].append((now, AWAKE))

    def _go_sleep(self, pool: _VecPool, i: int, now: float) -> None:
        pool.last_t_s[i] = now
        pool.state[i] = _STATE_CODE[pool.spec.sleep_state]
        pool.n_awake -= 1
        pool.timeline[i].append((now, pool.spec.sleep_state))

    def _maybe_descend(self, pool: _VecPool, i: int, now: float) -> None:
        if (not pool.power_managed or pool.state[i] != _AWAKE
                or pool.n_res[i] or pool.queue):
            return
        if (pool.target_awake is not None
                and pool.n_awake + pool.n_waking > pool.target_awake):
            self._go_sleep(pool, i, now)
            return
        linger_s = pool.spec.linger_s
        if np.isfinite(linger_s) and now >= pool.empty_since_s[i] + linger_s - 1e-12:
            self._go_sleep(pool, i, now)

    def _control(self, pool: _VecPool, now: float, events, seq) -> None:
        policy = self._autoscalers[pool.name]
        snap = self._snapshot(pool, now)
        lo = max(0, min(policy.min_instances, pool.spec.instances))
        target = max(lo, min(pool.spec.instances, policy.desired_awake(snap)))
        pool.target_awake = target
        n_awake_like = pool.n_awake + pool.n_waking
        if n_awake_like < target:
            self._wake_sleeping(pool, (target - n_awake_like) * pool.slots,
                                now, events, seq)
        elif n_awake_like > target and not pool.queue:
            surplus = n_awake_like - target
            idlers = np.where((pool.state == _AWAKE) & (pool.n_res == 0))[0]
            order = np.argsort(pool.empty_since_s[idlers], kind="stable")
            for i in idlers[order][:surplus]:
                self._go_sleep(pool, int(i), now)
                self._reschedule(pool, int(i), now, events, seq)
        if self._work_remaining():
            nxt = now + policy.period_s
            if not self._fleet_busy():
                nxt = max(nxt, self._next_arrival_s())
            heapq.heappush(events, (nxt, next(seq), CONTROL, pool.name))

    # ------------------------------------------------------------ scheduling
    def _fleet_busy(self) -> bool:
        return any(p.queue or p.busy > 0 for p in self._pool_list)

    def _next_arrival_s(self) -> float:
        if self._next_idx >= self._n_req:
            return 0.0
        return float(self.t_arrival_s[self._next_idx])

    def _work_remaining(self) -> bool:
        return self._next_idx < self._n_req or self._fleet_busy()

    def _reschedule(self, pool: _VecPool, i: int, now: float,
                    events, seq) -> None:
        """Bump the instance's version (staling pending events), re-key its
        cached per-token times to the decode group at ``now`` (twin of
        ``_Resident.tok_time_util``'s per-b memo), and push its next event.
        The refresh and the next-event scan share one pass over the row —
        residents only live on AWAKE instances, so the resident branch never
        has to consult the power state."""
        pool.version[i] += 1
        nr = int(pool.n_res[i])
        if nr == 0:
            pool.b_cached[i] = 0
            if not pool.power_managed:
                return         # non-managed pools are always AWAKE, no linger
            st = int(pool.state[i])
            if st == _WAKING:
                nxt = float(pool.wake_done_s[i])
            elif st >= _SLEEP:
                return
            elif pool.linger_finite:
                nxt = float(pool.empty_since_s[i]) + pool.linger_s
            else:
                return
        else:
            thr = now + 1e-12
            pf = pool.r_pf_end_s[i, :nr].tolist()
            dec_ks = [k for k in range(nr) if pf[k] <= thr]
            b = len(dec_ks)
            pool.b_cached[i] = b
            nxt = math.inf
            if b:
                t_toks = pool.r_t_tok[i, :nr].tolist()
                rbs = pool.r_b[i, :nr].tolist()
                stale = [k for k in dec_ks if rbs[k] != b]
                if stale:
                    t_tab, p_tab = self._decode_table(pool, b)
                    p_ws = pool.r_p_w[i, :nr].tolist()
                    rids = pool.r_rid[i, :nr].tolist()
                    for k in stale:
                        rid = rids[k]
                        t_toks[k] = float(t_tab[rid])
                        p_ws[k] = float(p_tab[rid])
                        rbs[k] = b
                    pool.r_t_tok[i, :nr] = t_toks
                    pool.r_p_w[i, :nr] = p_ws
                    pool.r_b[i, :nr] = rbs
                rems = pool.r_rem[i, :nr].tolist()
                for k in range(nr):
                    t = pf[k] if pf[k] > thr else now + rems[k] * t_toks[k]
                    if t < nxt:
                        nxt = t
            else:
                for k in range(nr):
                    if pf[k] < nxt:
                        nxt = pf[k]
            if nxt == math.inf:
                return
        heapq.heappush(events, (max(nxt, now), next(seq), INSTANCE,
                                (pool, i, pool.version[i])))

    # -------------------------------------------------------------- finalize
    def _finalize(self, policy: str) -> FleetSimResult:
        horizon_s = self._horizon_s
        per_pool: Dict[str, PoolResult] = {}
        for pool in self._pool_list:
            spec = pool.spec
            total_slots = spec.instances * spec.slots
            busy = sum(pool.busy_slot_s.tolist())      # left-fold, as sum()
            pool.result.busy_slot_seconds = busy
            pool.result.energy_j = sum(
                self.energy_j[self.pool_code == pool.idx].tolist())
            if horizon_s > 0:
                pool.result.utilization = busy / (total_slots * horizon_s)
                if all(len(tl) == 1 for tl in pool.timeline):
                    idle_slot_s = total_slots * horizon_s - busy
                    pool.result.idle_energy_j = (
                        idle_slot_s * spec.system.power(0.0) / spec.slots)
                else:
                    self._integrate_power(pool, horizon_s)
            per_pool[pool.name] = pool.result
        arrays = {"t_arrival_s": self.t_arrival_s, "t_start_s": self.t_start_s,
                  "t_decode_s": self.t_decode_s, "t_done_s": self.t_done_s,
                  "energy_j": self.energy_j, "mig_bytes": self.mig_bytes}
        return FleetSimResult.from_arrays(
            policy, self._queries, self.pool_code,
            [p.name for p in self._pool_list], arrays, per_pool, horizon_s,
            pool2_code=self.pool2_code)

    def _integrate_power(self, pool: _VecPool, horizon_s: float) -> None:
        """Transcription of ``FleetSimulator._integrate_power`` over the
        array-backed per-instance accounting (same accumulation order)."""
        s = pool.spec.system
        p_idle_w = s.power(0.0)
        idle_j = sleep_s = wake_j = 0.0
        wakes = 0
        for i in range(pool.n_inst):
            segs = pool.timeline[i] + [(horizon_s, "end")]
            for (t0, st), (t1, _) in zip(segs, segs[1:]):
                dur = min(t1, horizon_s) - min(t0, horizon_s)
                if dur <= 0:
                    continue
                if st in (AWAKE, WAKING):
                    idle_j += dur * p_idle_w
                else:
                    idle_j += dur * s.state_power(st)
                    sleep_s += dur
            idle_j -= float(pool.busy_slot_s[i]) * p_idle_w / pool.slots
            idle_j += float(pool.wake_energy_j[i])
            wake_j += float(pool.wake_energy_j[i])
            wakes += int(pool.n_wakes[i])
        pool.result.idle_energy_j = idle_j
        pool.result.sleep_s = sleep_s
        pool.result.wake_energy_j = wake_j
        pool.result.wake_count = wakes
