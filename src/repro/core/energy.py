"""Energy model E(m, n, s): the paper's measured quantity, derived analytically.

E = sum over phases of  P(util) * t_phase, with
P(util) = chips * (P_idle + (P_peak - P_idle) * util).

This reproduces the paper's central empirical finding structurally:
  * small queries on a performance-class instance are dominated by
    (idle+overhead) power x time  -> high J/token;
  * an efficiency-class device has far lower allocated-idle power, so it wins
    below a workload threshold, and loses above it where the performance
    instance reaches high utilization.

The free functions here are deprecation shims over the unified pricing layer
(``core.pricing.CostModel`` with the analytic oracle — bit-for-bit identical
values, shared memo). New code should take a ``CostModel``.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.core.pricing import default_cost_model
from repro.core.systems import SystemProfile


def energy(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
           batch: int = 1) -> float:
    """E(m, n, s) in joules (Eq. 1's energy term).
    Deprecated shim: ``CostModel(cfg).energy(m, n, s)``."""
    return default_cost_model(cfg).energy(m, n, s, batch)


def energy_per_token_in(cfg: ModelConfig, m: int, s: SystemProfile,
                        n_out: int = 32) -> float:
    """J/token while varying input size (paper Fig 1c protocol: out fixed 32)."""
    return energy(cfg, m, n_out, s) / max(1, m)


def energy_per_token_out(cfg: ModelConfig, n: int, s: SystemProfile,
                         m_in: int = 32) -> float:
    """J/token while varying output size (paper Fig 2c protocol: in fixed 32)."""
    return energy(cfg, m_in, n, s) / max(1, n)


def crossover_threshold(cfg: ModelConfig, eff: SystemProfile, perf: SystemProfile,
                        *, axis: str = "in", lo: int = 1, hi: int = 4096) -> int:
    """Smallest token count where the performance system's J/token drops below
    the efficiency system's (the quantity the paper's T_in/T_out estimate)."""
    fn = energy_per_token_in if axis == "in" else energy_per_token_out
    for t in range(lo, hi + 1):
        if fn(cfg, t, perf) < fn(cfg, t, eff):
            return t
    return hi
