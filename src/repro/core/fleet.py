"""Discrete-event fleet simulator: time-aware evaluation of dispatch policies.

The static path (``simulator.simulate``) prices each query independently —
correct for the paper's Section 6 accounting, but blind to arrivals,
queueing, batching, and finite instance counts. This module simulates the
fleet as a discrete-event system so every ``Scheduler`` policy is compared
under identical queueing dynamics via the uniform online
``dispatch(query, fleet_state)`` API.

Event loop (heap-ordered, deterministic under a fixed workload seed):

  * **arrival**    — a query arrives; the policy dispatches it to a pool
                     (given a ``FleetState`` snapshot) and it joins the pool's
                     FIFO or priority queue.
  * **dispatch**   — a queued request is admitted to a free slot on the
                     least-loaded instance; per-request overhead + prefill
                     begin (prefill runs per-request, as in
                     ``serving.batching.ContinuousBatcher``).
  * **batch-step** — an instance's decode group advances. Decode steps are
                     shared across co-resident requests (the batcher's slot
                     model): each resident's per-token time is the priced
                     ``model.phases(..., batch=b).t_decode / n`` at the current
                     occupancy ``b``, so weight streaming amortizes across the
                     batch. The loop re-linearizes on every occupancy change
                     instead of emitting one event per token.

All pricing flows through one ``CostModel`` (``core.pricing``) — by default
the dispatch policy's own, so simulator and scheduler agree on phase times
whichever perf oracle (analytic / table / calibrated) is plugged in.
  * **completion** — a resident finishes its output tokens; the slot frees
                     and the queue refills it.

Energy accounting attributes instance power to residents (power at the
resident's utilization, split ``1/b`` across the batch), which makes the
zero-load / infinite-capacity limit reduce *exactly* to the static
``simulate()`` totals: batch=1 service reproduces ``energy(cfg, m, n, s)``
and ``runtime(cfg, m, n, s)`` term by term. Idle (allocated-but-unused)
energy over the makespan is reported separately as ``idle_energy_j`` so the
request-attributed total stays comparable to the static path.

Energy-proportional fleets: each instance additionally runs a power-state
machine over the profile's ``active``/``idle``/``sleep``/``off`` table
(``core.systems``). An instance drained of residents descends to
``PoolSpec.sleep_state`` after ``linger_s`` of idleness; ``_refill`` wakes
sleeping instances on demand (latency ``wake_s``, transition energy
``wake_j`` — both charged into ``idle_energy_j``, where allocated-but-idle
draw already lives). An optional ``AutoscalerPolicy`` (target-utilization or
queue-depth variant) additionally drives each pool's awake-instance count
between ``min_instances`` and ``PoolSpec.instances`` at a fixed control-loop
cadence, emitting scale events into the same heap. With ``linger_s=inf``
and no autoscaler the machine never engages and the simulation — per-request
energies AND fleet totals — is bit-for-bit the static-fleet behavior (the
equivalence invariant gated by tests and CI).
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import Plan
from repro.core.pricing import AnalyticOracle, CostModel
from repro.core.scheduler import (FleetState, PoolSnapshot, Scheduler,
                                  kv_blocks_needed)
# roles are defined by the shared settlement layer (re-exported here for the
# historical import path); both engines enqueue legs tagged with them
from repro.core.settlement import (ROLE_DEC, ROLE_FULL, ROLE_PF,
                                   leg_service_s, migration_charge, plan_legs,
                                   resolve_plan)
from repro.core.systems import SystemProfile
from repro.core.workload import Query

# event kinds: INSTANCE = batch-step/completion/wake/linger, CONTROL =
# autoscaler tick, MIGRATE = a disaggregated request's KV handoff landing on
# its decode pool after the priced link transit time, ADMIT = a deferred
# request's admission clock arriving (DeferPlan.until_s)
ARRIVAL, INSTANCE, CONTROL, MIGRATE, ADMIT = 0, 1, 2, 3, 4

# instance power-machine states. AWAKE/WAKING draw idle power when unused;
# SLEEP/OFF names match the profile's PowerStateTable rows.
AWAKE, WAKING, SLEEP, OFF = "awake", "waking", "sleep", "off"


# ------------------------------------------------------------------ fleet spec
@dataclass(frozen=True)
class PoolSpec:
    """One pool: a system profile replicated ``instances`` times, each
    instance running ``slots`` continuous-batching decode lanes.

    ``kv_blocks`` bounds each instance's KV memory in blocks of
    ``block_size`` tokens (the paged serving runtime's unit): a request is
    admitted only when its worst-case context ``ceil((m + n) / block_size)``
    fits in the instance's free blocks, so decode occupancy is bounded by
    memory, not just the slot count. 0 = unbounded (pre-paging behavior).

    ``linger_s`` arms the power-state machine: an instance empty for that
    long descends to ``sleep_state`` (``"sleep"`` or ``"off"`` in the
    profile's power table) and is woken on demand. The default ``inf``
    keeps every instance awake forever — the pre-power-management fleet."""
    system: SystemProfile
    instances: int = 1
    slots: int = 1
    kv_blocks: int = 0
    block_size: int = 16
    linger_s: float = math.inf
    sleep_state: str = SLEEP

    def __post_init__(self):
        if self.sleep_state not in (SLEEP, OFF):
            raise ValueError(f"sleep_state must be {SLEEP!r} or {OFF!r}, "
                             f"got {self.sleep_state!r}")
        if self.linger_s < 0:
            raise ValueError(f"linger_s must be >= 0, got {self.linger_s}")

    def blocks_needed(self, q: Query) -> int:
        if not self.kv_blocks:
            return 0
        return kv_blocks_needed(q.m + q.n, self.block_size)

    def blocks_needed_prefill(self, q: Query) -> int:
        """Blocks a prefill-only (pre-handoff) residency holds: the prompt's
        context, not the worst-case decoded context — the decode pool pays
        for that after migration."""
        if not self.kv_blocks:
            return 0
        return kv_blocks_needed(q.m, self.block_size)


# --------------------------------------------------------------------- records
@dataclass
class RequestRecord:
    rid: int
    query: Query
    pool: str
    t_arrival: float
    t_start: float = 0.0          # admitted to an instance (queue wait ends)
    t_decode: float = 0.0         # prefill done, decoding begins
    t_done: float = 0.0
    energy_j: float = 0.0
    # disaggregated requests only: the pool that ran decode (``pool`` is then
    # the prefill pool, which also carries the per-pool energy attribution),
    # and the KV bytes the handoff moved over the inter-pool link
    pool_decode: str = ""
    mig_bytes: float = 0.0

    @property
    def wait_s(self) -> float:
        return self.t_start - self.t_arrival

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def service_s(self) -> float:
        return self.t_done - self.t_start


@dataclass
class PoolResult:
    queries: int = 0
    energy_j: float = 0.0
    idle_energy_j: float = 0.0    # allocated idle + sleep draw + wake energy
    busy_slot_seconds: float = 0.0
    utilization: float = 0.0      # busy slot-seconds / (slots * horizon)
    peak_residents: int = 0       # max concurrent residents (occupancy bound)
    wake_count: int = 0           # sleep/off -> awake transitions
    wake_energy_j: float = 0.0    # one-shot transition energy (inside idle_energy_j)
    sleep_s: float = 0.0          # instance-seconds spent in sleep/off (<= horizon)


class FleetSimResult:
    """Simulation outcome: request records (or their struct-of-arrays
    equivalent from the vectorized engine), per-pool accounting, horizon.

    Backed either by a list of ``RequestRecord`` (event engine) or by
    rid-indexed numpy arrays (``from_arrays``, vectorized engine); each view
    is materialized lazily from the other, so metrics are computed one way —
    over the arrays — whichever engine produced the result. Every metric is
    bit-for-bit what the historical list-comprehension code computed (same
    float values elementwise, same reduction order/algorithm).
    """

    def __init__(self, policy: str, records: Optional[List[RequestRecord]],
                 per_pool: Dict[str, PoolResult], horizon_s: float, *,
                 _queries: Optional[Sequence[Query]] = None,
                 _pool_code: Optional[np.ndarray] = None,
                 _pool_names: Optional[Sequence[str]] = None,
                 _arrays: Optional[Dict[str, np.ndarray]] = None,
                 _pool2_code: Optional[np.ndarray] = None):
        self.policy = policy
        self.per_pool = per_pool
        self.horizon_s = horizon_s
        self._records = records
        self._queries = _queries          # rid-ordered (array-backed results)
        self._pool_code = _pool_code      # rid -> index into _pool_names
        self._pool_names = _pool_names
        self._arrays = _arrays            # rid-indexed t_*/energy arrays
        self._pool2_code = _pool2_code    # rid -> decode pool (-1 = no split)
        self._sorted_latency_s: Optional[np.ndarray] = None
        self._sorted_ttft_s: Optional[np.ndarray] = None
        self._sorted_tpot_s: Optional[np.ndarray] = None

    @classmethod
    def from_arrays(cls, policy: str, queries: Sequence[Query],
                    pool_code: np.ndarray, pool_names: Sequence[str],
                    arrays: Dict[str, np.ndarray],
                    per_pool: Dict[str, PoolResult],
                    horizon_s: float,
                    pool2_code: Optional[np.ndarray] = None) -> "FleetSimResult":
        """Array-backed result (vectorized engine): ``arrays`` holds
        ``t_arrival_s``/``t_start_s``/``t_decode_s``/``t_done_s``/``energy_j``
        /``mig_bytes`` indexed by rid; ``pool_code[rid]`` indexes
        ``pool_names`` (``pool2_code`` likewise for split requests' decode
        pool, -1 where the request never split)."""
        return cls(policy, None, per_pool, horizon_s, _queries=queries,
                   _pool_code=pool_code, _pool_names=pool_names,
                   _arrays=arrays, _pool2_code=pool2_code)

    @property
    def records(self) -> List[RequestRecord]:
        if self._records is None:
            a = self._arrays
            p2 = self._pool2_code
            self._records = [
                RequestRecord(rid, q, self._pool_names[self._pool_code[rid]],
                              t_arrival=float(a["t_arrival_s"][rid]),
                              t_start=float(a["t_start_s"][rid]),
                              t_decode=float(a["t_decode_s"][rid]),
                              t_done=float(a["t_done_s"][rid]),
                              energy_j=float(a["energy_j"][rid]),
                              pool_decode=(self._pool_names[p2[rid]]
                                           if p2 is not None and p2[rid] >= 0
                                           else ""),
                              mig_bytes=float(a["mig_bytes"][rid])
                              if "mig_bytes" in a else 0.0)
                for rid, q in enumerate(self._queries)]
        return self._records

    def _metric_arrays(self) -> Dict[str, np.ndarray]:
        if self._arrays is None:
            recs = self._records
            self._arrays = {
                "t_arrival_s": np.array([r.t_arrival for r in recs]),
                "t_start_s": np.array([r.t_start for r in recs]),
                "t_decode_s": np.array([r.t_decode for r in recs]),
                "t_done_s": np.array([r.t_done for r in recs]),
                "energy_j": np.array([r.energy_j for r in recs]),
                "mig_bytes": np.array([r.mig_bytes for r in recs]),
            }
        return self._arrays

    def _out_tokens(self) -> np.ndarray:
        if self._queries is not None:
            return np.array([q.n for q in self._queries])
        return np.array([r.query.n for r in self._records])

    def __len__(self) -> int:
        if self._queries is not None:
            return len(self._queries)
        return len(self._records)

    @property
    def total_energy_j(self) -> float:
        # sequential left-fold, as the historical sum over records
        return sum(self._metric_arrays()["energy_j"].tolist())

    @property
    def idle_energy_j(self) -> float:
        return sum(p.idle_energy_j for p in self.per_pool.values())

    @property
    def fleet_energy_j(self) -> float:
        """Request-attributed + allocated-idle energy over the makespan."""
        return self.total_energy_j + self.idle_energy_j

    @property
    def tokens(self) -> int:
        if self._queries is not None:
            return sum(q.m + q.n for q in self._queries)
        return sum(r.query.m + r.query.n for r in self._records)

    @property
    def j_per_token(self) -> float:
        """Request-attributed J/token only — EXCLUDES allocated-idle energy.
        Comparable to the static per-query accounting, but it understates a
        poorly-utilized fleet; use ``fleet_j_per_token`` to rank policies."""
        return self.total_energy_j / max(1, self.tokens)

    @property
    def fleet_j_per_token(self) -> float:
        """Idle-inclusive J/token: (attributed + allocated-idle + wake)
        energy over the makespan, per token — the headline fleet metric."""
        return self.fleet_energy_j / max(1, self.tokens)

    def slo_attainment(self, slo_s: float) -> float:
        """Fraction of requests whose end-to-end latency met ``slo_s``."""
        if not len(self):
            return 1.0
        a = self._metric_arrays()
        return float(np.mean((a["t_done_s"] - a["t_arrival_s"]) <= slo_s))

    def latency_percentile(self, p: float) -> float:
        if not len(self):
            return 0.0
        if self._sorted_latency_s is None:
            # sorted once per result: p50 + p99 + any further percentile
            # reuse it instead of re-sorting per call
            a = self._metric_arrays()
            self._sorted_latency_s = np.sort(a["t_done_s"] - a["t_arrival_s"])
        return float(np.percentile(self._sorted_latency_s, p))

    def ttft_percentile(self, p: float) -> float:
        """Time-to-first-token percentile: prefill completion minus arrival
        (``t_decode_s`` is when decoding begins — for a split request, when
        the prefill pool finished, so a handoff does not inflate TTFT)."""
        if not len(self):
            return 0.0
        if self._sorted_ttft_s is None:
            # sorted once per result, as latency_percentile does
            a = self._metric_arrays()
            self._sorted_ttft_s = np.sort(a["t_decode_s"] - a["t_arrival_s"])
        return float(np.percentile(self._sorted_ttft_s, p))

    def tpot_percentile(self, p: float) -> float:
        """Time-per-output-token percentile: the decode span spread over the
        request's output tokens. For a split request the span includes the
        migration transit and the decode pool's queue — the handoff's
        latency cost lands here, not in TTFT."""
        if not len(self):
            return 0.0
        if self._sorted_tpot_s is None:
            a = self._metric_arrays()
            span_s = a["t_done_s"] - a["t_decode_s"]
            self._sorted_tpot_s = np.sort(
                span_s / np.maximum(1, self._out_tokens()))
        return float(np.percentile(self._sorted_tpot_s, p))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_latency_s(self) -> float:
        return self.latency_percentile(99)

    @property
    def p99_ttft_s(self) -> float:
        return self.ttft_percentile(99)

    @property
    def mig_bytes(self) -> float:
        """Total KV bytes moved by prefill->decode handoffs (0 when the
        policy never split a request)."""
        # sequential left-fold, as total_energy_j
        return sum(self._metric_arrays()["mig_bytes"].tolist())

    @property
    def mean_wait_s(self) -> float:
        if not len(self):
            return 0.0
        a = self._metric_arrays()
        return float(np.mean(a["t_start_s"] - a["t_arrival_s"]))

    def summary(self) -> Dict[str, float]:
        """Flat scalar summary (one CSV row): per-pool utilization appears
        as ``util_<pool>`` keys, never as a nested dict."""
        out = {
            "energy_j": self.total_energy_j,
            "fleet_energy_j": self.fleet_energy_j,
            "j_per_token": self.j_per_token,
            "fleet_j_per_token": self.fleet_j_per_token,
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "p99_ttft_s": self.p99_ttft_s,
            "mean_wait_s": self.mean_wait_s,
            "mig_bytes": self.mig_bytes,
            "horizon_s": self.horizon_s,
        }
        for n, p in self.per_pool.items():
            out[f"util_{n}"] = p.utilization
        return out


# ----------------------------------------------------------------- autoscaling
@dataclass
class AutoscalerPolicy:
    """SLO-aware control loop over a pool's awake-instance count.

    Every ``period_s`` the simulator snapshots the pool and asks
    ``desired_awake``; the answer is clamped to
    [``min_instances``, ``PoolSpec.instances``], then sleeping instances are
    woken (scale-up) or drained idle instances are put to sleep
    (scale-down). Demand wake in ``_refill`` can always override a low
    target — the autoscaler shapes provisioned capacity, it never blocks
    admission of queued work."""
    period_s: float = 30.0
    min_instances: int = 1

    def desired_awake(self, snap: PoolSnapshot) -> int:
        raise NotImplementedError


@dataclass
class TargetUtilizationAutoscaler(AutoscalerPolicy):
    """Provision so current demand (busy slots + queued requests) lands at
    ``target_util`` of the awake slot capacity."""
    target_util: float = 0.6

    def desired_awake(self, snap: PoolSnapshot) -> int:
        demand = snap.busy_slots + snap.queue_len
        per_instance = max(snap.slots_per_instance * self.target_util, 1e-9)
        return int(math.ceil(demand / per_instance))


@dataclass
class QueueDepthAutoscaler(AutoscalerPolicy):
    """Hysteresis on queue depth: wake one more instance when the queue
    exceeds ``high`` requests per awake instance; sleep one when the queue
    is at most ``low`` and a whole instance's worth of slots is idle."""
    high: int = 2
    low: int = 0

    def desired_awake(self, snap: PoolSnapshot) -> int:
        awake = snap.provisioned_instances
        if snap.queue_len > self.high * max(1, awake):
            return awake + 1
        if (snap.queue_len <= self.low
                and snap.busy_slots <= (awake - 1) * snap.slots_per_instance):
            return awake - 1
        return awake


# ------------------------------------------------------------------- internals
class _Resident:
    """A request occupying one slot (and its KV blocks) of an instance."""
    __slots__ = ("rec", "phases1", "rem_tokens", "prefill_end", "_t_tok",
                 "blocks", "role")

    def __init__(self, model: CostModel, rec: RequestRecord, s: SystemProfile,
                 now: float, blocks: int = 0, role: int = ROLE_FULL):
        self.rec = rec
        self.role = role
        q = rec.query
        self.phases1 = model.phases(q.m, q.n, s, batch=1)
        # overhead + per-request prefill run before the resident joins the
        # decode group (ContinuousBatcher: prefill per-request, decode batched)
        self.prefill_end = now + self.phases1.t_overhead + self.phases1.t_prefill
        self.rem_tokens = float(q.n)
        if role == ROLE_PF:
            # prefill-only residency: completes at prefill_end, the output
            # tokens decode elsewhere after the KV handoff
            self.rem_tokens = 0.0
        elif role == ROLE_DEC:
            # decode-only residency: prefill already ran on the source pool,
            # so no prefill window (and no prefill energy) accrues here
            self.prefill_end = now
        self.blocks = blocks
        self._t_tok: Dict[int, Tuple[float, float]] = {}

    def tok_time_util(self, model: CostModel, s: SystemProfile,
                      b: int) -> Tuple[float, float]:
        """(seconds per output token, decode utilization) at occupancy b."""
        hit = self._t_tok.get(b)
        if hit is None:
            ph = model.phases(self.rec.query.m, self.rec.query.n, s, batch=b)
            hit = (ph.t_decode / max(1, self.rec.query.n), ph.util_decode)
            self._t_tok[b] = hit
        return hit


class _Instance:
    __slots__ = ("pool", "iid", "slots", "residents", "last_t", "version",
                 "busy_slot_seconds", "blocks_in_use", "state", "wake_done",
                 "empty_since", "timeline", "wake_energy_j", "n_wakes")

    def __init__(self, pool: "_PoolRuntime", iid: int, slots: int):
        self.pool = pool
        self.iid = iid
        self.slots = slots
        self.residents: List[_Resident] = []
        self.last_t = 0.0
        self.version = 0
        self.busy_slot_seconds = 0.0
        self.blocks_in_use = 0
        # power-state machine: every instance starts awake (so a fleet with
        # the machine disengaged IS the static fleet). ``timeline`` records
        # (t, state) transitions for exact idle-power integration; a
        # single-entry timeline means the instance never left AWAKE.
        self.state = AWAKE
        self.wake_done = 0.0
        self.empty_since = 0.0
        self.timeline: List[Tuple[float, str]] = [(0.0, AWAKE)]
        self.wake_energy_j = 0.0
        self.n_wakes = 0

    @property
    def free_slots(self) -> int:
        return self.slots - len(self.residents)

    # ------------------------------------------------------ power transitions
    def begin_wake(self, now: float) -> None:
        """sleep/off -> waking: charge the one-shot transition energy and
        hold the instance for the table's wake latency (idle draw is accrued
        for the window by the timeline integration)."""
        st = self.pool.spec.system.states().state(self.state)
        self.wake_done = now + st.wake_s
        self.wake_energy_j += st.wake_j
        self.n_wakes += 1
        self.state = WAKING
        self.timeline.append((now, WAKING))

    def finish_wake(self, now: float) -> None:
        self.state = AWAKE
        self.empty_since = now
        self.timeline.append((now, AWAKE))

    def go_sleep(self, now: float, state: str) -> None:
        """awake -> sleep/off. Only drained instances descend."""
        assert not self.residents and self.state == AWAKE
        self.last_t = now
        self.state = state
        self.timeline.append((now, state))

    @property
    def free_blocks(self) -> int:
        kv = self.pool.spec.kv_blocks
        return kv - self.blocks_in_use if kv else 0

    def fits(self, blocks: int) -> bool:
        return not self.pool.spec.kv_blocks or blocks <= self.free_blocks

    def advance(self, model: CostModel, now: float) -> None:
        """Progress decode/prefill state from last_t to now.

        Event scheduling guarantees no resident crosses prefill->decode
        strictly inside the interval: every prefill_end and every admission
        is itself an event boundary, so the decode batch size b is constant
        over [last_t, now].
        """
        t0, dt = self.last_t, now - self.last_t
        self.last_t = now
        if dt <= 0 or not self.residents:
            return
        self.busy_slot_seconds += len(self.residents) * dt
        decoding = [r for r in self.residents if r.prefill_end <= t0 + 1e-12]
        b = len(decoding)
        s = self.pool.spec.system
        for r in decoding:
            t_tok, util = r.tok_time_util(model, s, b)
            steps = dt / t_tok if t_tok > 0 else r.rem_tokens
            steps = min(steps, r.rem_tokens)
            r.rem_tokens -= steps
            # instance power at this resident's utilization, split across batch
            r.rec.energy_j += steps * t_tok * s.power(util) / b
            # snap float dust: a remainder whose decode time is below the
            # representable time resolution at `now` would schedule an event
            # that rounds onto `now` and never progresses (livelock)
            if r.rem_tokens * t_tok <= 4.0 * np.spacing(max(now, 1.0)):
                r.rem_tokens = 0.0
        for r in self.residents:
            if r.prefill_end > t0 + 1e-12:       # in overhead+prefill phase
                span = min(now, r.prefill_end) - t0
                if span > 0:
                    ph = r.phases1
                    t_total = ph.t_overhead + ph.t_prefill
                    # blended power over the window: overhead draws idle
                    # power, prefill draws power at util_prefill — integrates
                    # to exactly the static per-query prefill+overhead energy
                    p_w = (ph.t_overhead * s.power(0.0) + ph.t_prefill
                           * s.power(ph.util_prefill)) / max(t_total, 1e-12)
                    r.rec.energy_j += span * p_w

    def pop_finished(self, now: float) -> List[_Resident]:
        """Remove and return residents that have emitted all output tokens
        (a residual microtoken counts as done — its service time and energy
        are below float resolution at fleet time scales)."""
        done = [r for r in self.residents
                if r.rem_tokens <= 1e-6 and r.prefill_end <= now + 1e-12]
        for r in done:
            self.residents.remove(r)
            self.blocks_in_use -= r.blocks
        return done

    def next_event_time(self, model: CostModel, now: float) -> Optional[float]:
        """Earliest upcoming prefill-finish or decode completion; for the
        power machine, the wake completion (waking) or the linger deadline
        (empty + awake + finite linger). Sleeping instances are event-free
        until woken."""
        if self.state == WAKING:
            return self.wake_done
        if self.state in (SLEEP, OFF):
            return None
        if not self.residents:
            linger = self.pool.spec.linger_s
            if self.pool.power_managed and np.isfinite(linger):
                return self.empty_since + linger
            return None
        t = float("inf")
        decoding = [r for r in self.residents if r.prefill_end <= now + 1e-12]
        b = len(decoding)
        for r in self.residents:
            if r.prefill_end > now + 1e-12:
                t = min(t, r.prefill_end)
            else:
                t_tok, _ = r.tok_time_util(model, self.pool.spec.system, b)
                t = min(t, now + r.rem_tokens * t_tok)
        return t if np.isfinite(t) else None


class _PoolRuntime:
    def __init__(self, name: str, spec: PoolSpec):
        self.name = name
        self.spec = spec
        # finite linger engages the power machine; the simulator also sets
        # this for autoscaled pools. Disengaged = static-fleet behavior.
        self.power_managed = bool(np.isfinite(spec.linger_s))
        self.target_awake: Optional[int] = None   # autoscaler's current target
        self.instances = [_Instance(self, i, spec.slots)
                          for i in range(spec.instances)]
        # heap of (priority, seq, record, batch=1 service time, role)
        self.queue: List[Tuple[float, int, RequestRecord, float, int]] = []
        self.queued_service_s = 0.0      # running sum of queued service times
        self.result = PoolResult()

    def awake_like(self) -> List[_Instance]:
        """Provisioned capacity: awake plus already-waking instances."""
        return [i for i in self.instances if i.state in (AWAKE, WAKING)]

    def wake_delay(self, now: float) -> float:
        """Expected extra delay before NEW capacity could serve an arrival:
        0 with a free awake slot; else the soonest wake completion among
        waking instances, or the fastest wake latency among sleeping ones
        (a stuck arrival triggers a demand wake). 0 again when the pool has
        nothing asleep — then the only path to a slot is a completion."""
        if any(i.state == AWAKE and i.free_slots > 0 for i in self.instances):
            return 0.0
        cands = []
        for i in self.instances:
            if i.state == WAKING:
                cands.append(max(0.0, i.wake_done - now))
            elif i.state in (SLEEP, OFF):
                cands.append(self.spec.system.states().state(i.state).wake_s)
        return min(cands) if cands else 0.0

    def enqueue(self, key: float, seqno: int, rec: RequestRecord,
                service_s: float, role: int = ROLE_FULL) -> None:
        heapq.heappush(self.queue, (key, seqno, rec, service_s, role))
        self.queued_service_s += service_s

    def dequeue(self) -> Tuple[RequestRecord, int]:
        _, _, rec, service_s, role = heapq.heappop(self.queue)
        self.queued_service_s -= service_s
        return rec, role

    def snapshot(self, model: CostModel, now: float) -> PoolSnapshot:
        busy = sum(len(i.residents) for i in self.instances)
        kv = self.spec.kv_blocks
        provisioned = self.awake_like()
        # per-instance admission terms (see PoolSnapshot): a request lands on
        # ONE instance, so the admissibility signal is the most-free
        # instance's headroom, not the pool aggregate. Sleeping instances
        # COUNT: a demand wake makes their blocks reachable within
        # wake_delay_s (already folded into est_wait_s), so reporting a cold
        # pool as block-starved would double-penalize it — the mem_wait_s
        # pressure term prices ~a full service time on top of the wake.
        return PoolSnapshot(
            system=self.spec.system,
            instances=self.spec.instances,
            slots_per_instance=self.spec.slots,
            busy_slots=busy,
            queue_len=len(self.queue),
            est_wait_s=self.est_wait(model, now),
            free_blocks=max(i.free_blocks for i in self.instances) if kv else None,
            total_blocks=kv if kv else None,
            block_size=self.spec.block_size if kv else 0,
            awake_instances=len(provisioned),
            asleep_instances=self.spec.instances - len(provisioned),
            wake_delay_s=self.wake_delay(now),
        )

    def est_wait(self, model: CostModel, now: float) -> float:
        """Estimated queueing delay for a new arrival: time until the next
        slot frees, plus the queued backlog spread over the provisioned
        (awake + waking) slots. A cold pool is priced honestly: when no
        awake slot is free the wake path — a waking instance's completion,
        or the demand-wake latency of a sleeping one — competes with the
        next decode completion for ``next_free``."""
        provisioned = self.awake_like()
        total_slots = len(provisioned) * self.spec.slots
        free = sum(i.free_slots for i in provisioned if i.state == AWAKE)
        backlog = self.queued_service_s / max(1, total_slots)
        if free > 0:
            return backlog
        nxt = [i.next_event_time(model, now) for i in provisioned]
        nxt = [t for t in nxt if t is not None]
        wake = self.wake_delay(now)
        if wake > 0:
            nxt.append(now + wake)
        next_free = (min(nxt) - now) if nxt else 0.0
        return max(0.0, next_free) + backlog


# ------------------------------------------------------------------- simulator
class FleetSimulator:
    """Discrete-event simulation of a heterogeneous pool fleet under an
    online dispatch policy.

    queue_discipline: 'fifo' (arrival order) or 'sjf' (shortest expected
    service first — priority queue on the analytic batch=1 runtime).

    autoscaler: one ``AutoscalerPolicy`` applied to every pool, or a
    {pool name: policy} mapping for a subset. Autoscaled pools get CONTROL
    events at the policy's cadence; pools left out (and all pools when None)
    keep static provisioning unless their ``linger_s`` is finite.
    """

    def __init__(self, cfg: ModelConfig, pools: Dict[str, PoolSpec],
                 scheduler: Scheduler, *, queue_discipline: str = "fifo",
                 model: Optional[CostModel] = None,
                 autoscaler: Union[AutoscalerPolicy,
                                   Dict[str, AutoscalerPolicy], None] = None):
        if queue_discipline not in ("fifo", "sjf"):
            raise ValueError(f"unknown queue discipline {queue_discipline!r}")
        self.cfg = cfg
        # one pricing seam for the whole simulation: default to the policy's
        # own CostModel so simulator and scheduler price identically
        self.model = model if model is not None \
            else getattr(scheduler, "model", None) or CostModel(cfg, AnalyticOracle())
        self.pools = {n: _PoolRuntime(n, spec) for n, spec in pools.items()}
        if autoscaler is None:
            self._autoscalers: Dict[str, AutoscalerPolicy] = {}
        elif isinstance(autoscaler, dict):
            unknown = set(autoscaler) - set(pools)
            if unknown:
                raise KeyError(f"autoscaler for unknown pool(s) {sorted(unknown)}")
            self._autoscalers = dict(autoscaler)
        else:
            self._autoscalers = {n: autoscaler for n in pools}
        for name in self._autoscalers:
            self.pools[name].power_managed = True
        self.scheduler = scheduler
        self.queue_discipline = queue_discipline
        self._by_system = {spec.system.name: n for n, spec in pools.items()}
        if len(self._by_system) != len(pools):
            raise ValueError("pools must use distinct SystemProfile names: "
                             "dispatch maps a chosen system back to its pool "
                             "by name")
        self._ran = False
        self.events_processed = 0    # heap pops, incl. arrivals/stale events

    # ------------------------------------------------------------------ run
    def run(self, queries: Sequence[Query],
            policy_name: Optional[str] = None) -> FleetSimResult:
        if self._ran:
            raise RuntimeError("FleetSimulator is single-shot (instances hold "
                               "clock state); build a new one per run")
        self._ran = True
        model = self.model
        seq = itertools.count()
        events: List[Tuple[float, int, int, object]] = []
        for rid, q in enumerate(sorted(queries, key=lambda q: q.arrival_s)):
            heapq.heappush(events, (q.arrival_s, next(seq), ARRIVAL, (rid, q)))

        records: List[RequestRecord] = []
        self._horizon = 0.0
        self._arrival_times = [e[0] for e in sorted(events)]
        self._arrivals_left = len(events)

        # arm the power machine: linger timers for initially-empty instances
        # and the first control tick per autoscaled pool. Disengaged pools
        # (infinite linger, no autoscaler) schedule nothing here.
        for pool in self.pools.values():
            if pool.power_managed and np.isfinite(pool.spec.linger_s):
                for inst in pool.instances:
                    self._reschedule(inst, 0.0, events, seq)
        for name, policy in self._autoscalers.items():
            heapq.heappush(events, (policy.period_s, next(seq), CONTROL, name))

        while events:
            t, _, kind, payload = heapq.heappop(events)
            self.events_processed += 1
            if kind == ARRIVAL:
                self._arrivals_left -= 1
                rid, q = payload
                plan = self._dispatch(q, t)
                pool_sys, dec_sys, role, until_s = plan_legs(plan, q)
                pool = self.pools[self._by_system[pool_sys]]
                if dec_sys is not None:             # split: prefill here...
                    dst = self.pools[self._by_system[dec_sys]]
                    self._check_admissible(pool,
                                           pool.spec.blocks_needed_prefill(q),
                                           q)
                    self._check_admissible(dst, dst.spec.blocks_needed(q), q)
                    rec = RequestRecord(rid, q, pool.name, t_arrival=t,
                                        pool_decode=dst.name)
                else:
                    self._check_admissible(pool, pool.spec.blocks_needed(q), q)
                    rec = RequestRecord(rid, q, pool.name, t_arrival=t)
                svc = leg_service_s(model, q, pool.spec.system, role)
                records.append(rec)
                pool.result.queries += 1
                if until_s > t:                     # deferred admission
                    heapq.heappush(events, (until_s, next(seq), ADMIT,
                                            (pool, rec, svc, role)))
                else:
                    key = svc if self.queue_discipline == "sjf" else t
                    pool.enqueue(key, next(seq), rec, svc, role)
                    self._refill(pool, t, events, seq)
            elif kind == ADMIT:                     # DeferPlan clock arrived
                pool, rec, svc, role = payload
                key = svc if self.queue_discipline == "sjf" else t
                pool.enqueue(key, next(seq), rec, svc, role)
                self._refill(pool, t, events, seq)
            elif kind == INSTANCE:                  # batch-step/wake/linger
                inst, version = payload
                if version != inst.version:
                    continue                        # stale event
                inst.advance(model, t)
                if inst.state == WAKING and t >= inst.wake_done - 1e-12:
                    inst.finish_wake(t)
                self._complete(inst, t, events, seq)
                self._refill(inst.pool, t, events, seq)
                self._maybe_descend(inst, t)
                self._reschedule(inst, t, events, seq)
            elif kind == MIGRATE:                   # ...decode there
                rec = payload
                pool = self.pools[rec.pool_decode]
                q = rec.query
                svc = leg_service_s(model, q, pool.spec.system, ROLE_DEC)
                key = svc if self.queue_discipline == "sjf" else t
                pool.enqueue(key, next(seq), rec, svc, ROLE_DEC)
                self._refill(pool, t, events, seq)
            else:                                   # CONTROL autoscaler tick
                self._control(self.pools[payload], t, events, seq)

        return self._finalize(records, self._horizon,
                              policy_name or type(self.scheduler).__name__)

    # ------------------------------------------------------------- internals
    def _fleet_state(self, now: float) -> FleetState:
        return FleetState(time_s=now,
                          pools={n: p.snapshot(self.model, now)
                                 for n, p in self.pools.items()})

    def _dispatch(self, q: Query, now: float) -> Plan:
        """Route one arrival through the shared settlement seam: resolve the
        policy's return into the plan IR (legacy encodings coerce behind a
        ``DeprecationWarning``; a split for a zero-decode query degrades to
        the prefill pool — there is nothing to hand off), validate its pool
        names, then commit it to the scheduler via ``observe``."""
        plan = resolve_plan(self.scheduler.dispatch(q, self._fleet_state(now)),
                            q, self._by_system)
        self.scheduler.observe(q, plan)
        return plan

    @staticmethod
    def _check_admissible(pool: _PoolRuntime, need: int, q: Query) -> None:
        if need > pool.spec.kv_blocks > 0:
            raise ValueError(
                f"query (m={q.m}, n={q.n}) needs {need} KV blocks but "
                f"pool {pool.name!r} instances hold only "
                f"{pool.spec.kv_blocks}: it can never be admitted")

    def _complete(self, inst: _Instance, now: float, events, seq) -> None:
        done = inst.pop_finished(now)
        for r in done:
            if r.role == ROLE_PF:
                self._handoff(r.rec, inst.pool, now, events, seq)
            else:
                r.rec.t_done = now
                self._horizon = max(self._horizon, now)
        if done and not inst.residents:
            inst.empty_since = now      # linger clock starts on drain

    def _handoff(self, rec: RequestRecord, src: _PoolRuntime, now: float,
                 events, seq) -> None:
        """Prefill finished on the source pool: price the KV-block migration
        (one scalar ``migration_terms`` call — the seam shared with the
        scheduler and the vectorized engine), charge its energy to the
        request, and deliver it to the decode pool's queue after the link
        transit time via a MIGRATE event."""
        q = rec.query
        spec = src.spec
        bs = spec.block_size if spec.kv_blocks else 0
        dst = self.pools[rec.pool_decode]
        nbytes, t_mig, e_mig = migration_charge(
            self.model, q.m, spec.system, dst.spec.system, block_size=bs,
            rid=rec.rid)
        rec.energy_j += e_mig
        rec.mig_bytes = nbytes
        heapq.heappush(events, (now + t_mig, next(seq), MIGRATE, rec))

    def _refill(self, pool: _PoolRuntime, now: float, events, seq) -> None:
        """Admit queued requests into free slots (least-loaded awake
        instance); the admissibility set is re-evaluated after every
        admission — ``_complete`` on the chosen instance may have freed
        blocks only after the previous check.

        Block-capacity admission: with ``kv_blocks`` set, the head request is
        admitted only to an instance whose free blocks cover its worst-case
        context — a free slot alone is not capacity. Before the head is made
        to wait, completions due at exactly ``now`` on *other* instances are
        settled (``_settle``) so capacity freed in the same tick is used in
        the same tick; if the pool is still stuck, sleeping instances are
        demand-woken to cover the queue."""
        while pool.queue:
            head_rec, head_role = pool.queue[0][2], pool.queue[0][4]
            need = (pool.spec.blocks_needed_prefill(head_rec.query)
                    if head_role == ROLE_PF
                    else pool.spec.blocks_needed(head_rec.query))
            ready = [i for i in pool.instances
                     if i.state == AWAKE and i.free_slots > 0 and i.fits(need)]
            if not ready:
                if self._settle(pool, now, events, seq):
                    continue            # freed capacity: re-evaluate the head
                self._demand_wake(pool, now, events, seq)
                break
            inst = min(ready, key=lambda i: len(i.residents))
            rec, role = pool.dequeue()
            inst.advance(self.model, now)
            self._complete(inst, now, events, seq)
            res = _Resident(self.model, rec, pool.spec.system, now, need,
                            role=role)
            if role != ROLE_DEC:        # a DEC admission keeps the original
                rec.t_start = now       # queue-wait and TTFT anchors from
                rec.t_decode = res.prefill_end      # the prefill pool
            inst.residents.append(res)
            inst.blocks_in_use += need
            pool.result.peak_residents = max(
                pool.result.peak_residents,
                sum(len(i.residents) for i in pool.instances))
            self._reschedule(inst, now, events, seq)

    def _settle(self, pool: _PoolRuntime, now: float, events, seq) -> bool:
        """Advance + complete every resident-holding instance to ``now`` and
        report whether any slot or block freed. A completion due at exactly
        ``now`` can still sit in the event heap (same timestamp, later
        sequence number) while the head-of-line request is evaluated — its
        slots/blocks must count as capacity in this tick, not the next.
        Advancing here is exact: ``now`` is an event boundary, so no
        resident crosses prefill->decode strictly inside the interval."""
        freed = False
        for i in pool.instances:
            if not i.residents:
                continue
            before = (len(i.residents), i.blocks_in_use)
            i.advance(self.model, now)
            self._complete(i, now, events, seq)
            if (len(i.residents), i.blocks_in_use) != before:
                self._reschedule(i, now, events, seq)
                freed = True
        return freed

    def _demand_wake(self, pool: _PoolRuntime, now: float, events, seq) -> None:
        """Wake sleeping instances to cover the queue. Demand overrides the
        autoscaler target (SLO protection): the control loop shapes
        provisioned capacity, it never strands queued work. Reached only
        when no awake instance can admit the head — whether slot-bound or
        block-bound — so a block-bound stall wakes a (block-free) sleeping
        instance instead of waiting out a resident's decode."""
        if not pool.power_managed or not pool.queue:
            return
        # no awake free-slot capacity can fit the head here (that is what
        # made _refill stick), so the queue's only incoming capacity is
        # instances already waking
        incoming = sum(i.slots for i in pool.instances if i.state == WAKING)
        self._wake_sleeping(pool, len(pool.queue) - incoming, now, events, seq)

    def _wake_sleeping(self, pool: _PoolRuntime, slot_deficit: int,
                       now: float, events, seq) -> None:
        """Begin waking sleeping/off instances, fastest wake first, until
        their slots cover ``slot_deficit``."""
        if slot_deficit <= 0:
            return
        table = pool.spec.system.states()
        asleep = sorted((i for i in pool.instances if i.state in (SLEEP, OFF)),
                        key=lambda i: table.state(i.state).wake_s)
        for i in asleep:
            if slot_deficit <= 0:
                break
            i.begin_wake(now)
            self._reschedule(i, now, events, seq)
            slot_deficit -= i.slots

    def _maybe_descend(self, inst: _Instance, now: float) -> None:
        """Drained-instance descent: immediately when the pool is over its
        autoscaler target, at the linger deadline otherwise. The caller
        reschedules, which also invalidates any pending timer."""
        pool = inst.pool
        if (not pool.power_managed or inst.state != AWAKE or inst.residents
                or pool.queue):
            return
        if (pool.target_awake is not None
                and len(pool.awake_like()) > pool.target_awake):
            inst.go_sleep(now, pool.spec.sleep_state)
            return
        linger = pool.spec.linger_s
        if np.isfinite(linger) and now >= inst.empty_since + linger - 1e-12:
            inst.go_sleep(now, pool.spec.sleep_state)

    def _control(self, pool: _PoolRuntime, now: float, events, seq) -> None:
        """One autoscaler tick: clamp the policy's desired awake count to
        [min_instances, instances], wake or drain toward it, and keep
        ticking while work remains anywhere in the fleet (the loop must not
        hold the event heap open forever on an idle fleet)."""
        policy = self._autoscalers[pool.name]
        snap = pool.snapshot(self.model, now)
        lo = max(0, min(policy.min_instances, pool.spec.instances))
        target = max(lo, min(pool.spec.instances, policy.desired_awake(snap)))
        pool.target_awake = target
        awake = pool.awake_like()
        if len(awake) < target:
            self._wake_sleeping(pool, (target - len(awake)) * pool.spec.slots,
                                now, events, seq)
        elif len(awake) > target and not pool.queue:
            surplus = len(awake) - target
            idlers = sorted((i for i in awake
                             if i.state == AWAKE and not i.residents),
                            key=lambda i: i.empty_since)
            for i in idlers[:surplus]:
                i.go_sleep(now, pool.spec.sleep_state)
                self._reschedule(i, now, events, seq)
        if self._work_remaining():
            nxt = now + policy.period_s
            if not self._fleet_busy():
                # fleet fully drained, only future arrivals remain: skip the
                # empty gap instead of ticking through it (a trace with an
                # hours-long lull would otherwise cost thousands of no-op
                # snapshots)
                nxt = max(nxt, self._next_arrival_s())
            heapq.heappush(events, (nxt, next(seq), CONTROL, pool.name))

    def _fleet_busy(self) -> bool:
        return any(p.queue or any(i.residents for i in p.instances)
                   for p in self.pools.values())

    def _next_arrival_s(self) -> float:
        if self._arrivals_left <= 0:
            return 0.0
        return self._arrival_times[len(self._arrival_times)
                                   - self._arrivals_left]

    def _work_remaining(self) -> bool:
        return self._arrivals_left > 0 or self._fleet_busy()

    def _reschedule(self, inst: _Instance, now: float, events, seq) -> None:
        inst.version += 1
        nxt = inst.next_event_time(self.model, now)
        if nxt is not None:
            heapq.heappush(events, (max(nxt, now), next(seq), INSTANCE,
                                    (inst, inst.version)))

    def _finalize(self, records, horizon, policy) -> FleetSimResult:
        per_pool = {}
        for n, p in self.pools.items():
            total_slots = p.spec.instances * p.spec.slots
            busy = sum(i.busy_slot_seconds for i in p.instances)
            p.result.busy_slot_seconds = busy
            p.result.energy_j = sum(r.energy_j for r in records if r.pool == n)
            if horizon > 0:
                p.result.utilization = busy / (total_slots * horizon)
                if all(len(i.timeline) == 1 for i in p.instances):
                    # power machine never engaged: the historical pooled
                    # formula, bit-for-bit (the static-fleet equivalence
                    # invariant). Allocated-idle power per slot: instance
                    # idle power / slots.
                    idle_slot_s = total_slots * horizon - busy
                    p.result.idle_energy_j = (
                        idle_slot_s * p.spec.system.power(0.0) / p.spec.slots)
                else:
                    self._integrate_power(p, horizon)
            per_pool[n] = p.result
        return FleetSimResult(policy, records, per_pool, horizon)

    def _integrate_power(self, p: _PoolRuntime, horizon: float) -> None:
        """Exact idle-side energy over [0, horizon] from each instance's
        power-state timeline: awake/waking segments draw instance idle power
        (minus the busy share already attributed to residents), sleep/off
        segments draw the table's state power, and each wake adds its
        one-shot transition energy. Transitions after the horizon (e.g. a
        linger descent scheduled past the last completion) fall outside the
        accounting window and contribute nothing."""
        s = p.spec.system
        p_idle = s.power(0.0)
        idle_j = sleep_s = wake_j = 0.0
        wakes = 0
        for i in p.instances:
            segs = i.timeline + [(horizon, "end")]
            for (t0, st), (t1, _) in zip(segs, segs[1:]):
                dur = min(t1, horizon) - min(t0, horizon)
                if dur <= 0:
                    continue
                if st in (AWAKE, WAKING):
                    idle_j += dur * p_idle
                else:
                    idle_j += dur * s.state_power(st)
                    sleep_s += dur
            idle_j -= i.busy_slot_seconds * p_idle / p.spec.slots
            idle_j += i.wake_energy_j
            wake_j += i.wake_energy_j
            wakes += i.n_wakes
        p.result.idle_energy_j = idle_j
        p.result.sleep_s = sleep_s
        p.result.wake_energy_j = wake_j
        p.result.wake_count = wakes


FLEET_ENGINES = ("event", "vectorized")


def simulate_fleet(cfg: ModelConfig, queries: Sequence[Query],
                   pools: Optional[Dict[str, PoolSpec]] = None,
                   scheduler: Optional[Scheduler] = None, *,
                   regions: Optional[Sequence] = None,
                   queue_discipline: str = "fifo",
                   policy_name: Optional[str] = None,
                   model: Optional[CostModel] = None,
                   autoscaler: Union[AutoscalerPolicy,
                                     Dict[str, AutoscalerPolicy],
                                     None] = None,
                   engine: str = "vectorized") -> FleetSimResult:
    """One-call wrapper: build a fleet simulator and run the workload.

    Pass exactly one of ``pools`` (a flat {name: PoolSpec} fleet — the
    historical single-region form) or ``regions`` (a sequence of
    ``core.region.Region``: each a named fleet with its own carbon/price
    trace). Regions are flattened into one pool mapping with
    ``<region>/<pool>`` names (``core.region.flatten_regions``), so every
    engine, metric, and record works unchanged; a region-aware policy
    (``core.region.GlobalDispatcher``) can then route or defer across them
    through the same plan IR as any single-region scheduler.

    ``engine="vectorized"`` (the default) is the struct-of-arrays engine
    (``core.fleet_vec``), ~20-40x faster at fleet scale;
    ``engine="event"`` is the reference one-event-at-a-time loop above.
    The engines are bit-for-bit equivalent (gated by
    tests/test_fleet_vec.py and ``benchmarks/fleet_bench.py --smoke``)."""
    if (pools is None) == (regions is None):
        raise ValueError("pass exactly one of pools= or regions=")
    if scheduler is None:
        raise TypeError("simulate_fleet requires a scheduler")
    if regions is not None:
        # deferred import: region builds on this module's PoolSpec
        from repro.core.region import flatten_regions
        pools = flatten_regions(regions)
    if engine not in FLEET_ENGINES:
        raise ValueError(f"unknown engine {engine!r}; "
                         f"choose from {FLEET_ENGINES}")
    if engine == "vectorized":
        from repro.core.fleet_vec import VectorizedFleetSimulator
        return VectorizedFleetSimulator(
            cfg, pools, scheduler, queue_discipline=queue_discipline,
            model=model, autoscaler=autoscaler).run(queries, policy_name)
    return FleetSimulator(cfg, pools, scheduler,
                          queue_discipline=queue_discipline, model=model,
                          autoscaler=autoscaler).run(queries, policy_name)
