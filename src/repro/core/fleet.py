"""Discrete-event fleet simulator: time-aware evaluation of dispatch policies.

The static path (``simulator.simulate``) prices each query independently —
correct for the paper's Section 6 accounting, but blind to arrivals,
queueing, batching, and finite instance counts. This module simulates the
fleet as a discrete-event system so every ``Scheduler`` policy is compared
under identical queueing dynamics via the uniform online
``dispatch(query, fleet_state)`` API.

Event loop (heap-ordered, deterministic under a fixed workload seed):

  * **arrival**    — a query arrives; the policy dispatches it to a pool
                     (given a ``FleetState`` snapshot) and it joins the pool's
                     FIFO or priority queue.
  * **dispatch**   — a queued request is admitted to a free slot on the
                     least-loaded instance; per-request overhead + prefill
                     begin (prefill runs per-request, as in
                     ``serving.batching.ContinuousBatcher``).
  * **batch-step** — an instance's decode group advances. Decode steps are
                     shared across co-resident requests (the batcher's slot
                     model): each resident's per-token time is the priced
                     ``model.phases(..., batch=b).t_decode / n`` at the current
                     occupancy ``b``, so weight streaming amortizes across the
                     batch. The loop re-linearizes on every occupancy change
                     instead of emitting one event per token.

All pricing flows through one ``CostModel`` (``core.pricing``) — by default
the dispatch policy's own, so simulator and scheduler agree on phase times
whichever perf oracle (analytic / table / calibrated) is plugged in.
  * **completion** — a resident finishes its output tokens; the slot frees
                     and the queue refills it.

Energy accounting attributes instance power to residents (power at the
resident's utilization, split ``1/b`` across the batch), which makes the
zero-load / infinite-capacity limit reduce *exactly* to the static
``simulate()`` totals: batch=1 service reproduces ``energy(cfg, m, n, s)``
and ``runtime(cfg, m, n, s)`` term by term. Idle (allocated-but-unused)
energy over the makespan is reported separately as ``idle_energy_j`` so the
request-attributed total stays comparable to the static path.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pricing import AnalyticOracle, CostModel
from repro.core.scheduler import (FleetState, PoolSnapshot, Scheduler,
                                  kv_blocks_needed)
from repro.core.systems import SystemProfile
from repro.core.workload import Query

ARRIVAL, INSTANCE = 0, 1      # event kinds (INSTANCE = batch-step/completion)


# ------------------------------------------------------------------ fleet spec
@dataclass(frozen=True)
class PoolSpec:
    """One pool: a system profile replicated ``instances`` times, each
    instance running ``slots`` continuous-batching decode lanes.

    ``kv_blocks`` bounds each instance's KV memory in blocks of
    ``block_size`` tokens (the paged serving runtime's unit): a request is
    admitted only when its worst-case context ``ceil((m + n) / block_size)``
    fits in the instance's free blocks, so decode occupancy is bounded by
    memory, not just the slot count. 0 = unbounded (pre-paging behavior)."""
    system: SystemProfile
    instances: int = 1
    slots: int = 1
    kv_blocks: int = 0
    block_size: int = 16

    def blocks_needed(self, q: Query) -> int:
        if not self.kv_blocks:
            return 0
        return kv_blocks_needed(q.m + q.n, self.block_size)


# --------------------------------------------------------------------- records
@dataclass
class RequestRecord:
    rid: int
    query: Query
    pool: str
    t_arrival: float
    t_start: float = 0.0          # admitted to an instance (queue wait ends)
    t_decode: float = 0.0         # prefill done, decoding begins
    t_done: float = 0.0
    energy_j: float = 0.0

    @property
    def wait_s(self) -> float:
        return self.t_start - self.t_arrival

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_arrival

    @property
    def service_s(self) -> float:
        return self.t_done - self.t_start


@dataclass
class PoolResult:
    queries: int = 0
    energy_j: float = 0.0
    idle_energy_j: float = 0.0
    busy_slot_seconds: float = 0.0
    utilization: float = 0.0      # busy slot-seconds / (slots * horizon)
    peak_residents: int = 0       # max concurrent residents (occupancy bound)


@dataclass
class FleetSimResult:
    policy: str
    records: List[RequestRecord]
    per_pool: Dict[str, PoolResult]
    horizon_s: float              # last completion time

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.records)

    @property
    def idle_energy_j(self) -> float:
        return sum(p.idle_energy_j for p in self.per_pool.values())

    @property
    def fleet_energy_j(self) -> float:
        """Request-attributed + allocated-idle energy over the makespan."""
        return self.total_energy_j + self.idle_energy_j

    @property
    def tokens(self) -> int:
        return sum(r.query.m + r.query.n for r in self.records)

    @property
    def j_per_token(self) -> float:
        return self.total_energy_j / max(1, self.tokens)

    def latency_percentile(self, p: float) -> float:
        if not self.records:
            return 0.0
        return float(np.percentile([r.latency_s for r in self.records], p))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_latency_s(self) -> float:
        return self.latency_percentile(99)

    @property
    def mean_wait_s(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.wait_s for r in self.records]))

    def summary(self) -> Dict[str, float]:
        return {
            "energy_j": self.total_energy_j,
            "fleet_energy_j": self.fleet_energy_j,
            "j_per_token": self.j_per_token,
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "mean_wait_s": self.mean_wait_s,
            "horizon_s": self.horizon_s,
            "utilization": {n: p.utilization for n, p in self.per_pool.items()},
        }


# ------------------------------------------------------------------- internals
class _Resident:
    """A request occupying one slot (and its KV blocks) of an instance."""
    __slots__ = ("rec", "phases1", "rem_tokens", "prefill_end", "_t_tok",
                 "blocks")

    def __init__(self, model: CostModel, rec: RequestRecord, s: SystemProfile,
                 now: float, blocks: int = 0):
        self.rec = rec
        q = rec.query
        self.phases1 = model.phases(q.m, q.n, s, batch=1)
        # overhead + per-request prefill run before the resident joins the
        # decode group (ContinuousBatcher: prefill per-request, decode batched)
        self.prefill_end = now + self.phases1.t_overhead + self.phases1.t_prefill
        self.rem_tokens = float(q.n)
        self.blocks = blocks
        self._t_tok: Dict[int, Tuple[float, float]] = {}

    def tok_time_util(self, model: CostModel, s: SystemProfile,
                      b: int) -> Tuple[float, float]:
        """(seconds per output token, decode utilization) at occupancy b."""
        hit = self._t_tok.get(b)
        if hit is None:
            ph = model.phases(self.rec.query.m, self.rec.query.n, s, batch=b)
            hit = (ph.t_decode / max(1, self.rec.query.n), ph.util_decode)
            self._t_tok[b] = hit
        return hit


class _Instance:
    __slots__ = ("pool", "iid", "slots", "residents", "last_t", "version",
                 "busy_slot_seconds", "blocks_in_use")

    def __init__(self, pool: "_PoolRuntime", iid: int, slots: int):
        self.pool = pool
        self.iid = iid
        self.slots = slots
        self.residents: List[_Resident] = []
        self.last_t = 0.0
        self.version = 0
        self.busy_slot_seconds = 0.0
        self.blocks_in_use = 0

    @property
    def free_slots(self) -> int:
        return self.slots - len(self.residents)

    @property
    def free_blocks(self) -> int:
        kv = self.pool.spec.kv_blocks
        return kv - self.blocks_in_use if kv else 0

    def fits(self, blocks: int) -> bool:
        return not self.pool.spec.kv_blocks or blocks <= self.free_blocks

    def advance(self, model: CostModel, now: float) -> None:
        """Progress decode/prefill state from last_t to now.

        Event scheduling guarantees no resident crosses prefill->decode
        strictly inside the interval: every prefill_end and every admission
        is itself an event boundary, so the decode batch size b is constant
        over [last_t, now].
        """
        t0, dt = self.last_t, now - self.last_t
        self.last_t = now
        if dt <= 0 or not self.residents:
            return
        self.busy_slot_seconds += len(self.residents) * dt
        decoding = [r for r in self.residents if r.prefill_end <= t0 + 1e-12]
        b = len(decoding)
        s = self.pool.spec.system
        for r in decoding:
            t_tok, util = r.tok_time_util(model, s, b)
            steps = dt / t_tok if t_tok > 0 else r.rem_tokens
            steps = min(steps, r.rem_tokens)
            r.rem_tokens -= steps
            # instance power at this resident's utilization, split across batch
            r.rec.energy_j += steps * t_tok * s.power(util) / b
            # snap float dust: a remainder whose decode time is below the
            # representable time resolution at `now` would schedule an event
            # that rounds onto `now` and never progresses (livelock)
            if r.rem_tokens * t_tok <= 4.0 * np.spacing(max(now, 1.0)):
                r.rem_tokens = 0.0
        for r in self.residents:
            if r.prefill_end > t0 + 1e-12:       # in overhead+prefill phase
                span = min(now, r.prefill_end) - t0
                if span > 0:
                    ph = r.phases1
                    t_total = ph.t_overhead + ph.t_prefill
                    # blended power over the window: overhead draws idle
                    # power, prefill draws power at util_prefill — integrates
                    # to exactly the static per-query prefill+overhead energy
                    p = (ph.t_overhead * s.power(0.0) + ph.t_prefill
                         * s.power(ph.util_prefill)) / max(t_total, 1e-12)
                    r.rec.energy_j += span * p

    def pop_finished(self, now: float) -> List[_Resident]:
        """Remove and return residents that have emitted all output tokens
        (a residual microtoken counts as done — its service time and energy
        are below float resolution at fleet time scales)."""
        done = [r for r in self.residents
                if r.rem_tokens <= 1e-6 and r.prefill_end <= now + 1e-12]
        for r in done:
            self.residents.remove(r)
            self.blocks_in_use -= r.blocks
        return done

    def next_event_time(self, model: CostModel, now: float) -> Optional[float]:
        """Earliest upcoming prefill-finish or decode completion."""
        if not self.residents:
            return None
        t = float("inf")
        decoding = [r for r in self.residents if r.prefill_end <= now + 1e-12]
        b = len(decoding)
        for r in self.residents:
            if r.prefill_end > now + 1e-12:
                t = min(t, r.prefill_end)
            else:
                t_tok, _ = r.tok_time_util(model, self.pool.spec.system, b)
                t = min(t, now + r.rem_tokens * t_tok)
        return t if np.isfinite(t) else None


class _PoolRuntime:
    def __init__(self, name: str, spec: PoolSpec):
        self.name = name
        self.spec = spec
        self.instances = [_Instance(self, i, spec.slots)
                          for i in range(spec.instances)]
        # heap of (priority, seq, record, batch=1 service time)
        self.queue: List[Tuple[float, int, RequestRecord, float]] = []
        self.queued_service_s = 0.0      # running sum of queued service times
        self.result = PoolResult()

    def enqueue(self, key: float, seqno: int, rec: RequestRecord,
                service_s: float) -> None:
        heapq.heappush(self.queue, (key, seqno, rec, service_s))
        self.queued_service_s += service_s

    def dequeue(self) -> RequestRecord:
        _, _, rec, service_s = heapq.heappop(self.queue)
        self.queued_service_s -= service_s
        return rec

    def snapshot(self, model: CostModel, now: float) -> PoolSnapshot:
        busy = sum(len(i.residents) for i in self.instances)
        kv = self.spec.kv_blocks
        # per-instance admission terms (see PoolSnapshot): a request lands on
        # ONE instance, so the admissibility signal is the most-free
        # instance's headroom, not the pool aggregate
        return PoolSnapshot(
            system=self.spec.system,
            instances=self.spec.instances,
            slots_per_instance=self.spec.slots,
            busy_slots=busy,
            queue_len=len(self.queue),
            est_wait_s=self.est_wait(model, now),
            free_blocks=max(i.free_blocks for i in self.instances) if kv else None,
            total_blocks=kv if kv else None,
            block_size=self.spec.block_size if kv else 0,
        )

    def est_wait(self, model: CostModel, now: float) -> float:
        """Estimated queueing delay for a new arrival: time until the next
        slot frees, plus the queued backlog spread over all slots."""
        total_slots = self.spec.instances * self.spec.slots
        free = sum(i.free_slots for i in self.instances)
        backlog = self.queued_service_s / max(1, total_slots)
        if free > 0:
            return backlog
        nxt = [i.next_event_time(model, now) for i in self.instances]
        nxt = [t for t in nxt if t is not None]
        next_free = (min(nxt) - now) if nxt else 0.0
        return max(0.0, next_free) + backlog


# ------------------------------------------------------------------- simulator
class FleetSimulator:
    """Discrete-event simulation of a heterogeneous pool fleet under an
    online dispatch policy.

    queue_discipline: 'fifo' (arrival order) or 'sjf' (shortest expected
    service first — priority queue on the analytic batch=1 runtime).
    """

    def __init__(self, cfg: ModelConfig, pools: Dict[str, PoolSpec],
                 scheduler: Scheduler, *, queue_discipline: str = "fifo",
                 model: Optional[CostModel] = None):
        if queue_discipline not in ("fifo", "sjf"):
            raise ValueError(f"unknown queue discipline {queue_discipline!r}")
        self.cfg = cfg
        # one pricing seam for the whole simulation: default to the policy's
        # own CostModel so simulator and scheduler price identically
        self.model = model if model is not None \
            else getattr(scheduler, "model", None) or CostModel(cfg, AnalyticOracle())
        self.pools = {n: _PoolRuntime(n, spec) for n, spec in pools.items()}
        self.scheduler = scheduler
        self.queue_discipline = queue_discipline
        self._by_system = {spec.system.name: n for n, spec in pools.items()}
        if len(self._by_system) != len(pools):
            raise ValueError("pools must use distinct SystemProfile names: "
                             "dispatch maps a chosen system back to its pool "
                             "by name")
        self._ran = False

    # ------------------------------------------------------------------ run
    def run(self, queries: Sequence[Query],
            policy_name: Optional[str] = None) -> FleetSimResult:
        if self._ran:
            raise RuntimeError("FleetSimulator is single-shot (instances hold "
                               "clock state); build a new one per run")
        self._ran = True
        model = self.model
        seq = itertools.count()
        events: List[Tuple[float, int, int, object]] = []
        for rid, q in enumerate(sorted(queries, key=lambda q: q.arrival_s)):
            heapq.heappush(events, (q.arrival_s, next(seq), ARRIVAL, (rid, q)))

        records: List[RequestRecord] = []
        self._horizon = 0.0

        while events:
            t, _, kind, payload = heapq.heappop(events)
            if kind == ARRIVAL:
                rid, q = payload
                pool = self._dispatch(q, t)
                need = pool.spec.blocks_needed(q)
                if need > pool.spec.kv_blocks > 0:
                    raise ValueError(
                        f"query (m={q.m}, n={q.n}) needs {need} KV blocks but "
                        f"pool {pool.name!r} instances hold only "
                        f"{pool.spec.kv_blocks}: it can never be admitted")
                rec = RequestRecord(rid, q, pool.name, t_arrival=t)
                records.append(rec)
                pool.result.queries += 1
                svc = model.runtime(q.m, q.n, pool.spec.system)
                key = svc if self.queue_discipline == "sjf" else t
                pool.enqueue(key, next(seq), rec, svc)
                self._refill(pool, t, events, seq)
            else:                                   # INSTANCE batch-step
                inst, version = payload
                if version != inst.version:
                    continue                        # stale event
                inst.advance(model, t)
                self._complete(inst, t)
                self._refill(inst.pool, t, events, seq)
                self._reschedule(inst, t, events, seq)

        return self._finalize(records, self._horizon,
                              policy_name or type(self.scheduler).__name__)

    # ------------------------------------------------------------- internals
    def _fleet_state(self, now: float) -> FleetState:
        return FleetState(time_s=now,
                          pools={n: p.snapshot(self.model, now)
                                 for n, p in self.pools.items()})

    def _dispatch(self, q: Query, now: float) -> _PoolRuntime:
        s = self.scheduler.dispatch(q, self._fleet_state(now))
        name = self._by_system.get(s.name)
        if name is None:
            raise KeyError(f"scheduler dispatched to unknown system {s.name!r}")
        self.scheduler.observe(q, s)
        return self.pools[name]

    def _complete(self, inst: _Instance, now: float) -> None:
        for r in inst.pop_finished(now):
            r.rec.t_done = now
            self._horizon = max(self._horizon, now)

    def _refill(self, pool: _PoolRuntime, now: float, events, seq) -> None:
        """Admit queued requests into free slots (least-loaded instance).

        Block-capacity admission: with ``kv_blocks`` set, the head request is
        admitted only to an instance whose free blocks cover its worst-case
        context — a free slot alone is not capacity. The head waits otherwise
        (head-of-line, matching the paged batcher's FIFO admission)."""
        while pool.queue:
            need = pool.spec.blocks_needed(pool.queue[0][2].query)
            ready = [i for i in pool.instances
                     if i.free_slots > 0 and i.fits(need)]
            if not ready:
                break
            inst = min(ready, key=lambda i: len(i.residents))
            rec = pool.dequeue()
            inst.advance(self.model, now)
            self._complete(inst, now)
            res = _Resident(self.model, rec, pool.spec.system, now, need)
            rec.t_start = now
            rec.t_decode = res.prefill_end
            inst.residents.append(res)
            inst.blocks_in_use += need
            pool.result.peak_residents = max(
                pool.result.peak_residents,
                sum(len(i.residents) for i in pool.instances))
            self._reschedule(inst, now, events, seq)

    def _reschedule(self, inst: _Instance, now: float, events, seq) -> None:
        inst.version += 1
        nxt = inst.next_event_time(self.model, now)
        if nxt is not None:
            heapq.heappush(events, (max(nxt, now), next(seq), INSTANCE,
                                    (inst, inst.version)))

    def _finalize(self, records, horizon, policy) -> FleetSimResult:
        per_pool = {}
        for n, p in self.pools.items():
            total_slots = p.spec.instances * p.spec.slots
            busy = sum(i.busy_slot_seconds for i in p.instances)
            p.result.busy_slot_seconds = busy
            p.result.energy_j = sum(r.energy_j for r in records if r.pool == n)
            if horizon > 0:
                p.result.utilization = busy / (total_slots * horizon)
                idle_slot_s = total_slots * horizon - busy
                # allocated-idle power per slot: instance idle power / slots
                p.result.idle_energy_j = (idle_slot_s *
                                          p.spec.system.power(0.0) / p.spec.slots)
            per_pool[n] = p.result
        return FleetSimResult(policy, records, per_pool, horizon)


def simulate_fleet(cfg: ModelConfig, queries: Sequence[Query],
                   pools: Dict[str, PoolSpec], scheduler: Scheduler, *,
                   queue_discipline: str = "fifo",
                   policy_name: Optional[str] = None,
                   model: Optional[CostModel] = None) -> FleetSimResult:
    """One-call wrapper: build a FleetSimulator and run the workload."""
    return FleetSimulator(cfg, pools, scheduler,
                          queue_discipline=queue_discipline, model=model
                          ).run(queries, policy_name)
