"""Carbon-aware extension of the paper's cost function (beyond paper).

The paper optimizes joules; its related-work section (Radovanović et al.,
Chien et al.) points at *carbon*-aware computing as the real objective.
Joules are time-invariant, grams of CO2 are not: grid carbon intensity CI(t)
swings 2-4x daily. We extend Eq. 1 to

    U(m, n, s, t) = lambda * CI(t_exec) * E(m, n, s) + (1 - lambda) * R(m, n, s)

and add a scheduler that exploits the *temporal* dimension the paper leaves
on the table: deferrable queries (the paper's own "overnight batch" use case,
Section 6.3) wait for low-carbon windows; interactive ones route by the
spatial hybrid rule as before.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.energy import energy
from repro.core.perf_model import runtime
from repro.core.scheduler import Assignment, Scheduler
from repro.core.systems import SystemProfile
from repro.core.workload import Query


@dataclass(frozen=True)
class CarbonProfile:
    """Sinusoidal daily grid carbon intensity (gCO2/kWh), solar-dip shaped."""
    mean_g_per_kwh: float = 400.0
    swing: float = 0.45              # peak-to-mean fractional swing
    trough_hour: float = 13.0        # solar midday dip

    def intensity(self, t_s: float) -> float:
        hours = (t_s / 3600.0) % 24.0
        phase = 2.0 * math.pi * (hours - self.trough_hour) / 24.0
        return self.mean_g_per_kwh * (1.0 - self.swing * math.cos(phase))

    def grams(self, joules: float, t_s: float) -> float:
        return joules / 3.6e6 * self.intensity(t_s)


class CarbonAwareScheduler(Scheduler):
    """Spatial hybrid routing + temporal deferral.

    Queries with ``n > defer_out_threshold`` output tokens are treated as
    batch work (paper Section 6.3's own example) and deferred to the next
    low-carbon window (intensity below ``defer_below`` x mean); interactive
    queries run immediately on the carbon-cheapest system.
    """

    def __init__(self, cfg: ModelConfig, systems: Sequence[SystemProfile],
                 carbon: CarbonProfile = CarbonProfile(), *,
                 defer_out_threshold: int = 256, defer_below: float = 0.85,
                 max_defer_s: float = 24 * 3600.0):
        super().__init__(cfg, systems)
        self.carbon = carbon
        self.defer_out_threshold = defer_out_threshold
        self.defer_below = defer_below
        self.max_defer_s = max_defer_s

    def _next_green_window(self, t_s: float) -> float:
        target = self.carbon.mean_g_per_kwh * self.defer_below
        step = 900.0                                     # 15-min resolution
        t = t_s
        while t < t_s + self.max_defer_s:
            if self.carbon.intensity(t) <= target:
                return t
            t += step
        return t_s                                       # no window: run now

    def assign(self, queries: Sequence[Query]) -> List[Assignment]:
        out = []
        for q in queries:
            t_exec = (self._next_green_window(q.arrival_s)
                      if q.n > self.defer_out_threshold else q.arrival_s)
            best, best_g, best_e, best_r = None, float("inf"), 0.0, 0.0
            for s in self.systems:
                e = energy(self.cfg, q.m, q.n, s)
                g = self.carbon.grams(e, t_exec)
                if g < best_g:
                    best, best_g, best_e, best_r = s, g, e, runtime(
                        self.cfg, q.m, q.n, s)
            out.append(Assignment(q, best, best_e, best_r,
                                  wait_s=t_exec - q.arrival_s))
        return out


def total_grams(cfg: ModelConfig, assignments: Sequence[Assignment],
                carbon: CarbonProfile) -> float:
    return sum(carbon.grams(a.energy_j, a.query.arrival_s + a.wait_s)
               for a in assignments)
