"""Carbon-aware extension of the paper's cost function (beyond paper).

The paper optimizes joules; its related-work section (Radovanović et al.,
Chien et al.) points at *carbon*-aware computing as the real objective.
Joules are time-invariant, grams of CO2 are not: grid carbon intensity CI(t)
swings 2-4x daily. We extend Eq. 1 to

    U(m, n, s, t) = lambda * CI(t_exec) * E(m, n, s) + (1 - lambda) * R(m, n, s)

and add a scheduler that exploits the *temporal* dimension the paper leaves
on the table: deferrable queries (the paper's own "overnight batch" use case,
Section 6.3) wait for low-carbon windows; interactive ones route by the
spatial hybrid rule as before. All pricing goes through the unified
``CostModel`` (with this module's ``CarbonProfile`` attached), so swapping
the perf oracle re-prices carbon decisions too.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.plan import DeferPlan, Plan, RunPlan
from repro.core.pricing import AnalyticOracle, CostModel, CostParams
from repro.core.scheduler import Assignment, FleetState, Scheduler
from repro.core.systems import SystemProfile
from repro.core.workload import Query


@dataclass(frozen=True)
class CarbonProfile:
    """Sinusoidal daily grid carbon intensity (gCO2/kWh), solar-dip shaped."""
    mean_g_per_kwh: float = 400.0
    swing: float = 0.45              # peak-to-mean fractional swing
    trough_hour: float = 13.0        # solar midday dip

    def intensity(self, t_s: float) -> float:
        hours = (t_s / 3600.0) % 24.0
        phase = 2.0 * math.pi * (hours - self.trough_hour) / 24.0
        return self.mean_g_per_kwh * (1.0 - self.swing * math.cos(phase))

    def grams(self, joules: float, t_s: float) -> float:
        return joules / 3.6e6 * self.intensity(t_s)


def next_green_window(carbon: CarbonProfile, t_s: float, *,
                      below: float = 0.85, max_defer_s: float = 24 * 3600.0,
                      step_s: float = 900.0) -> float:
    """Earliest clock >= ``t_s`` at which ``carbon`` dips below ``below`` x
    its mean, scanned at ``step_s`` resolution; ``t_s`` itself when no window
    opens within ``max_defer_s`` (run now). Shared by the single-fleet
    ``CarbonAwareScheduler`` and the multi-region ``GlobalDispatcher``."""
    target = carbon.mean_g_per_kwh * below
    t = t_s
    while t < t_s + max_defer_s:
        if carbon.intensity(t) <= target:
            return t
        t += step_s
    return t_s


class CarbonAwareScheduler(Scheduler):
    """Spatial hybrid routing + temporal deferral.

    Queries with ``n > defer_out_threshold`` output tokens are treated as
    batch work (paper Section 6.3's own example) and deferred to the next
    low-carbon window (intensity below ``defer_below`` x mean); interactive
    queries run immediately on the carbon-cheapest system.

    Online use: ``dispatch(q, fleet_state)`` makes the same route-now vs
    defer decision against the snapshot clock (``fleet_state.time_s``) and
    plans onto the system that is carbon-cheapest at the planned execution
    time — deferrable work is thereby steered to the hardware that will be
    greenest when it actually runs. By default (``defer=False``) the query
    still enters the queue now, preserving the historical single-fleet
    behavior bit-for-bit; with ``defer=True`` dispatch wraps the placement
    in a ``DeferPlan`` so engines hold the request out of the queue until
    the green window actually opens (temporal shifting with idle-inclusive
    fleet accounting).
    """

    def __init__(self, cfg: ModelConfig, systems: Sequence[SystemProfile],
                 carbon: CarbonProfile = CarbonProfile(), *,
                 defer_out_threshold: int = 256, defer_below: float = 0.85,
                 max_defer_s: float = 24 * 3600.0, defer: bool = False,
                 model: Optional[CostModel] = None):
        if model is None:
            model = CostModel(cfg, AnalyticOracle(), CostParams(),
                              carbon=carbon)
        elif model.carbon is None:
            model = CostModel(cfg, model.oracle, model.cp, carbon=carbon,
                              quant=model.quant, memo_size=model.memo_size)
        elif carbon != CarbonProfile() and carbon != model.carbon:
            raise ValueError(
                "conflicting carbon profiles: both carbon= and a "
                "carbon-bearing model= were given and disagree; build the "
                "model with the intended CarbonProfile")
        super().__init__(cfg, systems, model=model)
        # the model's profile is authoritative: window planning (_plan) and
        # pricing (model.grams) must read the SAME carbon curve, so a model
        # passed in with its own CarbonProfile overrides the ctor default
        self.carbon = self.model.carbon
        self.defer_out_threshold = defer_out_threshold
        self.defer_below = defer_below
        self.max_defer_s = max_defer_s
        self.defer = defer

    def _next_green_window(self, t_s: float) -> float:
        return next_green_window(self.carbon, t_s, below=self.defer_below,
                                 max_defer_s=self.max_defer_s)

    def _deferrable(self, q: Query) -> bool:
        return q.n > self.defer_out_threshold

    def _plan(self, q: Query, now: float) -> float:
        """Route-now vs defer: planned execution time for ``q`` seen at
        clock ``now``."""
        return self._next_green_window(now) if self._deferrable(q) else now

    def _greenest(self, q: Query, t_exec: float) -> SystemProfile:
        return min(self.systems,
                   key=lambda s: self.model.grams(q.m, q.n, s, t_exec))

    def choose(self, q: Query) -> SystemProfile:
        """Workload-only decision at the query's own arrival clock."""
        return self._greenest(q, self._plan(q, q.arrival_s))

    def dispatch(self, q: Query, fleet: Optional[FleetState] = None) -> Plan:
        """Online dispatch against the fleet snapshot's clock: a priced
        ``RunPlan`` on the system greenest at the planned execution time —
        wrapped in a ``DeferPlan`` holding admission until the green window
        when deferral is enabled and the window is in the future."""
        now = fleet.time_s if fleet is not None else q.arrival_s
        t_exec = self._plan(q, now)
        s = self._greenest(q, t_exec)
        inner = RunPlan(s.name, self._price_terms(q, s, wait_s=t_exec - now))
        if self.defer and t_exec > now:
            return DeferPlan(until_s=t_exec, inner=inner)
        return inner

    def assign(self, queries: Sequence[Query]) -> List[Assignment]:
        out = []
        for q in queries:
            t_exec = self._plan(q, q.arrival_s)
            best = self._greenest(q, t_exec)
            out.append(Assignment(q, best,
                                  self.model.energy(q.m, q.n, best),
                                  self.model.runtime(q.m, q.n, best),
                                  wait_s=t_exec - q.arrival_s))
        return out


def total_grams(cfg: ModelConfig, assignments: Sequence[Assignment],
                carbon: CarbonProfile) -> float:
    return sum(carbon.grams(a.energy_j, a.query.arrival_s + a.wait_s)
               for a in assignments)
