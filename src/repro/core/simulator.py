"""Hybrid-datacenter simulation — reproduces the paper's Section 6 analysis.

Given a workload, a fleet, and a scheduler, computes total energy / runtime /
J-per-token, the threshold sweeps of Figs. 4-5 (with single-hardware dashed
baselines), and the headline savings number (paper: 7.5% CPU+GPU energy
reduction vs the workload-unaware baseline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.pricing import CostModel
from repro.core.scheduler import (Assignment, Scheduler, SingleSystemScheduler,
                                  ThresholdScheduler)
from repro.core.systems import SystemProfile
from repro.core.workload import Query


@dataclass(frozen=True)
class SimResult:
    policy: str
    total_energy_j: float
    total_runtime_s: float          # sum of per-query service times
    total_wait_s: float
    tokens: int
    per_system_queries: Dict[str, int]
    per_system_energy: Dict[str, float]

    @property
    def j_per_token(self) -> float:
        return self.total_energy_j / max(1, self.tokens)


def summarize(policy: str, assignments: Sequence[Assignment]) -> SimResult:
    per_q: Dict[str, int] = {}
    per_e: Dict[str, float] = {}
    te = tr = tw = 0.0
    tok = 0
    for a in assignments:
        te += a.energy_j
        tr += a.runtime_s
        tw += a.wait_s
        tok += a.query.m + a.query.n
        per_q[a.system.name] = per_q.get(a.system.name, 0) + 1
        per_e[a.system.name] = per_e.get(a.system.name, 0.0) + a.energy_j
    return SimResult(policy, te, tr, tw, tok, per_q, per_e)


def simulate(cfg: ModelConfig, queries: Sequence[Query], scheduler: Scheduler,
             policy_name: Optional[str] = None) -> SimResult:
    return summarize(policy_name or type(scheduler).__name__,
                     scheduler.assign(queries))


# ------------------------------------------------------------- threshold sweep
@dataclass(frozen=True)
class SweepPoint:
    threshold: int
    energy_j: float
    runtime_s: float


def threshold_sweep(cfg: ModelConfig, queries: Sequence[Query],
                    eff: SystemProfile, perf: SystemProfile, *,
                    axis: str = "in", thresholds: Sequence[int] = (),
                    paper_faithful: bool = True,
                    model: Optional[CostModel] = None) -> List[SweepPoint]:
    """Paper Eqs. 9-10: total energy/runtime as a function of the cutoff.

    paper_faithful=True replicates the paper's methodology exactly: the
    input-axis analysis prices every query with its *other* dimension pinned
    to the experimental constant (out=32 for Eq. 9, in=32 for Eq. 10), because
    the paper builds E_{M1,in}(m)/E_{A100,in}(m) from the vary-input
    experiment (which fixed output at 32) and vice versa.
    paper_faithful=False prices the joint (m, n) query — the "what actually
    happens end-to-end" number our beyond-paper schedulers optimize.
    """
    if not thresholds:
        hi = 512 if axis == "out" else 2048   # M1 capped at 512 output tokens
        thresholds = [t for t in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512,
                                  1024, 2048) if t <= hi]
    if paper_faithful:
        queries = [Query(q.m, 32, q.arrival_s) if axis == "in"
                   else Query(32, q.n, q.arrival_s) for q in queries]
    out = []
    for t in thresholds:
        sch = ThresholdScheduler(cfg, eff, perf, t_in=t, t_out=t, axis=axis,
                                 model=model)
        r = simulate(cfg, queries, sch, f"threshold_{axis}={t}")
        out.append(SweepPoint(t, r.total_energy_j, r.total_runtime_s))
    return out


def optimal_threshold(sweep: Sequence[SweepPoint]) -> SweepPoint:
    return min(sweep, key=lambda p: p.energy_j)


# ------------------------------------------------------------- headline claim
@dataclass(frozen=True)
class HeadlineResult:
    hybrid: SimResult
    baselines: Dict[str, SimResult]
    best_baseline: str
    savings_vs_best_baseline: float        # fraction, e.g. 0.075
    savings_vs_all_perf: float
    runtime_penalty_frac_vs_all_perf: float    # dimensionless, e.g. 0.05


def headline(cfg: ModelConfig, queries: Sequence[Query], eff: SystemProfile,
             perf: SystemProfile, *, t_in: int = 32, axis: str = "in",
             paper_faithful: bool = True,
             model: Optional[CostModel] = None) -> HeadlineResult:
    """Hybrid threshold policy vs workload-unaware baselines (paper's 7.5%).

    paper_faithful pins the counterpart token dimension to 32, replicating the
    paper's Eq. 9/10 pricing. With joint pricing (False), single-axis
    thresholds can LOSE (long outputs ride along to the efficiency pool) —
    use axis="both" or the CostOptimalScheduler there; this gap is itself a
    finding, recorded in EXPERIMENTS.md.
    """
    if paper_faithful and axis in ("in", "out"):
        queries = [Query(q.m, 32, q.arrival_s) if axis == "in"
                   else Query(32, q.n, q.arrival_s) for q in queries]
    hybrid = simulate(cfg, queries,
                      ThresholdScheduler(cfg, eff, perf, t_in=t_in, t_out=t_in,
                                         axis=axis, model=model),
                      f"hybrid_T{axis}={t_in}")
    baselines = {
        "all_perf": simulate(cfg, queries,
                             SingleSystemScheduler(cfg, perf, model=model),
                             "all_perf"),
        "all_eff": simulate(cfg, queries,
                            SingleSystemScheduler(cfg, eff, model=model),
                            "all_eff"),
    }
    best = min(baselines, key=lambda k: baselines[k].total_energy_j)
    eb = baselines[best].total_energy_j
    ep = baselines["all_perf"].total_energy_j
    rp = baselines["all_perf"].total_runtime_s
    return HeadlineResult(
        hybrid=hybrid, baselines=baselines, best_baseline=best,
        savings_vs_best_baseline=(eb - hybrid.total_energy_j) / eb,
        savings_vs_all_perf=(ep - hybrid.total_energy_j) / ep,
        runtime_penalty_frac_vs_all_perf=(hybrid.total_runtime_s - rp) / rp,
    )
