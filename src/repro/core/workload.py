"""Workload generation: Alpaca-like token-length distributions (paper Fig 3)
plus arrival processes for the discrete-event fleet simulator.

The paper uses the 52K-prompt Alpaca dataset's input/output token histograms
as the representative workload. Alpaca's measured moments: median input
~20-30 tokens with a long tail to ~1k (instruction+context), median output
~60-70 with a tail to ~600. We model both as clipped log-normals with those
moments; the distribution object also accepts arbitrary empirical histograms
so a real trace can be dropped in.

Arrival processes (all deterministic under a fixed seed):
  * poisson_arrivals   — homogeneous Poisson at rate_qps.
  * diurnal_arrivals   — nonhomogeneous Poisson with a sinusoidal rate
                         (day/night traffic), sampled by Lewis-Shedler thinning.
  * mmpp_arrivals      — 2-state Markov-modulated Poisson (bursty traffic:
                         calm/burst phases with exponential dwell times).
  * trace_arrivals     — empirical trace replay (arbitrary timestamp list).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True, slots=True)
class Query:
    m: int          # input tokens
    n: int          # output tokens
    arrival_s: float = 0.0


@dataclass(frozen=True)
class WorkloadSpec:
    mu_in: float = 3.2       # log-normal params for input tokens (median ~e^3.2=25)
    sigma_in: float = 0.95
    mu_out: float = 4.1      # median ~e^4.1=60
    sigma_out: float = 0.85
    max_in: int = 2048       # paper's measured ranges
    max_out: int = 4096
    rate_qps: float = 2.0    # arrival rate for capacity-aware scheduling


# ----------------------------------------------------------- arrival processes
def poisson_arrivals(n_queries: int, rate_qps: float, seed: int = 0) -> np.ndarray:
    """Homogeneous Poisson: iid exponential inter-arrival times."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_qps, n_queries))


def diurnal_arrivals(n_queries: int, rate_qps: float, seed: int = 0, *,
                     amplitude: float = 0.8,
                     period_s: float = 86_400.0,
                     phase: float = 0.0) -> np.ndarray:
    """Nonhomogeneous Poisson with rate(t) = rate_qps*(1 + amplitude*sin(...)).

    Lewis-Shedler thinning against the peak rate; amplitude in [0, 1) keeps
    the instantaneous rate positive. Mean rate over a full period is rate_qps.
    """
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"amplitude must be in [0, 1), got {amplitude}")
    rng = np.random.default_rng(seed)
    lam_max = rate_qps * (1.0 + amplitude)
    out = np.empty(n_queries)
    filled = 0
    t_s = 0.0
    while filled < n_queries:
        # Candidate block sized for the expected acceptance rate
        # 1/(1 + amplitude), with margin. Deterministic in (n_queries,
        # filled, amplitude), so the per-seed stream is reproducible
        # (pinned by a golden-sequence test).
        block = max(1024, int(1.25 * (n_queries - filled) * (1.0 + amplitude)))
        cand = t_s + np.cumsum(rng.exponential(1.0 / lam_max, block))
        lam_t = rate_qps * (1.0 + amplitude * np.sin(2 * np.pi * cand / period_s + phase))
        kept = cand[rng.uniform(size=block) * lam_max <= lam_t]
        take = min(kept.size, n_queries - filled)
        out[filled:filled + take] = kept[:take]
        filled += take
        t_s = float(cand[-1])
    return out


def mmpp_arrivals(n_queries: int, rate_qps: float, seed: int = 0, *,
                  burst_factor: float = 8.0,
                  burst_fraction: float = 0.1,
                  mean_dwell_s: float = 30.0) -> np.ndarray:
    """2-state MMPP: a calm state and a burst state at burst_factor x the calm
    rate. burst_fraction is the long-run fraction of time in the burst state;
    rates are chosen so the long-run mean arrival rate equals rate_qps.
    Dwell times are exponential with mean mean_dwell_s in each state (scaled
    by occupancy so the stationary split matches burst_fraction).
    """
    rng = np.random.default_rng(seed)
    # stationary: pi_burst = burst_fraction. Mean rate = pi_c*lam_c + pi_b*lam_b.
    lam_calm = rate_qps / (1.0 - burst_fraction + burst_fraction * burst_factor)
    lam_burst = burst_factor * lam_calm
    lam_max = max(lam_calm, lam_burst)
    # Exponential dwell means, scaled so the stationary split is
    # burst_fraction; the state timeline is a cumsum of alternating dwells
    # (state 0 first), and state(t) = (#switch-edges <= t) mod 2.
    dwell_means = (mean_dwell_s * 2 * (1.0 - burst_fraction),
                   mean_dwell_s * 2 * burst_fraction)
    edge_chunks: list[np.ndarray] = []
    edge_end_s = 0.0
    n_edges = 0

    def extend_edges(horizon_s: float) -> np.ndarray:
        nonlocal edge_end_s, n_edges
        while edge_end_s <= horizon_s:
            k = 256
            means = np.where((np.arange(k) + n_edges) % 2 == 0,
                             dwell_means[0], dwell_means[1])
            chunk = edge_end_s + np.cumsum(rng.exponential(1.0, k) * means)
            edge_chunks.append(chunk)
            edge_end_s = float(chunk[-1])
            n_edges += k
        return np.concatenate(edge_chunks)

    # Thin candidates drawn at lam_max against the piecewise-constant state
    # rate (exact for an MMPP). Candidate blocks sized for the expected
    # acceptance rate rate_qps/lam_max, deterministic in (n_queries, filled).
    out = np.empty(n_queries)
    filled = 0
    t_s = 0.0
    while filled < n_queries:
        block = max(1024, int(1.25 * (n_queries - filled) * lam_max / rate_qps))
        cand = t_s + np.cumsum(rng.exponential(1.0 / lam_max, block))
        edges = extend_edges(float(cand[-1]))
        burst = np.searchsorted(edges, cand, side="right") % 2 == 1
        lam_t = np.where(burst, lam_burst, lam_calm)
        kept = cand[rng.uniform(size=block) * lam_max <= lam_t]
        take = min(kept.size, n_queries - filled)
        out[filled:filled + take] = kept[:take]
        filled += take
        t_s = float(cand[-1])
    return out


def trace_arrivals(times: Sequence[float]) -> np.ndarray:
    """Empirical trace replay: validates and sorts a list of timestamps."""
    arr = np.asarray(times, dtype=float)
    if arr.ndim != 1:
        raise ValueError("trace must be a 1-D sequence of timestamps")
    if np.any(arr < 0):
        raise ValueError("trace timestamps must be non-negative")
    return np.sort(arr)


ARRIVAL_PROCESSES = ("poisson", "diurnal", "mmpp", "trace")


def generate_arrivals(n_queries: int, rate_qps: float, seed: int = 0, *,
                      process: str = "poisson",
                      trace: Optional[Sequence[float]] = None,
                      **kwargs) -> np.ndarray:
    """Dispatch to one of the named arrival processes."""
    if process == "poisson":
        return poisson_arrivals(n_queries, rate_qps, seed, **kwargs)
    if process == "diurnal":
        return diurnal_arrivals(n_queries, rate_qps, seed, **kwargs)
    if process == "mmpp":
        return mmpp_arrivals(n_queries, rate_qps, seed, **kwargs)
    if process == "trace":
        if trace is None:
            raise ValueError("process='trace' requires a trace= timestamp list")
        arr = trace_arrivals(trace)
        if len(arr) < n_queries:
            raise ValueError(f"trace has {len(arr)} stamps < {n_queries} queries")
        return arr[:n_queries]
    raise ValueError(f"unknown arrival process {process!r}; "
                     f"choose from {ARRIVAL_PROCESSES}")


def sample_workload(n_queries: int, seed: int = 0,
                    spec: WorkloadSpec = WorkloadSpec(), *,
                    arrival_process: str = "poisson",
                    trace: Optional[Sequence[float]] = None,
                    **arrival_kwargs) -> list[Query]:
    rng = np.random.default_rng(seed)
    m = np.clip(np.round(rng.lognormal(spec.mu_in, spec.sigma_in, n_queries)),
                1, spec.max_in).astype(int)
    n = np.clip(np.round(rng.lognormal(spec.mu_out, spec.sigma_out, n_queries)),
                1, spec.max_out).astype(int)
    arrivals = generate_arrivals(n_queries, spec.rate_qps, seed + 1,
                                 process=arrival_process, trace=trace,
                                 **arrival_kwargs)
    return [Query(int(mi), int(ni), float(a)) for mi, ni, a in zip(m, n, arrivals)]


def token_histogram(queries: Sequence[Query], axis: str = "in",
                    bins: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(frequencies f(x), bin_centers) — the f_in/f_out of paper Eqs. 9-10."""
    vals = np.array([q.m if axis == "in" else q.n for q in queries])
    if bins is None:
        bins = np.arange(1, vals.max() + 2)
    freq, edges = np.histogram(vals, bins=bins)
    centers = edges[:-1]
    return freq, centers


def alpaca_like(n_queries: int = 52_000, seed: int = 0) -> list[Query]:
    """The paper's evaluation workload (52K prompts)."""
    return sample_workload(n_queries, seed)
