"""Workload generation: Alpaca-like token-length distributions (paper Fig 3).

The paper uses the 52K-prompt Alpaca dataset's input/output token histograms
as the representative workload. Alpaca's measured moments: median input
~20-30 tokens with a long tail to ~1k (instruction+context), median output
~60-70 with a tail to ~600. We model both as clipped log-normals with those
moments; the distribution object also accepts arbitrary empirical histograms
so a real trace can be dropped in.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class Query:
    m: int          # input tokens
    n: int          # output tokens
    arrival_s: float = 0.0


@dataclass(frozen=True)
class WorkloadSpec:
    mu_in: float = 3.2       # log-normal params for input tokens (median ~e^3.2=25)
    sigma_in: float = 0.95
    mu_out: float = 4.1      # median ~e^4.1=60
    sigma_out: float = 0.85
    max_in: int = 2048       # paper's measured ranges
    max_out: int = 4096
    rate_qps: float = 2.0    # arrival rate for capacity-aware scheduling


def sample_workload(n_queries: int, seed: int = 0,
                    spec: WorkloadSpec = WorkloadSpec()) -> list[Query]:
    rng = np.random.default_rng(seed)
    m = np.clip(np.round(rng.lognormal(spec.mu_in, spec.sigma_in, n_queries)),
                1, spec.max_in).astype(int)
    n = np.clip(np.round(rng.lognormal(spec.mu_out, spec.sigma_out, n_queries)),
                1, spec.max_out).astype(int)
    arrivals = np.cumsum(rng.exponential(1.0 / spec.rate_qps, n_queries))
    return [Query(int(mi), int(ni), float(a)) for mi, ni, a in zip(m, n, arrivals)]


def token_histogram(queries: Sequence[Query], axis: str = "in",
                    bins: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """(frequencies f(x), bin_centers) — the f_in/f_out of paper Eqs. 9-10."""
    vals = np.array([q.m if axis == "in" else q.n for q in queries])
    if bins is None:
        bins = np.arange(1, vals.max() + 2)
    freq, edges = np.histogram(vals, bins=bins)
    centers = edges[:-1]
    return freq, centers


def alpaca_like(n_queries: int = 52_000, seed: int = 0) -> list[Query]:
    """The paper's evaluation workload (52K prompts)."""
    return sample_workload(n_queries, seed)
