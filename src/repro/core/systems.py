"""System profiles for the hybrid heterogeneous fleet.

A ``SystemProfile`` is one *serving instance*: a set of chips that together
host one model replica. Profiles carry the hardware constants the analytic
perf/energy model needs. The paper's systems (M1-Pro, A100 node, V100 node)
are included so its experiments can be replayed; the TPU classes are the
deployment target of this framework.

Power constants: vendor TDP where published, otherwise documented estimates
(marked ~). The paper's central phenomenon — an efficiency-class device with
lower J/token below a workload threshold — depends on the *ratio* of idle
power to peak and on per-query software overhead, not on exact wattages.

Power states: allocated-but-idle draw dominates fleet energy at low
utilization (Samsi et al., "From Words to Watts"), so a profile also carries
a four-state power table (``active`` / ``idle`` / ``sleep`` / ``off``) with
per-state draw, wake latency, and wake energy. The fleet simulator's
power-state machine (``core.fleet``) descends drained instances into
``sleep``/``off`` and charges the transition costs on wake; with no table
attached, ``default_power_states`` derives one from the profile's
peak/idle constants.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

POWER_STATES = ("active", "idle", "sleep", "off")


@dataclass(frozen=True)
class PowerState:
    """One row of a profile's power-state table.

    ``power_w`` is per chip (multiply by ``SystemProfile.chips`` for the
    instance draw, as with ``power_peak_w``/``power_idle_w``). ``wake_s`` /
    ``wake_j`` are the latency and one-shot energy (per *instance*) of the
    transition from this state back to ``idle``; during that window the
    instance additionally draws idle power (it is powering up), so
    ``wake_j`` is only the above-idle transition surcharge."""
    name: str
    power_w: float
    wake_s: float = 0.0
    wake_j: float = 0.0


@dataclass(frozen=True)
class PowerStateTable:
    """Per-profile ``active``/``idle``/``sleep``/``off`` table.

    ``active``/``idle`` draws must agree with the profile's
    ``power_peak_w``/``power_idle_w`` (the utilization-linear ``power()`` model
    interpolates between them); ``sleep``/``off`` are the states the fleet
    power machine can descend a drained instance into."""
    active: PowerState
    idle: PowerState
    sleep: PowerState
    off: PowerState

    def state(self, name: str) -> PowerState:
        if name not in POWER_STATES:
            raise KeyError(f"unknown power state {name!r}; "
                           f"choose from {POWER_STATES}")
        return getattr(self, name)


@dataclass(frozen=True)
class SystemProfile:
    name: str
    kind: str                 # "eff" | "perf"
    chips: int                # chips per serving instance
    peak_flops: float         # FLOP/s per chip (bf16/fp16 dense)
    hbm_bw: float             # bytes/s per chip
    ici_bw: float             # bytes/s per inter-chip link
    power_peak_w: float       # W per chip, full utilization
    power_idle_w: float       # W per chip, idle but allocated
    overhead_s: float         # per-query software overhead (tokenize/schedule/launch)
    mem_eff: float = 0.8      # achievable fraction of peak HBM bandwidth
    compute_eff: float = 0.5  # achievable fraction of peak FLOPs at B=1 inference
    # Workload-saturation constant (tokens). Efficiency-class devices degrade
    # superlinearly as the working set grows (cache thrash, unified-memory
    # contention, thermal limits): effective service time is multiplied by
    # (1 + ctx/sat_ctx). None = no degradation (datacenter parts). This models
    # the paper's Fig 1a/2a observation that the M1-Pro's runtime escalates
    # "with the most significant magnitude" and it cannot generate >512 tokens
    # without "significant runtime penalties".
    sat_ctx: Optional[float] = None
    max_out_tokens: int = 0   # advisory output cap (0 = unlimited)
    # Inter-pool migration link bandwidth (gigabits/s) from/to this instance
    # class: the DCN/PCIe path a disaggregated KV handoff rides, as opposed to
    # ``ici_bw`` (the intra-instance chip interconnect). 0.0 = no migration
    # path; the DisaggregatedScheduler never splits a query across a pool
    # pair unless both endpoints advertise a positive link bandwidth.
    link_bw_gbps: float = 0.0
    # Optional explicit power-state table; None = derive on demand from the
    # peak/idle constants (``default_power_states``). Kept Optional so every
    # pre-power-management profile (and its hash/equality) is unchanged.
    power_states: Optional[PowerStateTable] = None

    def degradation(self, ctx: float) -> float:
        if self.sat_ctx is None:
            return 1.0
        return 1.0 + ctx / self.sat_ctx

    @property
    def instance_peak_flops(self) -> float:
        return self.chips * self.peak_flops

    @property
    def instance_hbm_bw(self) -> float:
        return self.chips * self.hbm_bw

    def power(self, util: float) -> float:
        """Instance power draw (W) at compute utilization in [0, 1]."""
        util = min(max(util, 0.0), 1.0)
        return self.chips * (self.power_idle_w
                              + (self.power_peak_w - self.power_idle_w) * util)

    def states(self) -> PowerStateTable:
        """This profile's power-state table (explicit or derived)."""
        if self.power_states is not None:
            return self.power_states
        return default_power_states(self)

    def state_power(self, name: str) -> float:
        """Instance draw (W) in the named power state."""
        return self.chips * self.states().state(name).power_w


@functools.lru_cache(maxsize=None)
def default_power_states(profile: SystemProfile, *,
                         sleep_frac: float = 0.12,
                         sleep_wake_s: float = 5.0,
                         off_wake_s: float = 60.0) -> PowerStateTable:
    """Derive a power-state table from a profile's peak/idle constants
    (memoized — profiles are frozen/hashable and the fleet simulator asks
    per arrival).

    Estimates (marked ~ like the profile wattages themselves): ``sleep``
    retains ~12% of idle draw (suspended host, powered links, self-refresh
    HBM); ``off`` draws nothing but takes a full reboot to return. Wake
    energy is the above-idle surcharge of re-initializing the part, modeled
    as half the idle-to-peak gap sustained over the wake latency — the fleet
    machine separately charges idle draw for the wake window, so the table
    stays consistent whichever latency is configured."""
    idle_w, peak_w = profile.power_idle_w, profile.power_peak_w
    surge_w = 0.5 * (peak_w - idle_w) * profile.chips     # per instance
    return PowerStateTable(
        active=PowerState("active", peak_w),
        idle=PowerState("idle", idle_w),
        sleep=PowerState("sleep", sleep_frac * idle_w,
                         wake_s=sleep_wake_s, wake_j=surge_w * sleep_wake_s),
        off=PowerState("off", 0.0,
                       wake_s=off_wake_s, wake_j=surge_w * off_wake_s),
    )


# --------------------------------------------------------------------------- TPU
# v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (the repo's roofline target)
TPU_V5E_PERF = SystemProfile(
    name="tpu-v5e-perf", kind="perf", chips=4,
    peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9,
    power_peak_w=170.0,         # ~ per-chip board power under load
    power_idle_w=55.0,          # ~ allocated-idle
    overhead_s=0.04,
    link_bw_gbps=100.0,         # ~ per-host DCN NIC
)

# efficiency class: down-clocked v5e-lite-like single chip. Half clock ->
# slightly better than half peak power (voltage scaling), much lower idle.
TPU_V5LITE_EFF = SystemProfile(
    name="tpu-v5lite-eff", kind="eff", chips=1,
    peak_flops=98.5e12, hbm_bw=819e9, ici_bw=50e9,
    power_peak_w=70.0, power_idle_w=8.0,
    overhead_s=0.08,          # weaker host, slower launch path
    sat_ctx=2048.0,           # single chip: VMEM/HBM pressure at long context
    max_out_tokens=4096,
    link_bw_gbps=100.0,       # same DCN fabric as the perf class
)

# --------------------------------------------------------------------------- paper replay
M1_PRO = SystemProfile(
    name="m1-pro", kind="eff", chips=1,
    peak_flops=5.2e12,        # 14-core M1 Pro GPU fp16
    hbm_bw=200e9,             # unified memory bandwidth
    ici_bw=0.0,
    power_peak_w=30.0, power_idle_w=2.0,
    overhead_s=0.35,          # macOS + python serving stack (paper Fig 1a intercept)
    compute_eff=0.4,
    sat_ctx=10.0,             # calibrated: reproduces the paper's T*=32 optimum
                              # on BOTH axes under the Eq. 9/10 methodology
    max_out_tokens=512,       # paper: M1 "could only generate up to 512 tokens"
)

A100_NODE = SystemProfile(
    name="swing-a100", kind="perf", chips=8,   # 8x A100-40GB (paper's Swing node)
    peak_flops=312e12, hbm_bw=1555e9, ici_bw=300e9,
    power_peak_w=400.0, power_idle_w=55.0,
    overhead_s=0.06,
    link_bw_gbps=200.0,       # HDR InfiniBand host fabric
)

V100_NODE = SystemProfile(
    name="palmetto-v100", kind="perf", chips=2,  # 2x V100-16GB
    peak_flops=125e12, hbm_bw=900e9, ici_bw=150e9,
    power_peak_w=300.0, power_idle_w=45.0,
    overhead_s=0.10,
    link_bw_gbps=100.0,       # EDR InfiniBand host fabric
)

PROFILES: Dict[str, SystemProfile] = {
    p.name: p for p in
    (TPU_V5E_PERF, TPU_V5LITE_EFF, M1_PRO, A100_NODE, V100_NODE)
}


def get_profile(name: str) -> SystemProfile:
    return PROFILES[name]


def paper_fleet() -> Tuple[SystemProfile, SystemProfile]:
    """(efficiency, performance) pair the paper's Section 6 analyses."""
    return M1_PRO, A100_NODE


def tpu_fleet() -> Tuple[SystemProfile, SystemProfile]:
    """TPU-native hybrid fleet (our deployment adaptation)."""
    return TPU_V5LITE_EFF, TPU_V5E_PERF
