"""Schedulers implementing the paper's Eq. 2 partition problem.

  * ThresholdScheduler — the paper's Section 6 heuristic: route by T_in/T_out.
  * CostOptimalScheduler — exact per-query argmin_s U(m,n,s); because Eq. 2's
    objective is separable per query (no capacity coupling), this IS the
    optimal partition for fixed lambda.
  * CapacityAwareScheduler — beyond-paper: accounts for instance counts and
    queueing: the cost of a pool includes the wait until an instance frees up,
    so bursts spill to the other pool instead of queueing indefinitely.
  * Baselines — workload-unaware policies the paper compares against.

Every scheduler prices queries through one ``CostModel`` (``core.pricing``):
pass ``model=`` to swap the perf oracle (analytic / table / calibrated) for
every policy at once; the default is the analytic oracle at the scheduler's
``CostParams``, which reproduces the historical free-function pricing
bit-for-bit.

Every scheduler exposes a uniform online API used by the discrete-event
fleet simulator (``core/fleet.py``), its vectorized twin, and the serving
router:

    dispatch(query, fleet_state) -> Plan       (core.plan IR)

``fleet_state`` is a ``FleetState`` snapshot (per-pool queue depths, busy
instances, estimated wait). Workload-only policies ignore it; queue-aware
policies price the wait in. ``dispatch`` returns a placement plan —
``RunPlan`` for a single pool, ``SplitPlan`` for a prefill/decode
disaggregation, ``DeferPlan`` for a delayed admission — carrying the priced
``PlanTerms`` behind the decision; callers settle it through
``core.settlement``. (Bare ``SystemProfile`` / tuple returns from external
subclasses are coerced there for one release behind a
``DeprecationWarning``.) ``dispatch`` is pure — stateful policies
(reservation heaps, round-robin counters) mutate only in ``observe``, which
callers invoke with the resolved plan after committing to it. The legacy
offline ``assign(queries)`` path is kept for the paper's static Section 6
accounting.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import DeferPlan, Plan, PlanTerms, RunPlan, SplitPlan
from repro.core.pricing import AnalyticOracle, CostModel, CostParams
from repro.core.systems import SystemProfile
from repro.core.workload import Query


def _placed_pool_name(placed) -> Optional[str]:
    """First-leg pool (system name) of a committed placement, for ``observe``
    implementations. Accepts the plan IR and, one release behind, the legacy
    encodings (``SystemProfile`` or a profile pair)."""
    if isinstance(placed, DeferPlan):
        placed = placed.inner
    if isinstance(placed, SplitPlan):
        return placed.pool_prefill
    if isinstance(placed, RunPlan):
        return placed.pool
    if isinstance(placed, tuple) and placed:
        placed = placed[0]
    name = getattr(placed, "name", None)
    return name if isinstance(name, str) else None


@dataclass
class Assignment:
    query: Query
    system: SystemProfile
    energy_j: float
    runtime_s: float
    wait_s: float = 0.0


# ----------------------------------------------------------------- fleet state
def kv_blocks_needed(tokens: int, block_size: int) -> int:
    """ceil(tokens / block_size): worst-case KV blocks for a request whose
    total context is ``tokens``. ONE definition shared by the paged batcher
    (admission), the fleet simulator (capacity), and the schedulers
    (pricing) — these must stay bit-identical or admission desynchronizes
    from pricing."""
    return -(-tokens // block_size)


@dataclass(slots=True)
class PoolSnapshot:
    """Observable state of one pool at dispatch time."""
    system: SystemProfile
    instances: int = 1
    slots_per_instance: int = 1
    busy_slots: int = 0
    queue_len: int = 0
    est_wait_s: float = 0.0      # estimated queueing delay for a new arrival
    # KV-memory state (paged runtimes / block-capped simulator pools), in
    # PER-INSTANCE admission terms: ``free_blocks`` is the headroom of the
    # most-free single instance (a request lives on one instance, so
    # pool-aggregate free blocks overstate admissibility), ``total_blocks``
    # one instance's capacity. None / 0 means the pool reports no memory
    # constraint (slot-bound only), which keeps every pre-paging snapshot
    # producer valid unchanged.
    free_blocks: Optional[int] = None
    total_blocks: Optional[int] = None
    block_size: int = 0
    # Power-management state (energy-proportional fleets). ``awake_instances``
    # counts instances that are awake or already waking (provisioned
    # capacity); None means the pool reports no power management — every
    # instance is awake, which keeps pre-power snapshot producers valid
    # unchanged. ``wake_delay_s`` is the expected extra delay before NEW
    # capacity could serve an arrival (0 with a free awake slot); producers
    # fold it into ``est_wait_s`` as well, so queue-aware policies price a
    # cold pool honestly without double counting.
    awake_instances: Optional[int] = None
    asleep_instances: int = 0
    wake_delay_s: float = 0.0

    @property
    def total_slots(self) -> int:
        return self.instances * self.slots_per_instance

    @property
    def provisioned_instances(self) -> int:
        """Awake + waking instances; all of them absent power management."""
        return (self.awake_instances if self.awake_instances is not None
                else self.instances)

    @property
    def awake_slots(self) -> int:
        """Slot capacity that is provisioned right now (awake or waking)."""
        return self.provisioned_instances * self.slots_per_instance

    @property
    def free_slots(self) -> int:
        return max(0, self.total_slots - self.busy_slots)

    def blocks_needed(self, m: int, n: int) -> int:
        """Worst-case KV blocks for an (m, n) request; 0 if unconstrained."""
        if not self.block_size or not self.total_blocks:
            return 0
        return kv_blocks_needed(m + n, self.block_size)

    def mem_wait_s(self, m: int, n: int, runtime_s: float) -> float:
        """Extra admission delay from KV-memory pressure: when the request's
        worst-case blocks exceed the pool's free blocks, the deficit must
        drain from resident contexts first — approximated as the fraction of
        one service time proportional to the missing share of blocks."""
        needed = self.blocks_needed(m, n)
        if needed <= 0:
            return 0.0
        free = self.free_blocks or 0
        if needed <= free:
            return 0.0
        return runtime_s * (needed - free) / needed


@dataclass(slots=True)
class FleetState:
    """Snapshot handed to ``Scheduler.dispatch`` by the fleet simulator or
    the serving router. Maps pool/system name -> PoolSnapshot."""
    time_s: float = 0.0
    pools: Dict[str, PoolSnapshot] = field(default_factory=dict)

    def for_system(self, s: SystemProfile) -> Optional[PoolSnapshot]:
        for p in self.pools.values():
            if p.system.name == s.name:
                return p
        return None


class Scheduler:
    """Assigns each query to a system. Subclasses override ``choose``
    (workload-only decision) and optionally ``dispatch`` (queue-aware) and
    ``observe`` (post-commit state update)."""

    def __init__(self, cfg: ModelConfig, systems: Sequence[SystemProfile],
                 cp: CostParams = CostParams(), *,
                 model: Optional[CostModel] = None):
        self.cfg = cfg
        self.systems = list(systems)
        if model is not None and cp != CostParams() and cp != model.cp:
            raise ValueError(
                "conflicting pricing: both cp= and model= were given and "
                f"disagree ({cp} vs {model.cp}); build the model with the "
                "intended CostParams (model.with_params(cp))")
        self.model = model if model is not None \
            else CostModel(cfg, AnalyticOracle(), cp)
        self.cp = self.model.cp

    def choose(self, q: Query) -> SystemProfile:
        raise NotImplementedError

    def choose_batch(self, m, n) -> Optional[np.ndarray]:
        """Vectorized ``choose`` over aligned (m, n) token-count arrays:
        indices into ``self.systems``, elementwise identical to calling
        ``choose`` per query — or None when the policy has no batch path.

        Only meaningful for policies whose decision is (m, n)-only (both
        ``dispatch`` and ``observe`` are the base no-ops); the vectorized
        fleet engine uses it to precompute a whole workload's dispatch in
        one pass instead of snapshotting the fleet per arrival."""
        return None

    def _price_terms(self, q: Query, s: SystemProfile, *,
                     wait_s: float = 0.0,
                     cost: Optional[float] = None) -> PlanTerms:
        """Priced ``PlanTerms`` for running ``q`` on ``s`` (pure: reads the
        memoized ``CostModel`` only). Pass ``cost=`` when the Eq. 1 scalar
        was already computed during the candidate scan."""
        if cost is None:
            cost = self.model.cost(q.m, q.n, s, wait_s=wait_s)
        return PlanTerms(energy_j=self.model.energy(q.m, q.n, s),
                         runtime_s=self.model.runtime(q.m, q.n, s),
                         wait_s=wait_s, cost=cost)

    def dispatch(self, q: Query, fleet: Optional[FleetState] = None) -> Plan:
        """Online dispatch under identical queueing dynamics for every policy.
        Default: the workload-only ``choose`` rule, ignoring fleet state,
        wrapped in a priced ``RunPlan``. Must be side-effect free; state
        updates belong in ``observe``."""
        s = self.choose(q)
        return RunPlan(s.name, self._price_terms(q, s))

    def observe(self, q: Query, placed) -> None:
        """Commit hook: the caller settled ``q`` onto ``placed`` (a resolved
        ``Plan``; legacy callers may still pass a ``SystemProfile``).
        Stateful policies (reservation heaps, counters) update internal
        state here — never in ``choose``/``dispatch``."""

    def assign(self, queries: Sequence[Query]) -> List[Assignment]:
        out = []
        for q in queries:
            s = self.choose(q)
            self.observe(q, s)
            out.append(Assignment(q, s, self.model.energy(q.m, q.n, s),
                                  self.model.runtime(q.m, q.n, s)))
        return out


class ThresholdScheduler(Scheduler):
    """Paper Section 6: efficiency pool iff m <= T_in (axis='in'),
    n <= T_out (axis='out'), or both (axis='both')."""

    def __init__(self, cfg, eff: SystemProfile, perf: SystemProfile, *,
                 t_in: int = 32, t_out: int = 32, axis: str = "in",
                 cp: CostParams = CostParams(),
                 model: Optional[CostModel] = None):
        super().__init__(cfg, [eff, perf], cp, model=model)
        self.eff, self.perf = eff, perf
        self.t_in, self.t_out, self.axis = t_in, t_out, axis

    def choose(self, q: Query) -> SystemProfile:
        if self.axis == "in":
            small = q.m <= self.t_in
        elif self.axis == "out":
            small = q.n <= self.t_out
        else:
            small = q.m <= self.t_in and q.n <= self.t_out
        return self.eff if small else self.perf

    def choose_batch(self, m, n) -> np.ndarray:
        m = np.asarray(m)
        n = np.asarray(n)
        if self.axis == "in":
            small = m <= self.t_in
        elif self.axis == "out":
            small = n <= self.t_out
        else:
            small = (m <= self.t_in) & (n <= self.t_out)
        # systems == [eff, perf] (constructor order)
        return np.where(small, 0, 1)


class CostOptimalScheduler(Scheduler):
    """Per-query argmin_s U(m, n, s) — exact for the uncapacitated Eq. 2."""

    def choose(self, q: Query) -> SystemProfile:
        return min(self.systems,
                   key=lambda s: self.model.cost(q.m, q.n, s))

    def choose_batch(self, m, n) -> np.ndarray:
        # np.argmin keeps the first minimum, exactly like min() over the
        # systems list; cost_batch is elementwise bit-identical to cost()
        costs = np.stack([self.model.cost_batch(m, n, s)
                          for s in self.systems])
        return np.argmin(costs, axis=0)


@dataclass
class _Pool:
    system: SystemProfile
    free_at: List[float] = field(default_factory=list)   # heap of instance-free times


class CapacityAwareScheduler(Scheduler):
    """Beyond-paper: cost includes queueing delay given finite instance counts.

    Greedy event-driven assignment in arrival order: each pool keeps a heap of
    instance-free times; candidate cost = lam*E + (1-lam)*(wait + R).

    ``choose``/``dispatch`` are pure — they price the heap (or the fleet
    snapshot) read-only. The reservation itself happens in ``observe`` (or
    the offline ``reserve``/``assign`` path), so pricing with a snapshot and
    later falling back without one can no longer double-book instances.
    """

    def __init__(self, cfg, systems: Sequence[SystemProfile],
                 counts: Dict[str, int], cp: CostParams = CostParams(), *,
                 model: Optional[CostModel] = None):
        super().__init__(cfg, systems, cp, model=model)
        self.pools = {s.name: _Pool(s, [0.0] * counts.get(s.name, 1))
                      for s in systems}
        for p in self.pools.values():
            heapq.heapify(p.free_at)
        self._rid_cost: Dict[str, "np.ndarray"] = {}
        self._rid_runtime_s: Dict[str, "np.ndarray"] = {}
        self._rid_energy_j: Dict[str, "np.ndarray"] = {}

    def prepare_batch(self, m, n) -> None:
        """Precompute per-system wait-free cost, runtime, and energy tables
        over a whole workload's (m, n) arrays, enabling ``dispatch_rid``.
        Called by the vectorized fleet engine before its event loop."""
        for s in self.systems:
            self._rid_cost[s.name] = self.model.cost_batch(m, n, s)
            self._rid_runtime_s[s.name] = self.model.runtime_batch(m, n, s)
            self._rid_energy_j[s.name] = self.model.energy_batch(m, n, s)

    def dispatch_rid(self, rid: int, q: Query,
                     fleet: Optional[FleetState]) -> Plan:
        """Table-backed ``dispatch``: identical decision (the scalar path's
        ``cost(..., wait_s=w)`` equals the wait-free cost plus the wait term,
        in the same float association), with all per-query pricing read from
        the ``prepare_batch`` tables instead of the scalar memo."""
        if fleet is None:
            s = self.choose(q)
            return RunPlan(s.name, self._price_terms(q, s))
        cp = self.cp
        best, best_c, best_wait = None, float("inf"), 0.0
        for s in self.systems:
            snap = fleet.for_system(s)
            wait_s = snap.est_wait_s if snap is not None else 0.0
            if snap is not None:
                wait_s += snap.mem_wait_s(q.m, q.n,
                                          self._rid_runtime_s[s.name][rid])
            c = self._rid_cost[s.name][rid]
            if wait_s:
                c = c + (1.0 - cp.lam) * wait_s / cp.r_norm
            if c < best_c:
                best, best_c, best_wait = s, c, wait_s
        terms = PlanTerms(energy_j=float(self._rid_energy_j[best.name][rid]),
                          runtime_s=float(self._rid_runtime_s[best.name][rid]),
                          wait_s=best_wait, cost=float(best_c))
        return RunPlan(best.name, terms)

    def _price(self, q: Query) -> Tuple[_Pool, float, float, float]:
        """Pure pricing against the internal reservation heaps:
        (best pool, wait_s, runtime_s, energy_j). Does not mutate."""
        best, best_c, best_wait, best_r, best_e = \
            None, float("inf"), 0.0, 0.0, 0.0
        for p in self.pools.values():
            r = self.model.runtime(q.m, q.n, p.system)
            e = self.model.energy(q.m, q.n, p.system)
            wait = max(0.0, p.free_at[0] - q.arrival_s)
            c = self.model.cost(q.m, q.n, p.system, wait_s=wait)
            if c < best_c:
                best, best_c, best_wait, best_r, best_e = p, c, wait, r, e
        return best, best_wait, best_r, best_e

    def reserve(self, q: Query) -> Assignment:
        """Price AND book the chosen instance (offline assignment path)."""
        pool, wait, r, e = self._price(q)
        start = max(q.arrival_s, pool.free_at[0])
        heapq.heapreplace(pool.free_at, start + r)
        return Assignment(q, pool.system, e, r, wait)

    def choose(self, q: Query) -> SystemProfile:
        """Online single-query decision. Pure: see ``observe``."""
        return self._price(q)[0].system

    def observe(self, q: Query, placed) -> None:
        """Book the committed placement's earliest-free instance."""
        pool = self.pools.get(_placed_pool_name(placed))
        if pool is None:
            return
        start = max(q.arrival_s, pool.free_at[0])
        heapq.heapreplace(pool.free_at,
                          start + self.model.runtime(q.m, q.n, pool.system))

    def observe_rid(self, rid: int, q: Query, placed) -> None:
        """``observe`` with the booked runtime read from the ``prepare_batch``
        table (bit-identical to the scalar ``model.runtime``)."""
        pool = self.pools.get(_placed_pool_name(placed))
        if pool is None:
            return
        start = max(q.arrival_s, pool.free_at[0])
        heapq.heapreplace(pool.free_at,
                          start + self._rid_runtime_s[pool.system.name][rid])

    def dispatch(self, q: Query, fleet: Optional[FleetState] = None) -> Plan:
        """Queue-aware dispatch: price each pool's *observed* estimated wait
        (from the fleet snapshot) into the Eq. 1 cost, plus the KV-memory
        pressure term when the pool reports block occupancy — a pool with
        free slots but no free blocks is priced like a backed-up pool, so
        memory-bound pools shed load before head-of-line blocking builds.
        Power-managed pools are priced just as honestly: their snapshot's
        ``est_wait_s`` already folds in ``wake_delay_s`` (the latency of
        waking sleeping capacity), so a cold pool competes at its true
        time-to-first-token, not as if its sleeping instances were free.
        Without a snapshot the internal reservation heap is read (not
        written) for the wait."""
        if fleet is None:
            s = self.choose(q)
            return RunPlan(s.name, self._price_terms(q, s))
        best, best_c, best_wait = None, float("inf"), 0.0
        for s in self.systems:
            snap = fleet.for_system(s)
            wait = snap.est_wait_s if snap is not None else 0.0
            if snap is not None:
                wait += snap.mem_wait_s(q.m, q.n,
                                        self.model.runtime(q.m, q.n, s))
            c = self.model.cost(q.m, q.n, s, wait_s=wait)
            if c < best_c:
                best, best_c, best_wait = s, c, wait
        return RunPlan(best.name,
                       self._price_terms(q, best, wait_s=best_wait,
                                         cost=best_c))

    def assign(self, queries: Sequence[Query]) -> List[Assignment]:
        return [self.reserve(q)
                for q in sorted(queries, key=lambda q: q.arrival_s)]


class DisaggregatedScheduler(Scheduler):
    """Phase-split routing: prefill here, decode there, KV migrates between.

    The paper routes whole queries, but its own Fig 1a/2a phenomenology says
    the two phases have opposite hardware affinities — prefill is
    compute-bound, decode is memory-bound (arXiv 2407.04014, 2504.17674).
    This policy prices, per query, every single-pool assignment (identical
    pricing to ``CapacityAwareScheduler``) AND every ordered pool pair
    (a, b): prefill energy+runtime on ``a``, the priced KV-block migration
    (``CostModel.migration_terms``), decode energy+runtime on ``b``, and both
    queues' estimated waits. ``dispatch`` returns a ``RunPlan`` for a
    single-pool decision or a ``SplitPlan`` for a split — callers that
    support handoff (both fleet engines, the serving router) settle either
    through ``core.settlement``; ``choose``/``assign`` stay single-pool (a
    split is only priceable against queue state, and the offline path has
    none).

    Pairs are only considered when the query decodes (n > 0) and both
    endpoints advertise a positive ``link_bw_gbps``; zero-decode queries
    therefore never hand off. Candidates are scanned singles-first, then
    pairs in systems order, strict ``<`` — so ties go to the simpler
    single-pool plan, and the scan order is shared bit-for-bit with the
    table-backed ``dispatch_rid`` path the vectorized engine uses.
    """

    def __init__(self, cfg, systems: Sequence[SystemProfile],
                 cp: CostParams = CostParams(), *,
                 model: Optional[CostModel] = None):
        super().__init__(cfg, systems, cp, model=model)
        self._rid_cost: Dict[str, "np.ndarray"] = {}
        self._rid_runtime_s: Dict[str, "np.ndarray"] = {}
        self._rid_energy_j: Dict[str, "np.ndarray"] = {}
        self._rid_e_pf_j: Dict[str, "np.ndarray"] = {}
        self._rid_e_dec_j: Dict[str, "np.ndarray"] = {}
        self._rid_r_pf_s: Dict[str, "np.ndarray"] = {}
        self._rid_r_dec_s: Dict[str, "np.ndarray"] = {}

    def choose(self, q: Query) -> SystemProfile:
        """Workload-only fallback: best single system (no queue state, so no
        split — the migration trade is priced in ``dispatch``)."""
        return min(self.systems,
                   key=lambda s: self.model.cost(q.m, q.n, s))

    # ----------------------------------------------------------- scalar path
    def _pair_cost(self, e_pf_j: float, r_pf_s: float, e_dec_j: float,
                   r_dec_s: float, mig_s: float, mig_j: float,
                   wait_s: float) -> float:
        """Eq. 1 over a split plan. One shared float path: the event engine's
        scalar dispatch and the vectorized engine's table-backed
        ``dispatch_rid`` both come through here with the same operands."""
        cp = self.cp
        eterm = (e_pf_j + mig_j + e_dec_j) / cp.e_norm
        rterm = (r_pf_s + mig_s + r_dec_s) / cp.r_norm
        c = cp.lam * eterm + (1.0 - cp.lam) * rterm
        if wait_s:
            c = c + (1.0 - cp.lam) * wait_s / cp.r_norm
        return c

    def _pair_waits(self, q: Query, snap_a: Optional[PoolSnapshot],
                    snap_b: Optional[PoolSnapshot], r_pf_s: float,
                    r_dec_s: float) -> float:
        """Both queues' estimated waits for a split: the prefill pool is
        charged prefill-only block pressure (ceil(m/bs)); the decode pool the
        full-context pressure it will hold (ceil((m+n)/bs))."""
        wait_s = 0.0
        if snap_a is not None:
            wait_s += snap_a.est_wait_s
            wait_s += snap_a.mem_wait_s(q.m, 0, r_pf_s)
        if snap_b is not None:
            wait_s += snap_b.est_wait_s
            wait_s += snap_b.mem_wait_s(q.m, q.n, r_dec_s)
        return wait_s

    def _as_plan(self, q: Query, best, best_c: float, best_wait: float,
                 best_split) -> Plan:
        """Wrap the winning candidate of a dispatch scan: a ``SplitPlan``
        with the pair's priced components when a pair won, else a priced
        ``RunPlan``. Pure — called from ``dispatch``/``dispatch_rid``."""
        if best_split is not None:
            a, b = best
            nbytes, mig_s, mig_j, e_pf_j, r_pf_s, e_dec_j, r_dec_s = best_split
            terms = PlanTerms(energy_j=e_pf_j + mig_j + e_dec_j,
                              runtime_s=r_pf_s + mig_s + r_dec_s,
                              wait_s=best_wait, cost=best_c)
            return SplitPlan(a.name, b.name, mig_bytes=nbytes, terms=terms)
        return RunPlan(best.name,
                       self._price_terms(q, best, wait_s=best_wait,
                                         cost=best_c))

    def dispatch(self, q: Query, fleet: Optional[FleetState] = None) -> Plan:
        if fleet is None:
            s = self.choose(q)
            return RunPlan(s.name, self._price_terms(q, s))
        best, best_c, best_wait = None, float("inf"), 0.0
        best_split = None
        for s in self.systems:
            snap = fleet.for_system(s)
            wait_s = snap.est_wait_s if snap is not None else 0.0
            if snap is not None:
                wait_s += snap.mem_wait_s(q.m, q.n,
                                          self.model.runtime(q.m, q.n, s))
            c = self.model.cost(q.m, q.n, s, wait_s=wait_s)
            if c < best_c:
                best, best_c, best_wait = s, c, wait_s
        if q.n <= 0:
            return self._as_plan(q, best, best_c, best_wait, None)
        for a in self.systems:
            for b in self.systems:
                if a is b or min(a.link_bw_gbps, b.link_bw_gbps) <= 0.0:
                    continue
                snap_a = fleet.for_system(a)
                snap_b = fleet.for_system(b)
                e_pf_j, _ = self.model.split_energy(q.m, q.n, a)
                _, e_dec_j = self.model.split_energy(q.m, q.n, b)
                r_pf_s, _ = self.model.split_runtime(q.m, q.n, a)
                _, r_dec_s = self.model.split_runtime(q.m, q.n, b)
                bs = snap_a.block_size if snap_a is not None else 0
                nbytes, mig_s, mig_j = self.model.migration_terms(
                    q.m, a, b, block_size=bs)
                wait_s = self._pair_waits(q, snap_a, snap_b, r_pf_s, r_dec_s)
                c = self._pair_cost(e_pf_j, r_pf_s, e_dec_j, r_dec_s,
                                    mig_s, mig_j, wait_s)
                if c < best_c:
                    best, best_c, best_wait = (a, b), c, wait_s
                    best_split = (nbytes, mig_s, mig_j,
                                  e_pf_j, r_pf_s, e_dec_j, r_dec_s)
        return self._as_plan(q, best, best_c, best_wait, best_split)

    # ------------------------------------------------------ table-backed path
    def prepare_batch(self, m, n) -> None:
        """Precompute per-system cost/runtime and phase-split tables over the
        workload's (m, n) arrays (vectorized fleet engine)."""
        for s in self.systems:
            self._rid_cost[s.name] = self.model.cost_batch(m, n, s)
            self._rid_runtime_s[s.name] = self.model.runtime_batch(m, n, s)
            self._rid_energy_j[s.name] = self.model.energy_batch(m, n, s)
            e_pf_j, e_dec_j = self.model.split_energy_batch(m, n, s)
            r_pf_s, r_dec_s = self.model.split_runtime_batch(m, n, s)
            self._rid_e_pf_j[s.name] = e_pf_j
            self._rid_e_dec_j[s.name] = e_dec_j
            self._rid_r_pf_s[s.name] = r_pf_s
            self._rid_r_dec_s[s.name] = r_dec_s

    def dispatch_rid(self, rid: int, q: Query,
                     fleet: Optional[FleetState]) -> Plan:
        """``dispatch`` with every per-query price read from the
        ``prepare_batch`` tables (elementwise bit-identical to the scalar
        calls); the migration terms and the candidate scan are the same
        scalar code in the same order."""
        if fleet is None:
            s = self.choose(q)
            return RunPlan(s.name, self._price_terms(q, s))
        cp = self.cp
        best, best_c, best_wait = None, float("inf"), 0.0
        best_split = None
        for s in self.systems:
            snap = fleet.for_system(s)
            wait_s = snap.est_wait_s if snap is not None else 0.0
            if snap is not None:
                wait_s += snap.mem_wait_s(
                    q.m, q.n, float(self._rid_runtime_s[s.name][rid]))
            c = float(self._rid_cost[s.name][rid])
            if wait_s:
                c = c + (1.0 - cp.lam) * wait_s / cp.r_norm
            if c < best_c:
                best, best_c, best_wait = s, c, wait_s
        if q.n <= 0:
            return self._as_plan_rid(rid, q, best, best_c, best_wait, None)
        for a in self.systems:
            for b in self.systems:
                if a is b or min(a.link_bw_gbps, b.link_bw_gbps) <= 0.0:
                    continue
                snap_a = fleet.for_system(a)
                snap_b = fleet.for_system(b)
                e_pf_j = float(self._rid_e_pf_j[a.name][rid])
                e_dec_j = float(self._rid_e_dec_j[b.name][rid])
                r_pf_s = float(self._rid_r_pf_s[a.name][rid])
                r_dec_s = float(self._rid_r_dec_s[b.name][rid])
                bs = snap_a.block_size if snap_a is not None else 0
                nbytes, mig_s, mig_j = self.model.migration_terms(
                    q.m, a, b, block_size=bs)
                wait_s = self._pair_waits(q, snap_a, snap_b, r_pf_s, r_dec_s)
                c = self._pair_cost(e_pf_j, r_pf_s, e_dec_j, r_dec_s,
                                    mig_s, mig_j, wait_s)
                if c < best_c:
                    best, best_c, best_wait = (a, b), c, wait_s
                    best_split = (nbytes, mig_s, mig_j,
                                  e_pf_j, r_pf_s, e_dec_j, r_dec_s)
        return self._as_plan_rid(rid, q, best, best_c, best_wait, best_split)

    def _as_plan_rid(self, rid: int, q: Query, best, best_c: float,
                     best_wait: float, best_split) -> Plan:
        """``_as_plan`` with single-pool terms read from the ``prepare_batch``
        tables instead of the scalar memo. Pure."""
        if best_split is not None:
            return self._as_plan(q, best, best_c, best_wait, best_split)
        terms = PlanTerms(energy_j=float(self._rid_energy_j[best.name][rid]),
                          runtime_s=float(self._rid_runtime_s[best.name][rid]),
                          wait_s=best_wait, cost=best_c)
        return RunPlan(best.name, terms)


# ------------------------------------------------------------------ baselines
class SingleSystemScheduler(Scheduler):
    """Workload-unaware: everything on one system (paper's dashed lines)."""

    def __init__(self, cfg, system: SystemProfile, cp: CostParams = CostParams(),
                 *, model: Optional[CostModel] = None):
        super().__init__(cfg, [system], cp, model=model)
        self.system = system

    def choose(self, q: Query) -> SystemProfile:
        return self.system

    def choose_batch(self, m, n) -> np.ndarray:
        return np.zeros(len(np.asarray(m)), dtype=np.int64)


class RoundRobinScheduler(Scheduler):
    """Workload-unaware hybrid baseline: alternate pools ignoring (m, n)."""

    def __init__(self, cfg, systems: Sequence[SystemProfile],
                 cp: CostParams = CostParams(), *,
                 model: Optional[CostModel] = None):
        super().__init__(cfg, systems, cp, model=model)
        self._i = 0

    def choose(self, q: Query) -> SystemProfile:
        return self.systems[self._i % len(self.systems)]

    def observe(self, q: Query, placed) -> None:
        self._i += 1
