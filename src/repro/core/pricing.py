"""Unified pricing layer: pluggable ``PerfOracle`` backends behind one
``CostModel``.

Every component that prices a query — the Eq. 1 cost function, all
schedulers, the carbon extension, the discrete-event fleet simulator, and
the serving router — goes through this seam. A ``PerfOracle`` answers one
question (per-phase seconds + utilization for a query on a system); the
``CostModel`` turns phases into energy (J), runtime (s), grams of CO2, and
the paper's U(m, n, s) = lambda*E + (1-lambda)*R, with an optional
quantized-(m, n) LRU memo for simulation hot paths.

Backends:
  * ``AnalyticOracle``   — the roofline model (``perf_model.query_phases``),
                           bit-for-bit identical to the historical
                           ``energy()``/``runtime()`` free functions.
  * ``TableOracle``      — bilinear interpolation over a log-spaced (m, n)
                           grid of per-phase times; grids are precomputed
                           from another oracle or loaded from measurements.
  * ``CalibratedOracle`` — the analytic form with ``compute_eff`` /
                           ``mem_eff`` / ``sat_ctx`` / ``overhead_s`` FIT to
                           measured kernel timings (``fit_calibration``, fed
                           by ``benchmarks/microbench.kernel_phase_samples``
                           timing the real Pallas kernels). Artifacts live
                           under ``experiments/calibration/``.

Why calibration: *Offline Energy-Optimal LLM Serving* (arXiv 2407.04014) and
*Energy Considerations of LLM Inference* (arXiv 2504.17674) both find that
workload-based energy models only transfer across hardware when fit to
measured runtimes; hand-tuned roofline efficiencies do not.
"""
from __future__ import annotations

import json
import math
from collections import OrderedDict
from dataclasses import asdict, dataclass, replace
from typing import (TYPE_CHECKING, Dict, Iterable, List, Optional, Protocol,
                    Sequence, Tuple, runtime_checkable)

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.perf_model import (BatchPhases, QueryPhases, query_phases,
                                   query_phases_batch)
from repro.core.systems import SystemProfile

if TYPE_CHECKING:   # avoid a runtime cycle: carbon imports pricing
    from repro.core.carbon import CarbonProfile


@dataclass(frozen=True)
class CostParams:
    """Eq. 1 parameters (historically defined in the deleted
    ``core.cost`` module)."""
    lam: float = 1.0                     # 1.0 = pure energy (paper's Section 6)
    e_norm: float = 1.0                  # J scale
    r_norm: float = 1.0                  # s scale


# ------------------------------------------------------------------ protocol
@runtime_checkable
class PerfOracle(Protocol):
    """Answers: how long does query (m, n) take on ``system``, per phase?"""

    def phases(self, cfg: ModelConfig, m: int, n: int, system: SystemProfile,
               batch: int = 1) -> QueryPhases: ...


class AnalyticOracle:
    """The repo's roofline model, moved behind the oracle interface.

    ``phases`` delegates verbatim to ``perf_model.query_phases`` so energy
    and runtime derived from it are bit-for-bit identical to the historical
    free functions (asserted in ``tests/test_pricing.py``).
    """

    name = "analytic"

    def phases(self, cfg: ModelConfig, m: int, n: int, system: SystemProfile,
               batch: int = 1) -> QueryPhases:
        return query_phases(cfg, m, n, system, batch)

    def phases_batch(self, cfg: ModelConfig, m, n, system: SystemProfile,
                     batch: int = 1) -> BatchPhases:
        """Vectorized ``phases`` — elementwise bit-identical to the scalar path."""
        return query_phases_batch(cfg, m, n, system, batch)

    def __repr__(self) -> str:
        return "AnalyticOracle()"


# --------------------------------------------------------------- table oracle
def default_grid(lo: int = 1, hi: int = 4096) -> np.ndarray:
    """Log2-spaced token grid: lo, 2*lo, 4*lo, ... up to hi (inclusive)."""
    ks = range(int(math.floor(math.log2(max(1, lo)))),
               int(math.floor(math.log2(hi))) + 1)
    return np.array([1 << k for k in ks if lo <= (1 << k) <= hi], dtype=float)


@dataclass(frozen=True)
class PhaseTable:
    """Per-phase values sampled on an (m, n) grid for one (system, batch).

    Prefill and decode are stored *per token* (t_prefill/m, t_decode/n): both
    are near-linear in their own token count, so interpolating the per-token
    rate and rescaling is far more accurate than interpolating totals.
    """
    m_grid: np.ndarray                 # (M,) ascending
    n_grid: np.ndarray                 # (N,) ascending
    tp_tok: np.ndarray                 # (M, N) prefill seconds per input token
    td_tok: np.ndarray                 # (M, N) decode seconds per output token
    util_prefill: np.ndarray           # (M, N)
    util_decode: np.ndarray            # (M, N)
    t_overhead: float

    def _coords(self, grid: np.ndarray, x: float) -> Tuple[int, int, float]:
        """Clamped bracketing indices + interpolation weight in log space."""
        lx = math.log(max(x, 1e-12))
        lg = np.log(grid)
        if lx <= lg[0]:
            return 0, 0, 0.0
        if lx >= lg[-1]:
            return len(grid) - 1, len(grid) - 1, 0.0
        j = int(np.searchsorted(lg, lx)) - 1
        w = (lx - lg[j]) / (lg[j + 1] - lg[j])
        return j, j + 1, w

    def interp(self, m: float, n: float) -> Tuple[float, float, float, float]:
        """Bilinear (in log m, log n) -> (tp_tok, td_tok, util_pf, util_dec)."""
        i0, i1, wm = self._coords(self.m_grid, m)
        j0, j1, wn = self._coords(self.n_grid, n)

        def bil(a: np.ndarray) -> float:
            top = a[i0, j0] * (1 - wn) + a[i0, j1] * wn
            bot = a[i1, j0] * (1 - wn) + a[i1, j1] * wn
            return float(top * (1 - wm) + bot * wm)

        return (bil(self.tp_tok), bil(self.td_tok),
                bil(self.util_prefill), bil(self.util_decode))


class TableOracle:
    """Phase times by bilinear interpolation over (m, n) log-grids.

    Tables are keyed by (system name, batch) and built lazily from ``base``
    (default: the analytic oracle) — or injected via ``add_table`` when they
    come from measurements. One oracle serves one ``ModelConfig``.
    """

    name = "table"

    def __init__(self, cfg: ModelConfig, base: Optional[PerfOracle] = None, *,
                 m_grid: Optional[Sequence[float]] = None,
                 n_grid: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.base: PerfOracle = base if base is not None else AnalyticOracle()
        self.m_grid = np.asarray(m_grid if m_grid is not None
                                 else default_grid(), dtype=float)
        self.n_grid = np.asarray(n_grid if n_grid is not None
                                 else default_grid(), dtype=float)
        self._tables: Dict[Tuple[SystemProfile, int], PhaseTable] = {}
        self.version = 0        # bumped on mutation so CostModel memos refresh
        self.calibration: Optional["Calibration"] = None  # set by from_autotune

    def add_table(self, system: SystemProfile, table: PhaseTable,
                  batch: int = 1) -> None:
        self._tables[(system, batch)] = table
        self.version += 1

    @classmethod
    def from_autotune(cls, cfg: ModelConfig, system: SystemProfile, cache, *,
                      batch: int = 1,
                      m_grid: Optional[Sequence[float]] = None,
                      n_grid: Optional[Sequence[float]] = None,
                      fit_sat_ctx: bool = True) -> "TableOracle":
        """Rebuild the phase grids from autotuned kernel timings.

        ``cache`` is anything with a ``tuned_samples() -> [KernelSample]``
        method (``kernels.autotune.AutotuneCache``) or a plain sample
        sequence. The tuned timings are fit to roofline constants
        (``fit_calibration``, noise-weighted) and the (m, n) grid is built
        eagerly from the resulting ``CalibratedOracle`` — so every scheduler
        pricing through this oracle prices the kernels *as tuned*. The fit
        is exposed as ``.calibration`` for CI gating (tuned-grid pricing
        must stay within the calibration tolerance of re-measured tuned
        kernels — see ``benchmarks/autotune_sweep.py``).
        """
        samples = (cache.tuned_samples() if hasattr(cache, "tuned_samples")
                   else list(cache))
        cal = fit_calibration(system, samples, fit_sat_ctx=fit_sat_ctx)
        oracle = cls(cfg, CalibratedOracle([cal]), m_grid=m_grid,
                     n_grid=n_grid)
        oracle.add_table(system, oracle._build(system, batch), batch)
        oracle.calibration = cal
        return oracle

    def _build(self, system: SystemProfile, batch: int) -> PhaseTable:
        M, N = len(self.m_grid), len(self.n_grid)
        tp = np.zeros((M, N))
        td = np.zeros((M, N))
        up = np.zeros((M, N))
        ud = np.zeros((M, N))
        for i, m in enumerate(self.m_grid):
            for j, n in enumerate(self.n_grid):
                ph = self.base.phases(self.cfg, int(m), int(n), system, batch)
                tp[i, j] = ph.t_prefill / max(m, 1.0)
                td[i, j] = ph.t_decode / max(n, 1.0)
                up[i, j] = ph.util_prefill
                ud[i, j] = ph.util_decode
        oh = self.base.phases(self.cfg, int(self.m_grid[0]),
                              int(self.n_grid[0]), system, batch).t_overhead
        return PhaseTable(self.m_grid, self.n_grid, tp, td, up, ud, oh)

    def phases(self, cfg: ModelConfig, m: int, n: int, system: SystemProfile,
               batch: int = 1) -> QueryPhases:
        if cfg != self.cfg:
            raise ValueError(f"TableOracle built for {self.cfg.name!r}, "
                             f"asked to price {cfg.name!r} (or a same-name "
                             "variant with different dimensions)")
        key = (system, batch)
        table = self._tables.get(key)
        if table is None:
            table = self._build(system, batch)
            self._tables[key] = table
        tp_tok, td_tok, up, ud = table.interp(m, n)
        return QueryPhases(t_prefill=tp_tok * m, t_decode=td_tok * n,
                           t_overhead=table.t_overhead,
                           util_prefill=up, util_decode=ud)

    def __repr__(self) -> str:
        return (f"TableOracle(cfg={self.cfg.name!r}, "
                f"grid={len(self.m_grid)}x{len(self.n_grid)}, "
                f"tables={len(self._tables)})")


# --------------------------------------------------------- calibrated oracle
@dataclass(frozen=True)
class KernelSample:
    """One measured kernel invocation, with its analytic work counts.

    ``flops``/``bytes`` are the kernel's arithmetic and memory traffic for the
    timed shape; ``ctx`` is the context length that drives the profile's
    saturation degradation (0 for context-independent kernels such as the
    SSD scan, whose running state is constant-size).
    """
    kernel: str                 # "flash_attention" | "decode_attention" | ...
    flops: float
    bytes: float
    ctx: float
    t_s: float                  # measured wall seconds (best-of-k)
    noise_frac: float = 0.0     # (median - best) / best across the k reps


@dataclass(frozen=True)
class Calibration:
    """Fitted roofline constants for one ``SystemProfile``."""
    profile: str
    compute_eff: float
    mem_eff: float
    sat_ctx: Optional[float]
    overhead_s: float
    fit_rel_rmse: float         # sqrt(mean(((pred - t) / t)^2)) over samples
    n_samples: int
    source: str = "microbench"

    def apply(self, system: SystemProfile) -> SystemProfile:
        if system.name != self.profile:
            raise ValueError(f"calibration for {self.profile!r} applied to "
                             f"{system.name!r}")
        return replace(system, compute_eff=self.compute_eff,
                       mem_eff=self.mem_eff, sat_ctx=self.sat_ctx,
                       overhead_s=self.overhead_s)


def _predict(samples: Sequence[KernelSample], system: SystemProfile,
             ce: float, me: float, sat: Optional[float],
             overhead: float) -> np.ndarray:
    f = np.array([s.flops for s in samples])
    b = np.array([s.bytes for s in samples])
    ctx = np.array([s.ctx for s in samples])
    base = np.maximum(f / (system.instance_peak_flops * ce),
                      b / (system.instance_hbm_bw * me))
    if sat is not None:
        base = base * (1.0 + ctx / sat)
    return overhead + base


def _rel_rmse(pred: np.ndarray, t: np.ndarray) -> float:
    return float(np.sqrt(np.mean(((pred - t) / t) ** 2)))


def fit_calibration(system: SystemProfile, samples: Sequence[KernelSample], *,
                    fit_sat_ctx: bool = True,
                    refine_rounds: int = 3) -> Calibration:
    """Least-squares fit of (compute_eff, mem_eff, sat_ctx, overhead_s).

    The model ``t = overhead + max(F/(peak*ce), B/(bw*me)) * (1 + ctx/sat)``
    is nonlinear in (ce, me, sat), so those are found by a deterministic
    coarse-to-fine log-grid search; ``overhead`` has a closed form given the
    rest (weighted least squares on relative error, clipped at >= 0). The
    objective is relative RMSE, so short and long kernels weigh equally.

    Samples carrying measurement noise (``KernelSample.noise_frac`` from the
    microbench best-of-k spread) are down-weighted in the search objective by
    1/(1+noise)^2 — a noisy cell steers the fit less. The *reported*
    ``fit_rel_rmse`` stays unweighted so recovery bounds keep their meaning
    (and synthetic samples, noise 0, fit exactly as before).
    """
    if not samples:
        raise ValueError("need at least one KernelSample to calibrate")
    t = np.array([s.t_s for s in samples])
    if np.any(t <= 0):
        raise ValueError("measured times must be positive")
    noise = np.array([max(0.0, getattr(s, "noise_frac", 0.0)) for s in samples])
    wgt = 1.0 / (1.0 + noise) ** 2

    def overhead_for(ce: float, me: float, sat: Optional[float]) -> float:
        base = _predict(samples, system, ce, me, sat, 0.0)
        w = wgt / t ** 2
        return float(max(0.0, np.sum(w * (t - base)) / np.sum(w)))

    def weighted_err(pred: np.ndarray) -> float:
        r2 = ((pred - t) / t) ** 2
        return float(np.sqrt(np.sum(wgt * r2) / np.sum(wgt)))

    sat_grid: List[Optional[float]] = [None]
    if fit_sat_ctx:
        sat_grid += list(np.geomspace(32.0, 65536.0, 12))

    ce_grid = np.geomspace(1e-6, 1.0, 25)
    me_grid = np.geomspace(1e-6, 1.0, 25)
    best = (float("inf"), 1.0, 1.0, None, 0.0)
    for _ in range(1 + refine_rounds):
        for ce in ce_grid:
            for me in me_grid:
                for sat in sat_grid:
                    oh = overhead_for(ce, me, sat)
                    err = weighted_err(_predict(samples, system, ce, me, sat, oh))
                    if err < best[0]:
                        best = (err, float(ce), float(me),
                                None if sat is None else float(sat), oh)
        # refine around the incumbent (keep sat candidates incl. None)
        _, ce0, me0, sat0, _ = best
        ce_grid = np.geomspace(ce0 / 3, min(1.0, ce0 * 3), 15)
        me_grid = np.geomspace(me0 / 3, min(1.0, me0 * 3), 15)
        if fit_sat_ctx and sat0 is not None:
            sat_grid = [None] + list(np.geomspace(sat0 / 3, sat0 * 3, 9))

    _, ce, me, sat, oh = best
    err = _rel_rmse(_predict(samples, system, ce, me, sat, oh), t)
    return Calibration(profile=system.name, compute_eff=ce, mem_eff=me,
                       sat_ctx=sat, overhead_s=oh, fit_rel_rmse=err,
                       n_samples=len(samples))


class CalibratedOracle:
    """Analytic roofline with per-profile fitted constants.

    Systems without a stored calibration fall back to their hand-tuned
    constants (``strict=True`` raises instead), so one oracle can price a
    mixed fleet where only some profiles have been measured.
    """

    name = "calibrated"

    def __init__(self, calibrations: Iterable[Calibration] = (), *,
                 strict: bool = False):
        self.calibrations: Dict[str, Calibration] = {
            c.profile: c for c in calibrations}
        self.strict = strict
        self._applied: Dict[SystemProfile, SystemProfile] = {}
        self.version = 0        # bumped on mutation so CostModel memos refresh

    def add(self, calibration: Calibration) -> None:
        self.calibrations[calibration.profile] = calibration
        self._applied = {s: a for s, a in self._applied.items()
                         if s.name != calibration.profile}
        self.version += 1

    def resolve(self, system: SystemProfile) -> SystemProfile:
        cal = self.calibrations.get(system.name)
        if cal is None:
            if self.strict:
                raise KeyError(f"no calibration for profile {system.name!r}")
            return system
        hit = self._applied.get(system)
        if hit is None:
            hit = cal.apply(system)
            self._applied[system] = hit
        return hit

    def phases(self, cfg: ModelConfig, m: int, n: int, system: SystemProfile,
               batch: int = 1) -> QueryPhases:
        return query_phases(cfg, m, n, self.resolve(system), batch)

    def phases_batch(self, cfg: ModelConfig, m, n, system: SystemProfile,
                     batch: int = 1) -> BatchPhases:
        """Vectorized ``phases``: resolve the calibrated profile once, then
        evaluate the roofline over arrays (bit-identical elementwise)."""
        return query_phases_batch(cfg, m, n, self.resolve(system), batch)

    # ------------------------------------------------------------- artifacts
    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"calibrations": [asdict(c) for c in
                                        self.calibrations.values()]},
                      f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str, *, strict: bool = False) -> "CalibratedOracle":
        with open(path) as f:
            data = json.load(f)
        return cls([Calibration(**c) for c in data["calibrations"]],
                   strict=strict)

    def __repr__(self) -> str:
        return f"CalibratedOracle(profiles={sorted(self.calibrations)})"


# ------------------------------------------------------------- kv migration
def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: float = 4.0) -> float:
    """Resident KV-cache footprint per token in the paged serving pools.

    This is the *stored* state a disaggregated handoff must move (K and V for
    every layer at the pool dtype — float32 by default, matching
    ``models.model.init_paged_cache``), not the per-token *read* traffic of
    ``perf_model.kv_bytes_per_token_ctx``. Attention-free stacks keep a
    constant-size SSM state instead of per-token KV; migration for them is
    priced at the same per-token rate over the head geometry they declare.
    """
    return 2.0 * cfg.num_layers * cfg.num_kv_heads * cfg.resolved_head_dim \
        * dtype_bytes


# ---------------------------------------------------------------- cost model
class CostModel:
    """Single pricing front-end: Eq. 1 + normalizers + optional carbon term.

    ``quant`` rounds (m, n) to multiples of that many tokens before the memo
    lookup — set > 1 on simulation hot paths (fleet sweeps) to trade exact
    per-query pricing for a high cache-hit rate. The default (1) is exact, so
    every historical call path is reproduced bit-for-bit under the analytic
    oracle.
    """

    def __init__(self, cfg: ModelConfig, oracle: Optional[PerfOracle] = None,
                 cp: CostParams = CostParams(), *,
                 carbon: Optional["CarbonProfile"] = None,
                 quant: int = 1, memo_size: int = 65536):
        if quant < 1:
            raise ValueError(f"quant must be >= 1, got {quant}")
        self.cfg = cfg
        self.oracle: PerfOracle = oracle if oracle is not None else AnalyticOracle()
        self.cp = cp
        self.carbon = carbon
        self.quant = int(quant)
        self.memo_size = int(memo_size)
        # keyed by the SystemProfile OBJECT (frozen/hashable), not its name:
        # replace()-built variants sharing a name must not collide
        self._memo: "OrderedDict[Tuple[SystemProfile, int, int, int], QueryPhases]" = \
            OrderedDict()
        self._oracle_version = getattr(self.oracle, "version", 0)
        self.memo_hits = 0
        self.memo_misses = 0

    # ------------------------------------------------------------ construction
    @classmethod
    def normalized(cls, cfg: ModelConfig, ref: SystemProfile, lam: float, *,
                   oracle: Optional[PerfOracle] = None, m: int = 128,
                   n: int = 128, carbon: Optional["CarbonProfile"] = None,
                   quant: int = 1) -> "CostModel":
        """CostParams scaled so E and R are O(1) on ``ref`` at a
        representative query size — lambda becomes a true preference."""
        probe = cls(cfg, oracle)
        cp = CostParams(lam=lam,
                        e_norm=max(probe.energy(m, n, ref), 1e-9),
                        r_norm=max(probe.runtime(m, n, ref), 1e-9))
        return cls(cfg, probe.oracle, cp, carbon=carbon, quant=quant)

    def with_params(self, cp: CostParams) -> "CostModel":
        """Same oracle/memo policy, different Eq. 1 parameters."""
        return CostModel(self.cfg, self.oracle, cp, carbon=self.carbon,
                         quant=self.quant, memo_size=self.memo_size)

    # ---------------------------------------------------------------- pricing
    def _q(self, x: int) -> int:
        # Small token counts stay exact (few distinct keys anyway, and a
        # lognormal workload is densest there, where one bucket width is a
        # large *relative* perturbation); only the sparse large values are
        # bucketed, where quant/x is small.
        if self.quant == 1 or x <= 8 * self.quant:
            return int(x)
        return max(1, int(round(x / self.quant)) * self.quant)

    def phases(self, m: int, n: int, s: SystemProfile,
               batch: int = 1) -> QueryPhases:
        version = getattr(self.oracle, "version", 0)
        if version != self._oracle_version:   # oracle mutated (new tables /
            self._memo.clear()                # calibrations): drop stale phases
            self._oracle_version = version
        key = (s, self._q(m), self._q(n), batch)
        hit = self._memo.get(key)
        if hit is not None:
            self.memo_hits += 1
            self._memo.move_to_end(key)
            return hit
        self.memo_misses += 1
        ph = self.oracle.phases(self.cfg, key[1], key[2], s, batch)
        self._memo[key] = ph
        if len(self._memo) > self.memo_size:
            self._memo.popitem(last=False)
        return ph

    def _q_batch(self, x: np.ndarray) -> np.ndarray:
        """Vectorized ``_q`` (same values: numpy round is banker's, like
        Python's)."""
        x = np.asarray(x).astype(np.int64)
        if self.quant == 1:
            return x
        bucketed = np.maximum(
            1, np.round(x / self.quant).astype(np.int64) * self.quant)
        return np.where(x <= 8 * self.quant, x, bucketed)

    def price_batch(self, m, n, s: SystemProfile,
                    batch: int = 1) -> BatchPhases:
        """Vectorized ``phases`` over aligned (m, n) arrays: quantize, then
        evaluate Eq. 1's roofline terms in one numpy pass, bypassing the
        per-call LRU memo. Elementwise bit-identical to ``phases`` (asserted
        in tests/test_fleet_vec.py). Oracles without a ``phases_batch``
        method (e.g. ``TableOracle``) fall back to deduplicated scalar calls,
        which preserves bit-identity at reduced speed."""
        version = getattr(self.oracle, "version", 0)
        if version != self._oracle_version:
            self._memo.clear()
            self._oracle_version = version
        qm = self._q_batch(m)
        qn = self._q_batch(n)
        fn = getattr(self.oracle, "phases_batch", None)
        if fn is not None:
            return fn(self.cfg, qm, qn, s, batch)
        # scalar fallback: one oracle call per distinct quantized (m, n) pair
        pairs = np.stack([qm, qn], axis=1)
        uniq, inverse = np.unique(pairs, axis=0, return_inverse=True)
        fields = np.empty((5, len(uniq)), dtype=np.float64)
        for i, (um, un) in enumerate(uniq):
            ph = self.oracle.phases(self.cfg, int(um), int(un), s, batch)
            fields[:, i] = (ph.t_prefill, ph.t_decode, ph.t_overhead,
                            ph.util_prefill, ph.util_decode)
        t_pf, t_dec, t_ov, u_pf, u_dec = fields[:, inverse]
        return BatchPhases(t_prefill=t_pf, t_decode=t_dec, t_overhead=t_ov,
                           util_prefill=u_pf, util_decode=u_dec)

    def runtime_batch(self, m, n, s: SystemProfile,
                      batch: int = 1) -> np.ndarray:
        """Vectorized ``runtime`` (same association as ``QueryPhases.total``)."""
        return self.price_batch(m, n, s, batch).total

    def energy_batch(self, m, n, s: SystemProfile,
                     batch: int = 1) -> np.ndarray:
        """Vectorized ``energy`` — same accumulation order as the scalar
        path: prefill, then decode, then overhead."""
        ph = self.price_batch(m, n, s, batch)

        def power_w(util: np.ndarray) -> np.ndarray:
            u = np.minimum(np.maximum(util, 0.0), 1.0)
            return s.chips * (s.power_idle_w
                              + (s.power_peak_w - s.power_idle_w) * u)

        e_j = ph.t_prefill * power_w(ph.util_prefill)
        e_j = e_j + ph.t_decode * power_w(ph.util_decode)
        e_j = e_j + ph.t_overhead * s.power(0.0)
        return e_j

    def runtime(self, m: int, n: int, s: SystemProfile, batch: int = 1) -> float:
        """R(m, n, s) in seconds (Eq. 1's runtime term)."""
        return self.phases(m, n, s, batch).total

    def energy(self, m: int, n: int, s: SystemProfile, batch: int = 1) -> float:
        """E(m, n, s) in joules (Eq. 1's energy term)."""
        ph = self.phases(m, n, s, batch)
        e_j = ph.t_prefill * s.power(ph.util_prefill)
        e_j += ph.t_decode * s.power(ph.util_decode)
        e_j += ph.t_overhead * s.power(0.0)
        return e_j

    def cost(self, m: int, n: int, s: SystemProfile, *, batch: int = 1,
             wait_s: float = 0.0, t_exec: Optional[float] = None) -> float:
        """U = lam*E/e_norm + (1-lam)*R/r_norm, plus optional terms:

        * ``wait_s``  — queueing delay priced on the runtime side (the
          capacity-aware policies' objective);
        * ``t_exec``  — when a ``CarbonProfile`` is attached, modulates the
          energy term by CI(t_exec)/CI_mean so lambda trades *carbon*
          against runtime while the normalizers keep their meaning.
        """
        cp = self.cp
        eterm = self.energy(m, n, s, batch) / cp.e_norm
        if t_exec is not None and self.carbon is not None:
            eterm *= (self.carbon.intensity(t_exec)
                      / self.carbon.mean_g_per_kwh)
        rterm = self.runtime(m, n, s, batch) / cp.r_norm
        c = cp.lam * eterm + (1.0 - cp.lam) * rterm
        if wait_s:
            c += (1.0 - cp.lam) * wait_s / cp.r_norm
        return c

    def cost_batch(self, m, n, s: SystemProfile, *, batch: int = 1,
                   wait_s: float = 0.0,
                   t_exec: Optional[float] = None) -> np.ndarray:
        """Vectorized ``cost`` over aligned (m, n) arrays — same term order
        and association as the scalar path, so each element is bit-identical
        to the corresponding ``cost`` call."""
        cp = self.cp
        eterm = self.energy_batch(m, n, s, batch) / cp.e_norm
        if t_exec is not None and self.carbon is not None:
            eterm = eterm * (self.carbon.intensity(t_exec)
                             / self.carbon.mean_g_per_kwh)
        rterm = self.runtime_batch(m, n, s, batch) / cp.r_norm
        c = cp.lam * eterm + (1.0 - cp.lam) * rterm
        if wait_s:
            c = c + (1.0 - cp.lam) * wait_s / cp.r_norm
        return c

    def wait_cost(self, wait_s: float) -> float:
        """The runtime-side price of queueing delay alone."""
        return (1.0 - self.cp.lam) * wait_s / self.cp.r_norm

    # ------------------------------------------------------------ phase split
    def split_energy(self, m: int, n: int, s: SystemProfile,
                     batch: int = 1) -> Tuple[float, float]:
        """(prefill-side J incl. per-query overhead, decode-side J).

        The disaggregated scheduler prices the two phases on *different*
        systems; each side here uses the same power model and operand order
        as ``energy`` so a non-split query's (e_pf + e_dec) differs from
        ``energy`` only by float re-association, never by modeling."""
        ph = self.phases(m, n, s, batch)
        e_pf_j = ph.t_prefill * s.power(ph.util_prefill) \
            + ph.t_overhead * s.power(0.0)
        e_dec_j = ph.t_decode * s.power(ph.util_decode)
        return e_pf_j, e_dec_j

    def split_runtime(self, m: int, n: int, s: SystemProfile,
                      batch: int = 1) -> Tuple[float, float]:
        """(prefill-side seconds incl. overhead, decode-side seconds)."""
        ph = self.phases(m, n, s, batch)
        return ph.t_overhead + ph.t_prefill, ph.t_decode

    def split_energy_batch(self, m, n, s: SystemProfile,
                           batch: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized ``split_energy`` — elementwise bit-identical."""
        ph = self.price_batch(m, n, s, batch)

        def power_w(util: np.ndarray) -> np.ndarray:
            u = np.minimum(np.maximum(util, 0.0), 1.0)
            return s.chips * (s.power_idle_w
                              + (s.power_peak_w - s.power_idle_w) * u)

        e_pf_j = ph.t_prefill * power_w(ph.util_prefill) \
            + ph.t_overhead * s.power(0.0)
        e_dec_j = ph.t_decode * power_w(ph.util_decode)
        return e_pf_j, e_dec_j

    def split_runtime_batch(self, m, n, s: SystemProfile,
                            batch: int = 1) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized ``split_runtime`` — elementwise bit-identical."""
        ph = self.price_batch(m, n, s, batch)
        return ph.t_overhead + ph.t_prefill, ph.t_decode

    # ------------------------------------------------------------- migration
    def migration_bytes(self, m: int, *, block_size: int = 0) -> float:
        """Bytes a KV handoff of an m-token prefix moves, padded to whole
        blocks when the paged pools declare a ``block_size`` (block-table
        migration copies blocks, not tokens)."""
        tokens = -(-m // block_size) * block_size if block_size else m
        return tokens * kv_bytes_per_token(self.cfg)

    def migration_seconds(self, bytes_moved: float, src: SystemProfile,
                          dst: SystemProfile) -> float:
        """Wall seconds to move ``bytes_moved`` of KV from ``src`` to ``dst``:
        the inter-pool link transfer at the slower endpoint's advertised
        ``link_bw_gbps``, plus the device-side gather (src) and scatter (dst)
        through each endpoint's effective HBM bandwidth. Endpoints are
        resolved through the oracle's calibration when it has one, so a
        microbench KV-copy sample (``kernel == "kv_migrate"``) that refits
        ``mem_eff`` reprices the copy too. inf when either endpoint has no
        migration path."""
        link_gbps = min(src.link_bw_gbps, dst.link_bw_gbps)
        if link_gbps <= 0.0:
            return math.inf
        resolve = getattr(self.oracle, "resolve", None)
        rs = resolve(src) if resolve is not None else src
        rd = resolve(dst) if resolve is not None else dst
        t_s = bytes_moved / (link_gbps * 0.125e9)     # gigabits/s -> bytes/s
        t_s += bytes_moved / (rs.instance_hbm_bw * rs.mem_eff)
        t_s += bytes_moved / (rd.instance_hbm_bw * rd.mem_eff)
        return t_s

    def migration_energy(self, t_s: float, src: SystemProfile,
                         dst: SystemProfile) -> float:
        """J charged for a handoff window of ``t_s`` seconds: both endpoints
        held at their idle floor for the copy (conservative — the DMA engines
        draw little above idle, but the instances cannot sleep)."""
        return t_s * (src.power(0.0) + dst.power(0.0))

    def migration_terms(self, m: int, src: SystemProfile, dst: SystemProfile,
                        *, block_size: int = 0) -> Tuple[float, float, float]:
        """(bytes, seconds, joules) of migrating an m-token KV prefix.

        The single scalar path shared by the scheduler and BOTH fleet
        engines — one call site per decision keeps the engines bit-for-bit
        equivalent."""
        bytes_moved = self.migration_bytes(m, block_size=block_size)
        t_s = self.migration_seconds(bytes_moved, src, dst)
        return bytes_moved, t_s, self.migration_energy(t_s, src, dst)

    def grams(self, m: int, n: int, s: SystemProfile, t_exec: float,
              batch: int = 1) -> float:
        """gCO2 for executing (m, n) on s at time t_exec (requires carbon)."""
        if self.carbon is None:
            raise ValueError("CostModel has no CarbonProfile attached")
        return self.carbon.grams(self.energy(m, n, s, batch), t_exec)

    # ------------------------------------------------------------------ misc
    def memo_info(self) -> Dict[str, int]:
        return {"size": len(self._memo), "hits": self.memo_hits,
                "misses": self.memo_misses, "quant": self.quant}

    def clear_memo(self) -> None:
        self._memo.clear()
        self.memo_hits = self.memo_misses = 0

    def __repr__(self) -> str:
        return (f"CostModel(cfg={self.cfg.name!r}, oracle={self.oracle!r}, "
                f"lam={self.cp.lam}, quant={self.quant})")


# ----------------------------------------------------------- default pricing
_DEFAULT_MODELS: "OrderedDict[ModelConfig, CostModel]" = OrderedDict()
_DEFAULT_CACHE = 16


def default_cost_model(cfg: ModelConfig) -> CostModel:
    """Process-wide analytic CostModel per config — backs the free-function
    pricing views below (``energy``, ``cost``, ...) so they share one memo
    instead of re-deriving phases per call. Keyed by
    the (frozen, hashable) config OBJECT: ``cfg.reduced()`` keeps ``name``,
    so a name key would hand the reduced model the full model's prices."""
    model = _DEFAULT_MODELS.get(cfg)
    if model is None:
        model = CostModel(cfg, AnalyticOracle())
        _DEFAULT_MODELS[cfg] = model
        if len(_DEFAULT_MODELS) > _DEFAULT_CACHE:
            _DEFAULT_MODELS.popitem(last=False)
    else:
        _DEFAULT_MODELS.move_to_end(cfg)
    return model


# --------------------------------------------------- free-function pricing
# Folded in from the deleted ``core.cost`` / ``core.energy`` shim modules:
# thin free-function views over the shared per-config analytic CostModel
# (``default_cost_model``), bit-for-bit what those modules always returned.
# Offline analysis and the paper's Fig 1c/2c protocols use these; anything
# on a hot path should take a CostModel directly.
def cost(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
         cp: CostParams = CostParams(), batch: int = 1) -> float:
    """Eq. 1: U(m, n, s) = lam * E/e_norm + (1 - lam) * R/r_norm."""
    model = default_cost_model(cfg)
    e = model.energy(m, n, s, batch) / cp.e_norm
    r = model.runtime(m, n, s, batch) / cp.r_norm
    return cp.lam * e + (1.0 - cp.lam) * r


def normalized_cost_params(cfg: ModelConfig, ref: SystemProfile,
                           lam: float, m: int = 128, n: int = 128) -> CostParams:
    """CostParams normalized so E and R are O(1) on the reference system at a
    representative query size — makes lambda behave as a true preference."""
    model = default_cost_model(cfg)
    return CostParams(lam=lam,
                      e_norm=max(model.energy(m, n, ref), 1e-9),
                      r_norm=max(model.runtime(m, n, ref), 1e-9))


def energy(cfg: ModelConfig, m: int, n: int, s: SystemProfile,
           batch: int = 1) -> float:
    """E(m, n, s) in joules (Eq. 1's energy term)."""
    return default_cost_model(cfg).energy(m, n, s, batch)


def energy_per_token_in(cfg: ModelConfig, m: int, s: SystemProfile,
                        n_out: int = 32) -> float:
    """J/token while varying input size (paper Fig 1c protocol: out fixed 32)."""
    return energy(cfg, m, n_out, s) / max(1, m)


def energy_per_token_out(cfg: ModelConfig, n: int, s: SystemProfile,
                         m_in: int = 32) -> float:
    """J/token while varying output size (paper Fig 2c protocol: in fixed 32)."""
    return energy(cfg, m_in, n, s) / max(1, n)


def crossover_threshold(cfg: ModelConfig, eff: SystemProfile, perf: SystemProfile,
                        *, axis: str = "in", lo: int = 1, hi: int = 4096) -> int:
    """Smallest token count where the performance system's J/token drops below
    the efficiency system's (the quantity the paper's T_in/T_out estimate)."""
    fn = energy_per_token_in if axis == "in" else energy_per_token_out
    for t in range(lo, hi + 1):
        if fn(cfg, t, perf) < fn(cfg, t, eff):
            return t
    return hi
