"""Shared plan settlement: one booking path for engines and the live router.

Before this module, the interpretation of a scheduler's placement decision
was hand-duplicated three times — the event engine (``core.fleet``), the
vectorized engine (``core.fleet_vec``), and the live router
(``serving.router``) each re-derived "which pool, which role, what service
time, what migration charge" from ad-hoc returns. This module is the single
settlement seam they all call:

  * ``resolve_plan``   — coerce/validate a ``dispatch`` return into the plan
                         IR (legacy ``SystemProfile`` / tuple returns get a
                         ``DeprecationWarning`` shim for one release);
  * ``plan_legs``      — structural decomposition (first-leg pool, decode
                         pool, enqueue role, admission clock);
  * ``leg_service_s``  — the priced service time for a leg's role;
  * ``migration_charge`` — the KV-prefix migration bytes/seconds/joules with
                         the no-path guard both engines must raise;
  * ``route_bookings`` / ``reconcile_deltas`` / ``reconcile_split_deltas``
                       — the router's expectation-booking rows and EOS
                         reconciliation deltas.

Every float expression here is lifted verbatim from the pre-refactor call
sites — operand order and association preserved — because the PR-9
bit-for-bit equivalence gate (same summaries, same records, both engines,
all pinned seeds) is the contract this refactor must not move.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Mapping, Optional, Tuple

from repro.core.plan import (DeferPlan, Plan, RunPlan, SplitPlan, as_plan)

__all__ = ["ROLE_FULL", "ROLE_PF", "ROLE_DEC", "Booking",
           "resolve_plan", "plan_legs", "leg_service_s", "migration_charge",
           "route_bookings", "reconcile_deltas", "reconcile_split_deltas"]

# Execution role of a queued leg: full request, prefill-only (decode happens
# elsewhere after a KV migration), or decode-only (arrived via migration).
# Both engines enqueue (key, seq, rec/rid, svc, role) tuples tagged with one
# of these.
ROLE_FULL, ROLE_PF, ROLE_DEC = 0, 1, 2


def resolve_plan(raw, q, known: Mapping[str, object]) -> Plan:
    """Normalize and validate a scheduler ``dispatch`` return.

    ``known`` maps valid system names to anything truthy about the fleet
    (both engines pass their system-name index; the router passes its
    system-name → pool-name map). Validation and degradation mirror the
    pre-plan engine semantics exactly:

      * a split whose query has no decode phase (``q.n <= 0``) degrades to
        a ``RunPlan`` on the prefill pool — only that pool's name is
        validated, matching the old ``s = a`` path;
      * unknown pool names raise ``KeyError`` with the engines' historical
        message, *before* the scheduler's ``observe`` runs.
    """
    plan = as_plan(raw)
    inner = plan.inner if isinstance(plan, DeferPlan) else plan
    if isinstance(inner, SplitPlan) and q.n <= 0:
        inner = RunPlan(inner.pool_prefill, terms=inner.terms)
        plan = DeferPlan(plan.until_s, inner) \
            if isinstance(plan, DeferPlan) else inner
    if isinstance(inner, SplitPlan):
        names = (inner.pool_prefill, inner.pool_decode)
    else:
        names = (inner.pool,)
    for name in names:
        if name not in known:
            raise KeyError(f"scheduler dispatched to unknown system {name!r}")
    return plan


def plan_legs(plan: Plan, q) -> Tuple[str, Optional[str], int, float]:
    """Decompose a resolved plan into what an engine enqueues.

    Returns ``(pool, decode_pool, role, until_s)``: the system name of the
    first leg's pool, the decode pool's system name (``None`` unless split),
    the enqueue role for the first leg, and the admission clock (0.0 means
    admit on arrival)."""
    until_s = 0.0
    if isinstance(plan, DeferPlan):
        until_s = plan.until_s
        plan = plan.inner
    if isinstance(plan, SplitPlan):
        return plan.pool_prefill, plan.pool_decode, ROLE_PF, until_s
    return plan.pool, None, ROLE_FULL, until_s


def leg_service_s(model, q, system, role: int) -> float:
    """Service time the engines charge a queued leg of ``role`` on ``system``
    (the exact pre-refactor pricing calls)."""
    if role == ROLE_PF:
        return model.split_runtime(q.m, q.n, system)[0]
    if role == ROLE_DEC:
        return model.split_runtime(q.m, q.n, system)[1]
    return model.runtime(q.m, q.n, system)


def migration_charge(model, m: int, src, dst, *, block_size: int, rid):
    """KV-prefix migration (bytes, seconds, joules) for a split handoff,
    with the shared no-path guard both engines raise."""
    nbytes, t_mig_s, e_mig_j = model.migration_terms(
        m, src, dst, block_size=block_size)
    if not math.isfinite(t_mig_s):
        raise ValueError(
            f"split request {rid} has no migration path from "
            f"{src.name!r} to {dst.name!r} (link_bw_gbps <= 0 on an endpoint)")
    return nbytes, t_mig_s, e_mig_j


# ------------------------------------------------------------- router booking
@dataclass(frozen=True)
class Booking:
    """One pool's expectation-booked accounting row for a routed request
    (``pool`` is the system name; the router maps it back to its pool key)."""
    pool: str
    queries: int
    energy_j: float
    runtime_s: float
    tokens: int


def route_bookings(model, plan: Plan, q, systems: Mapping[str, object],
                   *, block_size: int = 0) -> List[Booking]:
    """Expectation bookings for a routed plan — the router's historical
    booking math, one row per pool touched.

    ``systems`` maps system name → ``SystemProfile``. A ``DeferPlan`` books
    as its inner plan (live serving cannot time-shift; the router runs the
    inner placement immediately). Split rows mirror the old
    ``_route_split``: the prefill pool absorbs the migration charge and the
    prompt tokens, the decode pool the decode-phase terms and output tokens.
    """
    if isinstance(plan, DeferPlan):
        plan = plan.inner
    if isinstance(plan, SplitPlan):
        sys_a = systems[plan.pool_prefill]
        sys_b = systems[plan.pool_decode]
        e_pf, _ = model.split_energy(q.m, q.n, sys_a)
        _, e_dec = model.split_energy(q.m, q.n, sys_b)
        r_pf, _ = model.split_runtime(q.m, q.n, sys_a)
        _, r_dec = model.split_runtime(q.m, q.n, sys_b)
        _, mig_s, mig_j = model.migration_terms(
            q.m, sys_a, sys_b, block_size=block_size)
        return [Booking(plan.pool_prefill, 1, e_pf + mig_j, r_pf + mig_s, q.m),
                Booking(plan.pool_decode, 0, e_dec, r_dec, q.n)]
    sys_one = systems[plan.pool]
    e = model.energy(q.m, q.n, sys_one)
    r = model.runtime(q.m, q.n, sys_one)
    return [Booking(plan.pool, 1, e, r, q.m + q.n)]


def reconcile_deltas(model, m: int, expected_n: int, actual_n: int, system):
    """EOS reconciliation for a single-pool booking: the (energy, runtime,
    tokens) corrections to move the expectation rows to actuals."""
    d_e = model.energy(m, actual_n, system) - model.energy(m, expected_n, system)
    d_r = model.runtime(m, actual_n, system) - model.runtime(m, expected_n, system)
    return d_e, d_r, actual_n - expected_n


def reconcile_split_deltas(model, m: int, expected_n: int, actual_n: int,
                           sys_a, sys_b):
    """EOS reconciliation for a split booking: per-pool (energy, runtime)
    corrections — prefill-side terms move with ``n`` only through the
    phase split, decode-side terms carry the output-token delta."""
    da_e = (model.split_energy(m, actual_n, sys_a)[0]
            - model.split_energy(m, expected_n, sys_a)[0])
    da_r = (model.split_runtime(m, actual_n, sys_a)[0]
            - model.split_runtime(m, expected_n, sys_a)[0])
    db_e = (model.split_energy(m, actual_n, sys_b)[1]
            - model.split_energy(m, expected_n, sys_b)[1])
    db_r = (model.split_runtime(m, actual_n, sys_b)[1]
            - model.split_runtime(m, expected_n, sys_b)[1])
    return (da_e, da_r), (db_e, db_r), actual_n - expected_n
