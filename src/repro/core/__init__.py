"""core/: the paper's contribution — cost-based scheduling across a hybrid
heterogeneous fleet for energy-efficient LLM inference."""
from repro.core.systems import (SystemProfile, PROFILES, get_profile,
                                paper_fleet, tpu_fleet, PowerState,
                                PowerStateTable, default_power_states)
from repro.core.perf_model import runtime, throughput, query_phases
from repro.core.pricing import (PerfOracle, AnalyticOracle, TableOracle,
                                CalibratedOracle, Calibration, CostModel,
                                KernelSample, fit_calibration,
                                default_cost_model, CostParams, cost,
                                normalized_cost_params, energy,
                                energy_per_token_in, energy_per_token_out,
                                crossover_threshold)
from repro.core.plan import (Plan, PlanTerms, RunPlan, SplitPlan, DeferPlan,
                             as_plan, plan_to_json, plan_from_json)
from repro.core.workload import (Query, WorkloadSpec, sample_workload, alpaca_like,
                                 token_histogram, generate_arrivals,
                                 poisson_arrivals, diurnal_arrivals,
                                 mmpp_arrivals, trace_arrivals)
from repro.core.scheduler import (Scheduler, ThresholdScheduler, CostOptimalScheduler,
                                  CapacityAwareScheduler, DisaggregatedScheduler,
                                  SingleSystemScheduler,
                                  RoundRobinScheduler, Assignment,
                                  FleetState, PoolSnapshot)
from repro.core.simulator import (simulate, summarize, threshold_sweep,
                                  optimal_threshold, headline, SimResult,
                                  SweepPoint, HeadlineResult)
from repro.core.fleet import (FLEET_ENGINES, FleetSimulator, FleetSimResult,
                              PoolSpec, RequestRecord, PoolResult,
                              simulate_fleet, AutoscalerPolicy,
                              TargetUtilizationAutoscaler,
                              QueueDepthAutoscaler)
from repro.core.fleet_vec import VectorizedFleetSimulator
from repro.core.region import (Region, RegionLink, PriceProfile,
                               flatten_regions, GlobalDispatcher)
