"""Pure-jnp oracles for every Pallas kernel.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes and
asserts allclose against these functions. They are also the fallback execution
path on non-TPU backends (the dry-run compiles these — same FLOP structure).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _attn_mask(sq: int, sk: int, *, causal: bool, window: Optional[int],
               q_offset: int = 0) -> jnp.ndarray:
    """(sq, sk) boolean mask. q position i attends to k position j iff
    j <= i+q_offset (causal) and i+q_offset - j < window (sliding window)."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    return mask


def mha_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: Optional[int] = None,
                  softcap: Optional[float] = None, q_offset: int = 0,
                  kv_len: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference grouped-query attention.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
    Computation in f32, returns q.dtype.
    kv_len: optional (B,) valid KV lengths (entries >= kv_len are masked).
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Hkv, group, Sq, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    Sk = k.shape[2]
    mask = _attn_mask(Sq, Sk, causal=causal, window=window, q_offset=q_offset)
    if kv_len is not None:
        valid = jnp.arange(Sk)[None, :] < kv_len[:, None]      # (B, Sk)
        mask = mask[None, :, :] & valid[:, None, :]            # (B, Sq, Sk)
        logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    else:
        logits = jnp.where(mask[None, None, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, Hq, Sq, D).astype(q.dtype)


def mha_attention_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                          causal: bool = True, window: Optional[int] = None,
                          softcap: Optional[float] = None, q_offset: int = 0,
                          block_q: int = 1024) -> jnp.ndarray:
    """Query-chunked attention in pure jnp: O(block_q * Sk) temporaries instead
    of O(Sq * Sk). Execution path for long prefills on non-TPU backends (the
    Pallas kernel covers TPU); numerically identical to ``mha_attention``.
    """
    from repro.models.scan_util import layer_scan  # unroll control

    B, Hq, Sq, D = q.shape
    if Sq <= block_q:
        return mha_attention(q, k, v, causal=causal, window=window,
                             softcap=softcap, q_offset=q_offset)
    pad = (-Sq) % block_q
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else q
    nq = qp.shape[2] // block_q
    qblocks = jnp.moveaxis(qp.reshape(B, Hq, nq, block_q, D), 2, 0)

    def body(i, qb):
        out = mha_attention(qb, k, v, causal=causal, window=window,
                            softcap=softcap, q_offset=q_offset + i * block_q)
        return i + 1, out

    _, outs = layer_scan(body, 0, qblocks)
    out = jnp.moveaxis(outs, 0, 2).reshape(B, Hq, nq * block_q, D)
    return out[:, :, :Sq, :]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray, *,
                     kv_len: jnp.ndarray, softcap: Optional[float] = None,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token decode attention against a (possibly partially filled) cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, Smax, D); kv_len: (B,) number of valid
    positions (the new token's own K/V must already be written at kv_len-1).

    Sliding-window fast path: when the window is much smaller than the cache,
    only the last `window` rows are gathered (per batch element) before the
    dense attention — so compute AND memory traffic scale with the window,
    matching the Pallas kernel's structural block skip.
    """
    B, Hq, _, D = q.shape
    Smax = k_cache.shape[2]
    if window is not None and Smax > 2 * window:
        w = window
        start = jnp.clip(kv_len - w, 0, Smax - w).astype(jnp.int32)    # (B,)
        sl = lambda c, s: jax.lax.dynamic_slice_in_dim(c, s, w, axis=1)
        k_win = jax.vmap(sl)(k_cache, start)
        v_win = jax.vmap(sl)(v_cache, start)
        return decode_attention(q, k_win, v_win, kv_len=kv_len - start,
                                softcap=softcap, window=None)
    q_offset = 0  # positions handled through kv_len masking
    Hkv = k_cache.shape[1]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qg = qf.reshape(B, Hkv, group, 1, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k_cache.astype(jnp.float32))
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(Smax)[None, :]
    valid = kpos < kv_len[:, None]
    if window is not None:
        valid &= kpos >= (kv_len[:, None] - window)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def gather_paged_kv(pool: jnp.ndarray, block_tables: jnp.ndarray) -> jnp.ndarray:
    """Materialize a contiguous per-lane view of a paged KV pool.

    pool: (num_blocks, Hkv, block_size, D) shared block pool;
    block_tables: (B, max_blocks) int32 per-lane block indices (entries past a
    lane's live length may point anywhere valid — typically the reserved null
    block 0 — since downstream attention masks by kv_len).
    Returns (B, Hkv, max_blocks * block_size, D).
    """
    g = pool[block_tables]                       # (B, mb, Hkv, bs, D)
    B, mb, Hkv, bs, D = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(B, Hkv, mb * bs, D)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray, *,
                           kv_len: jnp.ndarray, softcap: Optional[float] = None,
                           window: Optional[int] = None) -> jnp.ndarray:
    """Single-token decode attention reading K/V through block tables.

    q: (B, Hq, 1, D); pools: (num_blocks, Hkv, block_size, D);
    block_tables: (B, max_blocks) int32; kv_len: (B,) valid positions per lane
    (the new token's K/V must already be written into its block at kv_len-1).
    Semantic ground truth for the Pallas paged kernel: gather the lane's
    blocks into a contiguous cache view, then run dense masked decode.
    """
    k = gather_paged_kv(k_pool, block_tables)
    v = gather_paged_kv(v_pool, block_tables)
    return decode_attention(q, k, v, kv_len=kv_len, softcap=softcap,
                            window=window)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray,
                  dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of the models' symmetric per-row int8 KV quantization."""
    return (q.astype(jnp.float32) * scale).astype(dtype)


def paged_decode_attention_quant(q: jnp.ndarray, k_pool: jnp.ndarray,
                                 v_pool: jnp.ndarray, k_scale_pool: jnp.ndarray,
                                 v_scale_pool: jnp.ndarray,
                                 block_tables: jnp.ndarray, *,
                                 kv_len: jnp.ndarray,
                                 softcap: Optional[float] = None,
                                 window: Optional[int] = None) -> jnp.ndarray:
    """Decode attention over int8-quantized paged K/V — gather-dequant oracle.

    q: (B, Hq, 1, D); k_pool/v_pool: (num_blocks, Hkv, block_size, D) int8;
    k_scale_pool/v_scale_pool: (num_blocks, Hkv, block_size, 1) f32 per-row
    scales. Semantic ground truth for every quantized read path: gather the
    lane's blocks, dequantize to q.dtype (exactly the historical inline
    composition in ``models.attention``), then dense masked decode.
    """
    k = dequantize_kv(gather_paged_kv(k_pool, block_tables),
                      gather_paged_kv(k_scale_pool, block_tables), q.dtype)
    v = dequantize_kv(gather_paged_kv(v_pool, block_tables),
                      gather_paged_kv(v_scale_pool, block_tables), q.dtype)
    return decode_attention(q, k, v, kv_len=kv_len, softcap=softcap,
                            window=window)


def paged_decode_attention_quant_fused(q: jnp.ndarray, k_pool: jnp.ndarray,
                                       v_pool: jnp.ndarray,
                                       k_scale_pool: jnp.ndarray,
                                       v_scale_pool: jnp.ndarray,
                                       block_tables: jnp.ndarray, *,
                                       kv_len: jnp.ndarray,
                                       softcap: Optional[float] = None,
                                       window: Optional[int] = None,
                                       ) -> jnp.ndarray:
    """Scale-folded quantized decode: no dequantized K/V is materialized.

    The per-position scales are folded into the score/value contractions —
    logits = (q . k_int8) * k_scale and out = (p * v_scale) @ v_int8 — so
    the K/V operands stay int8 until the contraction. Execution path for the
    tuned ``impl="fused"`` quantized read on the jnp backend; numerically a
    hair different from the gather oracle when q.dtype is low-precision
    (dequantized values are never rounded to q.dtype), within test tol.
    """
    B, Hq, _, D = q.shape
    k8 = gather_paged_kv(k_pool, block_tables)           # (B,Hkv,S,D) int8
    v8 = gather_paged_kv(v_pool, block_tables)
    ks = gather_paged_kv(k_scale_pool, block_tables)     # (B,Hkv,S,1) f32
    vs = gather_paged_kv(v_scale_pool, block_tables)
    Hkv, S = k8.shape[1], k8.shape[2]
    group = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qg = qf.reshape(B, Hkv, group, 1, D)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k8.astype(jnp.float32))
    logits = logits * ks[..., 0][:, :, None, None, :]    # fold k scale per pos
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    kpos = jnp.arange(S)[None, :]
    valid = kpos < kv_len[:, None]
    if window is not None:
        valid &= kpos >= (kv_len[:, None] - window)
    logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    pw = p * vs[..., 0][:, :, None, None, :]             # fold v scale per pos
    out = jnp.einsum("bhgqk,bhkd->bhgqd", pw, v8.astype(jnp.float32))
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bmat: jnp.ndarray, Cmat: jnp.ndarray,
             init_state: Optional[jnp.ndarray] = None,
             ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference Mamba2 SSD recurrence (exact sequential scan).

    x:    (B, H, S, P)   per-head inputs
    dt:   (B, H, S)      softplus-activated step sizes (>0)
    A:    (H,)           negative decay rates (A < 0)
    Bmat: (B, S, N)      input projection onto state (shared across heads, ngroups=1)
    Cmat: (B, S, N)      state readout
    init_state: (B, H, P, N) or None.
    Returns (y, final_state): y (B, H, S, P), final_state (B, H, P, N).

    Recurrence per head:  state_t = exp(dt_t * A) * state_{t-1} + dt_t * x_t B_t^T
                          y_t = state_t C_t
    """
    Bsz, H, S, P = x.shape
    N = Bmat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32)
    Cf = Cmat.astype(jnp.float32)
    state0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs           # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * Af[None, :])                      # (B,H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, Ct)
        return state, yt

    xs = (jnp.moveaxis(xf, 2, 0), jnp.moveaxis(dtf, 2, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    final, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 2)             # (B, H, S, P)
    return y.astype(x.dtype), final


def ssd_scan_chunked(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                     Bmat: jnp.ndarray, Cmat: jnp.ndarray, *, chunk: int = 128,
                     ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD in pure jnp — same algebra as the Pallas kernel (matmul
    form, MXU-shaped FLOPs), used as the execution path on non-TPU backends.
    The sequential ``ssd_scan`` above remains the test oracle for both.
    """
    B, H, S, P = x.shape
    N = Bmat.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    xf = x.astype(jnp.float32).reshape(B, H, nc, chunk, P)
    dtf = dt.astype(jnp.float32).reshape(B, H, nc, chunk)
    Af = A.astype(jnp.float32)
    Bf = Bmat.astype(jnp.float32).reshape(B, nc, chunk, N)
    Cf = Cmat.astype(jnp.float32).reshape(B, nc, chunk, N)

    g = dtf * Af[None, :, None, None]                    # (B,H,nc,L)
    cum = jnp.cumsum(g, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]          # (B,H,nc,L,L)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    # clamp BEFORE exp: masked (j > i) entries have seg > 0 and can overflow
    # to inf, and the backward of where() would turn inf * 0 into NaN
    seg = jnp.where(causal, seg, 0.0)
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)           # (B,nc,L,L)
    att = cb[:, None] * decay * dtf[..., None, :]        # (B,H,nc,L,L)
    y_intra = jnp.einsum("bhclm,bhcmp->bhclp", att, xf)

    # inter-chunk state carry (scan over nc chunks)
    total = cum[..., -1]                                 # (B,H,nc)
    w = jnp.exp(total[..., None] - cum) * dtf            # (B,H,nc,L)
    chunk_state = jnp.einsum("bhclp,bcln->bhcpn", xf * w[..., None], Bf)  # per-chunk update

    def carry(state, inp):
        tot_c, upd_c = inp                               # (B,H), (B,H,P,N)
        new = state * jnp.exp(tot_c)[..., None, None] + upd_c
        return new, state                                # emit the INCOMING state

    state0 = jnp.zeros((B, H, P, N), jnp.float32)
    final, in_states = jax.lax.scan(
        carry, state0,
        (jnp.moveaxis(total, 2, 0), jnp.moveaxis(chunk_state, 2, 0)))
    in_states = jnp.moveaxis(in_states, 0, 2)            # (B,H,nc,P,N)
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bcln,bhcpn->bhclp", Cf, in_states)
    y = (y_intra + y_inter).reshape(B, H, Sp, P)[:, :, :S, :]
    return y.astype(x.dtype), final


def ssd_decode_step(state: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
                    A: jnp.ndarray, Bvec: jnp.ndarray, Cvec: jnp.ndarray,
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One-token SSD state update. state (B,H,P,N), x (B,H,P), dt (B,H),
    Bvec/Cvec (B,N). Returns (y (B,H,P), new_state)."""
    sf = state.astype(jnp.float32)
    decay = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32)[None, :])
    upd = jnp.einsum("bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None], Bvec.astype(jnp.float32))
    new = sf * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new, Cvec.astype(jnp.float32))
    return y.astype(x.dtype), new.astype(state.dtype)
