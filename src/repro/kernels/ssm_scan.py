"""Mamba2 SSD (state-space dual) chunked-scan Pallas-TPU kernel.

TPU-native adaptation: instead of the CUDA selective-scan (a sequential
per-element recurrence leaning on shared memory), we implement the SSD *dual
form* of Mamba2, which recasts the recurrence as chunked dense algebra:

  * within a chunk of length L: a masked (L, L) decay-weighted attention-like
    matmul — three MXU matmuls (C B^T, att x, C state);
  * across chunks: a rank-L state update carried sequentially in VMEM scratch
    along the innermost grid dimension (TPU grids are sequential, so the
    (P, N) running state needs no atomics).

All exponents are <= 0 (A < 0, dt > 0) so the kernel is numerically stable
without max-subtraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, fs_ref, state_ref, *,
                chunk: int, num_chunks: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (L,)
    A = a_ref[0].astype(jnp.float32)             # scalar decay rate (negative)
    Bm = b_ref[0].astype(jnp.float32)            # (L, N)
    Cm = c_ref[0].astype(jnp.float32)            # (L, N)

    g = dt * A                                   # (L,) all <= 0
    cum = jnp.cumsum(g)                          # (L,) decreasing
    # ---- intra-chunk (attention-like) ---------------------------------------
    seg = cum[:, None] - cum[None, :]            # (L, L): decay j -> i
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = jj <= ii
    seg = jnp.where(causal, seg, 0.0)            # masked entries overflow exp
    decay = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)      # (L, L)
    att = cb * decay * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (L, P)
    # ---- inter-chunk: contribution of the incoming state ---------------------
    state = state_ref[...]                       # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # ---- state carry ----------------------------------------------------------
    total = cum[-1]
    w = jnp.exp(total - cum) * dt                # (L,)
    state_new = jnp.exp(total) * state + jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (P, N)
    state_ref[...] = state_new

    @pl.when(c_idx == num_chunks - 1)
    def _emit_final():
        fs_ref[0, 0] = state_new.astype(fs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bmat: jnp.ndarray, Cmat: jnp.ndarray, *, chunk: int = 128,
             interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.

    x (B,H,S,P), dt (B,H,S), A (H,), Bmat (B,S,N), Cmat (B,S,N).
    Returns (y (B,H,S,P), final_state (B,H,P,N)). S is padded to the chunk
    size here (padded steps have dt=0 => identity state update, zero output).
    """
    B, H, S, P = x.shape
    N = Bmat.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, pad)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bmat, Cmat)
    return y[:, :, :S, :], final_state
