"""Kernel autotuner: grid-search tile/block parameters per ``SystemProfile``.

The Pallas/ref kernels behind ``kernels.ops`` historically ran with
hard-coded block sizes (flash ``(block_q, block_kv)``, dense decode's
split-KV tile ``block_kv``, the SSD scan ``chunk``) and, for quantized
paged-KV decode, a fixed read path (gather + host-side dequantize). This
module closes the measure -> fit -> route loop from the DynamoLLM recipe
(arXiv 2407.04014): time every candidate parameter set on the machine the
kernels will actually run on, persist the winners, and let

  * ``kernels.ops`` dispatch resolve tuned parameters per call
    (explicit kwargs still override; with no cache installed the historical
    defaults are used bit-for-bit), and
  * ``core.pricing.TableOracle.from_autotune`` rebuild oracle phase grids
    from the tuned timings, so the schedulers price the kernels *as tuned*.

Caches are versioned JSON under ``experiments/autotune/``, keyed by
``(kernel, backend, shape-bucket)`` per ``(profile, backend)`` file, and
stamped with the ``launch.envcfg`` environment fingerprint — a cache
recorded under a different environment refuses to load (``StaleCacheError``).

The timing callable defaults to ``benchmarks.microbench.time_kernel`` (the
generalized single-cell timer behind ``kernel_phase_samples``); tests inject
deterministic fake timers.
"""
from __future__ import annotations

import itertools
import json
import math
import os
from dataclasses import asdict, dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, Iterable, List, Mapping,
                    Optional, Sequence, Tuple)

from repro.launch import envcfg

if TYPE_CHECKING:
    from repro.core.pricing import KernelSample

CACHE_VERSION = 1

# Default cache root (repo's experiments/ tree); benchmarks and tests may
# point elsewhere.
CACHE_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "experiments", "autotune")

# Kernels the tuner knows. "paged_decode_quant" is the int8-KV paged decode
# read path — its tuning dimension is WHICH kernel runs (gather-dequantize
# vs the fused in-kernel int8 read), not just a tile size.
KERNELS = ("flash_attention", "decode_attention", "paged_decode_quant",
           "ssm_scan")


class StaleCacheError(ValueError):
    """Cache recorded under a different environment fingerprint."""


# ------------------------------------------------------------- param spaces
# Historical hard-coded defaults per (kernel, backend). ops dispatch falls
# back to these when no tuned entry matches — pinned bit-for-bit by
# tests/test_autotune.py. An empty dict means the kernel has no tunable
# parameters on that backend.
_PALLAS = ("pallas", "pallas_interpret")

DEFAULT_PARAMS: Dict[Tuple[str, str], Dict[str, object]] = {
    ("flash_attention", "ref"): {"block_q": 1024},
    **{("flash_attention", b): {"block_q": 128, "block_kv": 128}
       for b in _PALLAS},
    ("decode_attention", "ref"): {},
    **{("decode_attention", b): {"block_kv": 128} for b in _PALLAS},
    **{("paged_decode_quant", b): {"impl": "gather"}
       for b in ("ref",) + _PALLAS},
    **{("ssm_scan", b): {"chunk": 128} for b in ("ref",) + _PALLAS},
}

# Candidate grids. Every space includes the default point, so the winner is
# never slower than the default on the measured grid (asserted in tests and
# by the autotune_sweep no-regression gate).
_SPACES: Dict[Tuple[str, str], Dict[str, Sequence[object]]] = {
    ("flash_attention", "ref"): {"block_q": (128, 256, 512, 1024, 2048)},
    **{("flash_attention", b): {"block_q": (64, 128, 256),
                                "block_kv": (64, 128, 256)} for b in _PALLAS},
    ("decode_attention", "ref"): {},
    **{("decode_attention", b): {"block_kv": (64, 128, 256, 512)}
       for b in _PALLAS},
    **{("paged_decode_quant", b): {"impl": ("gather", "fused")}
       for b in ("ref",) + _PALLAS},
    **{("ssm_scan", b): {"chunk": (16, 32, 64, 128, 256)}
       for b in ("ref",) + _PALLAS},
}


def default_params(kernel: str, backend: str) -> Dict[str, object]:
    """Historical hard-coded parameters for (kernel, backend)."""
    try:
        return dict(DEFAULT_PARAMS[(kernel, backend)])
    except KeyError:
        raise KeyError(f"unknown kernel/backend {(kernel, backend)!r}") from None


def param_space(kernel: str, backend: str) -> List[Dict[str, object]]:
    """Cartesian candidate grid (default point first)."""
    space = _SPACES.get((kernel, backend))
    if space is None:
        raise KeyError(f"unknown kernel/backend {(kernel, backend)!r}")
    if not space:
        return []
    names = sorted(space)
    combos = [dict(zip(names, vals))
              for vals in itertools.product(*(space[k] for k in names))]
    default = default_params(kernel, backend)
    combos.sort(key=lambda c: c != default)        # default first
    return combos


# ----------------------------------------------------------- shape buckets
def _pow2(x: int) -> int:
    return 1 << max(0, math.ceil(math.log2(max(1, int(x)))))


def shape_bucket(kernel: str, **dims: int) -> str:
    """Canonical bucket key for a kernel invocation's shape.

    Sequence/context lengths and batch are bucketed to the next power of
    two: the tuned choice is driven by the padded grid the kernel actually
    runs, which is pow2-block granular. Head counts / head_dim are left out
    — they scale all candidates alike on these kernels.

      flash_attention    s=<seq>          -> "s1024"
      decode_attention   b=<batch> c=<ctx>-> "b8c2048"
      paged_decode_quant b=<batch> c=<ctx>-> "b8c1024"
      ssm_scan           s=<seq>          -> "s512"
    """
    if kernel in ("flash_attention", "ssm_scan"):
        return f"s{_pow2(dims['s'])}"
    if kernel in ("decode_attention", "paged_decode_quant"):
        return f"b{_pow2(dims['b'])}c{_pow2(dims['c'])}"
    raise KeyError(f"unknown kernel {kernel!r}")


# ------------------------------------------------------------------- cache
@dataclass(frozen=True)
class TunedEntry:
    """Winner of one (kernel, backend, shape-bucket) grid search.

    Carries the analytic work counts (flops/bytes/ctx) of the timed shape so
    ``TableOracle.from_autotune`` can refit pricing constants from the tuned
    timings without re-measuring.
    """
    kernel: str
    backend: str
    bucket: str
    params: Dict[str, object]          # winning parameters
    t_s: float                         # winner best-of-k seconds
    t_default_s: float                 # default params, same sweep
    noise_frac: float                  # winner's (median-best)/best spread
    flops: float
    bytes: float
    ctx: float
    shape: Dict[str, int] = field(default_factory=dict)

    @property
    def speedup(self) -> float:
        return self.t_default_s / self.t_s

    def key(self) -> str:
        return cache_key(self.kernel, self.backend, self.bucket)


def cache_key(kernel: str, backend: str, bucket: str) -> str:
    return f"{kernel}/{backend}/{bucket}"


class AutotuneCache:
    """Tuned winners for one (profile, backend), stamped with the recording
    environment fingerprint."""

    def __init__(self, profile: str, backend: str, *,
                 env: Optional[Dict[str, str]] = None,
                 entries: Iterable[TunedEntry] = ()):
        self.profile = profile
        self.backend = backend
        self.env = dict(env) if env is not None else envcfg.env_fingerprint()
        self.entries: Dict[str, TunedEntry] = {e.key(): e for e in entries}

    # ------------------------------------------------------------- queries
    def add(self, entry: TunedEntry) -> None:
        self.entries[entry.key()] = entry

    def resolve(self, kernel: str, backend: str,
                bucket: str) -> Optional[Dict[str, object]]:
        """Winning params for (kernel, backend, bucket), or None."""
        e = self.entries.get(cache_key(kernel, backend, bucket))
        return dict(e.params) if e is not None else None

    def tuned_samples(self) -> List["KernelSample"]:
        """The winners as ``KernelSample``s — the feed for
        ``fit_calibration`` / ``TableOracle.from_autotune``."""
        from repro.core.pricing import KernelSample
        return [KernelSample(e.kernel, e.flops, e.bytes, e.ctx, e.t_s,
                             noise_frac=e.noise_frac)
                for e in sorted(self.entries.values(), key=lambda e: e.key())]

    def geomean_speedup(self) -> float:
        """Geometric-mean tuned-vs-default speedup across entries."""
        ups = [e.speedup for e in self.entries.values()]
        if not ups:
            return 1.0
        return math.exp(sum(math.log(u) for u in ups) / len(ups))

    # ----------------------------------------------------------- artifacts
    def to_json(self) -> Dict[str, object]:
        return {
            "version": CACHE_VERSION,
            "profile": self.profile,
            "backend": self.backend,
            "env": self.env,
            "env_digest": envcfg.fingerprint_digest(self.env),
            "entries": {k: asdict(e) for k, e in sorted(self.entries.items())},
        }

    def dump(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
        return path

    @classmethod
    def from_json(cls, data: Mapping[str, object], *,
                  require_env: bool = True) -> "AutotuneCache":
        version = data.get("version")
        if version != CACHE_VERSION:
            raise ValueError(f"autotune cache version {version!r} != "
                             f"supported {CACHE_VERSION}")
        env = dict(data["env"])                                # type: ignore[arg-type]
        recorded = data.get("env_digest")
        if recorded != envcfg.fingerprint_digest(env):
            raise ValueError("autotune cache corrupt: env_digest does not "
                             "match its recorded fingerprint")
        if require_env:
            current = envcfg.fingerprint_digest()
            if recorded != current:
                raise StaleCacheError(
                    f"autotune cache recorded under env {recorded} but the "
                    f"current env is {current}; re-run the autotuner "
                    "(or pass require_env=False to inspect it anyway)")
        entries = [TunedEntry(**e) for e in data["entries"].values()]  # type: ignore[union-attr]
        return cls(str(data["profile"]), str(data["backend"]), env=env,
                   entries=entries)

    @classmethod
    def load(cls, path: str, *, require_env: bool = True) -> "AutotuneCache":
        with open(path) as f:
            data = json.load(f)
        return cls.from_json(data, require_env=require_env)

    def __repr__(self) -> str:
        return (f"AutotuneCache(profile={self.profile!r}, "
                f"backend={self.backend!r}, entries={len(self.entries)})")


def cache_path(profile: str, backend: str, root: Optional[str] = None) -> str:
    """Canonical on-disk location for a (profile, backend) cache."""
    return os.path.join(root if root is not None else CACHE_DIR,
                        f"{profile}__{backend}.json")


# -------------------------------------------------------------- the tuner
# timer(kernel, shape, params, backend, iters, seed) -> KernelSample
Timer = Callable[..., "KernelSample"]

# Representative shapes per kernel (bucket-defining dims only; the timer
# fills in heads/head_dim). One entry per bucket the serving stack hits.
DEFAULT_SHAPES: Dict[str, Tuple[Dict[str, int], ...]] = {
    "flash_attention": ({"s": 1024}, {"s": 2048}),
    "decode_attention": ({"b": 8, "c": 1024}, {"b": 8, "c": 4096}),
    "paged_decode_quant": ({"b": 8, "c": 1024}, {"b": 8, "c": 2048}),
    "ssm_scan": ({"s": 512}, {"s": 1024}),
}


def _default_timer() -> Timer:
    # lazy: benchmarks/ is a script dir, not part of the installed package
    try:
        from benchmarks.microbench import time_kernel
    except ImportError:                  # standalone: benchmarks/ on sys.path
        from microbench import time_kernel
    return time_kernel


def autotune(shapes: Optional[Mapping[str, Sequence[Dict[str, int]]]] = None,
             *, profile: str, backend: Optional[str] = None,
             iters: int = 5, seed: int = 0, timer: Optional[Timer] = None,
             verbose: bool = False) -> AutotuneCache:
    """Grid-search every (kernel, shape) cell and return the winners.

    ``backend`` defaults to the resolved auto backend (compiled Pallas on
    TPU, the jnp path elsewhere) so the tuner measures what serving will
    run. Each cell times every candidate in ``param_space`` best-of-k
    (warmup excluded) and keeps the fastest; the default parameters are in
    every space, so a winner is never slower than the default *on the
    measured grid* by construction.
    """
    from repro.kernels import ops                   # late: ops imports us
    if backend is None:
        backend = ops.resolve_backend("auto")
    if timer is None:
        timer = _default_timer()
    if shapes is None:
        shapes = DEFAULT_SHAPES

    cache = AutotuneCache(profile, backend)
    for kernel in sorted(shapes):
        if kernel not in KERNELS:
            raise KeyError(f"unknown kernel {kernel!r}; expected one of "
                           f"{KERNELS}")
        candidates = param_space(kernel, backend)
        if not candidates:
            continue                                 # nothing tunable here
        for shape in shapes[kernel]:
            bucket = shape_bucket(kernel, **shape)
            best = None                              # (t_s, params, sample)
            t_default = None
            default = default_params(kernel, backend)
            for params in candidates:
                sample = timer(kernel, dict(shape), params=params,
                               backend=backend, iters=iters, seed=seed)
                if params == default:
                    t_default = sample.t_s
                if best is None or sample.t_s < best[0]:
                    best = (sample.t_s, params, sample)
                if verbose:
                    print(f"[autotune] {kernel}/{bucket} {params} "
                          f"-> {sample.t_s * 1e3:.3f} ms")
            assert best is not None and t_default is not None
            t_s, params, sample = best
            cache.add(TunedEntry(
                kernel=kernel, backend=backend, bucket=bucket,
                params=dict(params), t_s=t_s, t_default_s=t_default,
                noise_frac=float(getattr(sample, "noise_frac", 0.0)),
                flops=sample.flops, bytes=sample.bytes, ctx=sample.ctx,
                shape=dict(shape)))
            if verbose:
                print(f"[autotune] {kernel}/{bucket} winner {params} "
                      f"({t_default / t_s:.2f}x vs default)")
    return cache


# ----------------------------------------------------- process-wide active cache
# The cache ``kernels.ops`` dispatch consults. Installing is explicit (no
# import-time disk reads): serving entry points / benchmarks opt in. With
# nothing installed every lookup misses and dispatch uses the pinned
# defaults, so the untuned path is bit-for-bit the historical one.
_ACTIVE: Optional[AutotuneCache] = None


def install(cache: Optional[AutotuneCache]) -> Optional[AutotuneCache]:
    """Make ``cache`` the processwide tuned-params source (None clears).
    Returns the previously installed cache. Install BEFORE tracing/jitting
    model steps: dispatch resolves params at trace time."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, cache
    return prev


def installed() -> Optional[AutotuneCache]:
    return _ACTIVE


def load_and_install(path: str, *, require_env: bool = True) -> AutotuneCache:
    cache = AutotuneCache.load(path, require_env=require_env)
    install(cache)
    return cache


def lookup(kernel: str, backend: str, bucket: str) -> Dict[str, object]:
    """Tuned params for a dispatch site ({} when none installed/matched)."""
    if _ACTIVE is None:
        return {}
    return _ACTIVE.resolve(kernel, backend, bucket) or {}
