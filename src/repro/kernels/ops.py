"""Public jit'd kernel wrappers with backend dispatch.

Backends:
  * "pallas"           — compiled Pallas (TPU target)
  * "pallas_interpret" — Pallas interpret mode (CPU correctness validation)
  * "ref"              — pure-jnp oracle (also what the CPU dry-run compiles;
                         identical FLOP structure to the fused kernel)
  * "auto" (default)   — pallas on TPU, ref elsewhere.

Models call these entry points only; they never touch pallas_call directly.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssm_scan as _ss

_FORCED = os.environ.get("REPRO_KERNEL_BACKEND")  # override for experiments


def resolve_backend(backend: str = "auto") -> str:
    if _FORCED:
        backend = _FORCED
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    backend: str = "auto") -> jnp.ndarray:
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.mha_attention_chunked(q, k, v, causal=causal, window=window,
                                          softcap=softcap, q_offset=q_offset)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               interpret=(b == "pallas_interpret"))


def decode_attention(q, k_cache, v_cache, kv_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     backend: str = "auto") -> jnp.ndarray:
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.decode_attention(q, k_cache, v_cache, kv_len=kv_len,
                                     window=window, softcap=softcap)
    return _da.decode_attention(q, k_cache, v_cache, kv_len, window=window,
                                softcap=softcap,
                                interpret=(b == "pallas_interpret"))


def paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           backend: str = "auto") -> jnp.ndarray:
    """Decode attention through a paged KV cache (shared block pool +
    per-lane block tables). See ``kernels.ref.paged_decode_attention``."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                           kv_len=kv_len, window=window,
                                           softcap=softcap)
    return _da.paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len,
                                      window=window, softcap=softcap,
                                      interpret=(b == "pallas_interpret"))


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk: int = 128, backend: str = "auto"):
    b = resolve_backend(backend)
    if b == "ref":
        # chunked matmul form: same algebra as the kernel, MXU-shaped FLOPs
        return _ref.ssd_scan_chunked(x, dt, A, Bmat, Cmat, chunk=chunk)
    return _ss.ssd_scan(x, dt, A, Bmat, Cmat, chunk=chunk,
                        interpret=(b == "pallas_interpret"))


def ssd_decode_step(state, x, dt, A, Bvec, Cvec):
    # single-token state update: pure jnp everywhere (elementwise + tiny matmuls,
    # no kernel win at (B,H,P,N) scale)
    return _ref.ssd_decode_step(state, x, dt, A, Bvec, Cvec)
