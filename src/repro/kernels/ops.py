"""Public jit'd kernel wrappers with backend dispatch.

Backends:
  * "pallas"           — compiled Pallas (TPU target)
  * "pallas_interpret" — Pallas interpret mode (CPU correctness validation)
  * "ref"              — pure-jnp oracle (also what the CPU dry-run compiles;
                         identical FLOP structure to the fused kernel)
  * "auto" (default)   — pallas on TPU, ref elsewhere.

Models call these entry points only; they never touch pallas_call directly.

Tile/block parameters resolve in three steps: an explicit kwarg wins, then a
winner from the installed autotune cache (``kernels.autotune.install``), then
the kernel's own historical default. With no cache installed and no kwarg,
nothing is passed down, so the untuned path is bit-for-bit the pre-autotune
dispatch. NOTE: resolution happens at trace time — install the cache before
jitting model steps, or the traced default is baked in.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssm_scan as _ss

_FORCED = os.environ.get("REPRO_KERNEL_BACKEND")  # override for experiments


def resolve_backend(backend: str = "auto") -> str:
    if _FORCED:
        backend = _FORCED
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


def flash_attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    block_q: Optional[int] = None,
                    block_kv: Optional[int] = None,
                    backend: str = "auto") -> jnp.ndarray:
    b = resolve_backend(backend)
    tuned = _at.lookup("flash_attention", b,
                       _at.shape_bucket("flash_attention", s=q.shape[2]))
    if b == "ref":
        kw = {}
        bq = block_q if block_q is not None else tuned.get("block_q")
        if bq is not None:
            kw["block_q"] = int(bq)
        return _ref.mha_attention_chunked(q, k, v, causal=causal, window=window,
                                          softcap=softcap, q_offset=q_offset,
                                          **kw)
    kw = {}
    bq = block_q if block_q is not None else tuned.get("block_q")
    bk = block_kv if block_kv is not None else tuned.get("block_kv")
    if bq is not None:
        kw["block_q"] = int(bq)
    if bk is not None:
        kw["block_k"] = int(bk)
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, q_offset=q_offset,
                               interpret=(b == "pallas_interpret"), **kw)


def decode_attention(q, k_cache, v_cache, kv_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     block_kv: Optional[int] = None,
                     backend: str = "auto") -> jnp.ndarray:
    b = resolve_backend(backend)
    if b == "ref":
        # no tunable tiles on the jnp path (block_kv is the Pallas split-KV
        # granularity); an explicit block_kv is accepted and ignored
        return _ref.decode_attention(q, k_cache, v_cache, kv_len=kv_len,
                                     window=window, softcap=softcap)
    tuned = _at.lookup("decode_attention", b,
                       _at.shape_bucket("decode_attention", b=q.shape[0],
                                        c=k_cache.shape[2]))
    kw = {}
    bk = block_kv if block_kv is not None else tuned.get("block_kv")
    if bk is not None:
        kw["block_k"] = int(bk)
    return _da.decode_attention(q, k_cache, v_cache, kv_len, window=window,
                                softcap=softcap,
                                interpret=(b == "pallas_interpret"), **kw)


def paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           backend: str = "auto") -> jnp.ndarray:
    """Decode attention through a paged KV cache (shared block pool +
    per-lane block tables). See ``kernels.ref.paged_decode_attention``.
    No free tile parameter: the split-KV granularity IS the pool block size."""
    b = resolve_backend(backend)
    if b == "ref":
        return _ref.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                           kv_len=kv_len, window=window,
                                           softcap=softcap)
    return _da.paged_decode_attention(q, k_pool, v_pool, block_tables, kv_len,
                                      window=window, softcap=softcap,
                                      interpret=(b == "pallas_interpret"))


def paged_decode_attention_quant(q, k_pool, v_pool, k_scale_pool, v_scale_pool,
                                 block_tables, kv_len, *,
                                 window: Optional[int] = None,
                                 softcap: Optional[float] = None,
                                 impl: Optional[str] = None,
                                 backend: str = "auto") -> jnp.ndarray:
    """Decode attention over an int8-quantized paged KV cache.

    Two read paths — the first tuning dimension where the tuned choice is a
    different kernel rather than a different tile:

      * ``impl="gather"`` (historical default): gather the lane's blocks,
        dequantize to q.dtype, run the dense decode kernel. Bit-for-bit the
        inline composition ``models.attention`` used before this entry point.
      * ``impl="fused"``: the scales fold into the attention contractions —
        in-kernel int8 read on Pallas (``paged_decode_attention_int8``),
        scale-folded jnp on the ref backend — so no dequantized copy of the
        cache is ever materialized.

    ``impl=None`` resolves explicit -> autotuned -> "gather".
    """
    b = resolve_backend(backend)
    if impl is None:
        ctx = block_tables.shape[1] * k_pool.shape[2]
        tuned = _at.lookup("paged_decode_quant", b,
                           _at.shape_bucket("paged_decode_quant",
                                            b=q.shape[0], c=ctx))
        impl = str(tuned.get("impl", "gather"))
    if impl == "gather":
        k = _ref.dequantize_kv(_ref.gather_paged_kv(k_pool, block_tables),
                               _ref.gather_paged_kv(k_scale_pool, block_tables),
                               q.dtype)
        v = _ref.dequantize_kv(_ref.gather_paged_kv(v_pool, block_tables),
                               _ref.gather_paged_kv(v_scale_pool, block_tables),
                               q.dtype)
        return decode_attention(q, k, v, kv_len, window=window, softcap=softcap,
                                backend=backend)
    if impl != "fused":
        raise ValueError(f"unknown quantized decode impl {impl!r}; "
                         "expected 'gather' or 'fused'")
    if b == "ref":
        return _ref.paged_decode_attention_quant_fused(
            q, k_pool, v_pool, k_scale_pool, v_scale_pool, block_tables,
            kv_len=kv_len, window=window, softcap=softcap)
    return _da.paged_decode_attention_int8(
        q, k_pool, v_pool, k_scale_pool, v_scale_pool, block_tables, kv_len,
        window=window, softcap=softcap, interpret=(b == "pallas_interpret"))


def ssd_scan(x, dt, A, Bmat, Cmat, *, chunk: Optional[int] = None,
             backend: str = "auto"):
    b = resolve_backend(backend)
    if chunk is None:
        tuned = _at.lookup("ssm_scan", b,
                           _at.shape_bucket("ssm_scan", s=x.shape[2]))
        chunk = int(tuned.get("chunk", 128))
    if b == "ref":
        # chunked matmul form: same algebra as the kernel, MXU-shaped FLOPs
        return _ref.ssd_scan_chunked(x, dt, A, Bmat, Cmat, chunk=chunk)
    return _ss.ssd_scan(x, dt, A, Bmat, Cmat, chunk=chunk,
                        interpret=(b == "pallas_interpret"))


def ssd_decode_step(state, x, dt, A, Bvec, Cvec):
    # single-token state update: pure jnp everywhere (elementwise + tiny matmuls,
    # no kernel win at (B,H,P,N) scale)
    return _ref.ssd_decode_step(state, x, dt, A, Bvec, Cvec)
