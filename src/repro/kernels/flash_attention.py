"""Blockwise (flash) attention Pallas-TPU kernel for prefill.

TPU-native design notes (vs the CUDA flash-attention algorithm):
  * Grid = (B, Hq, num_q_blocks, num_k_blocks) with the K dimension innermost —
    TPU grids execute sequentially, so the online-softmax running state
    (m, l, acc) lives in VMEM scratch and persists across K iterations of the
    same (b, h, iq) triple. No atomics / warp shuffles needed.
  * Block sizes default to (block_q=128, block_k=128): MXU-aligned (128x128
    systolic array) and head_dim (64/128) rides along as the minor dim.
  * Causal + sliding-window masking is done block-wise: fully-masked K blocks
    are skipped via pl.when on the block indices (structural, known from the
    grid), in-block masking via broadcasted_iota position comparison.
  * GQA: grid iterates query heads; the K/V BlockSpec index_map maps query head
    h -> kv head h // group, so KV blocks are fetched once per group position.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  sm_scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  num_k_blocks: int, q_offset: int, sk_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # --- structural block skip ------------------------------------------------
    # last query position in this q block / first+last key position in k block
    q_last = iq * block_q + block_q - 1 + q_offset
    k_first = ik * block_k
    k_last = ik * block_k + block_k - 1
    live = k_first < sk_valid
    if causal:
        live &= k_first <= q_last
    if window is not None:
        # whole k block left of every query's window?
        q_first = iq * block_q + q_offset
        live &= (q_first - k_last) < window

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                     # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                     # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < sk_valid
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                     # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)               # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                         # rescale old acc
        p = jnp.exp(s - m_new)                                  # (bq, bk)
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)                         # fully-masked rows
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "q_offset", "sk_valid",
                     "block_q", "block_k", "interpret"),
)
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, q_offset: int = 0,
                    sk_valid: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D). Sq/Sk padded here to blocks.

    sk_valid: number of valid key positions (defaults to Sk) — keys beyond it
    are masked (used by the wrapper when padding).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    for name, blk in (("block_q", block_q), ("block_k", block_k)):
        if blk < 1 or (blk & (blk - 1)):
            raise ValueError(f"{name} must be a positive power of two "
                             f"(MXU-aligned grid), got {blk}")
    group = Hq // Hkv
    if sk_valid is None:
        sk_valid = Sk
    sm_scale = D ** -0.5

    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sqp, Skp = Sq + pad_q, Sk + pad_k
    nq, nk = Sqp // block_q, Skp // block_k

    kernel = functools.partial(
        _flash_kernel, sm_scale=sm_scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        q_offset=q_offset, sk_valid=sk_valid)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq, :]
