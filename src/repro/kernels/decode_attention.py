"""Flash-decode Pallas-TPU kernel: one new query token vs a long KV cache.

TPU-native adaptation of flash-decode (no warp-level reductions):
  * Grouped-query packing: the G = Hq/Hkv query heads sharing one KV head form
    the *rows* of the query block, so the MXU sees a (G, D) x (D, bk) matmul
    instead of a degenerate (1, D) one. This is the standard TPU trick for
    making single-token decode MXU-friendly.
  * Split-KV: the cache is scanned in block_k chunks along the innermost
    (sequential) grid dimension; online-softmax partials (m, l, acc) persist in
    VMEM scratch exactly as in the prefill kernel, and blocks entirely beyond
    kv_len (or left of the sliding window) are skipped structurally.
  * kv_len is a scalar-prefetch operand (SMEM) so per-batch lengths steer the
    block skip without touching the vector units.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   sm_scale: float, window: Optional[int],
                   softcap: Optional[float], block_k: int, num_k_blocks: int):
    b = pl.program_id(0)
    ik = pl.program_id(2)
    kv_len = kv_len_ref[b]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_first = ik * block_k
    live = k_first < kv_len
    if window is not None:
        k_last = k_first + block_k - 1
        live &= k_last >= (kv_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                     # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (G, block_k), 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos >= (kv_len - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_kernel(kv_len_ref, tables_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, sm_scale: float,
                         window: Optional[int], softcap: Optional[float],
                         block_size: int, num_blocks: int):
    """Same online-softmax body as ``_decode_kernel``; the difference is pure
    addressing — the K/V BlockSpec index maps route each grid step's block
    through the scalar-prefetched block table, so the kernel walks the lane's
    logical context while reading physically scattered pool blocks."""
    b = pl.program_id(0)
    ik = pl.program_id(2)
    kv_len = kv_len_ref[b]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_first = ik * block_size
    live = k_first < kv_len
    if window is not None:
        k_last = k_first + block_size - 1
        live &= k_last >= (kv_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32)                     # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        kpos = ik * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_size), 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos >= (kv_len - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_decode_int8_kernel(kv_len_ref, tables_ref, q_ref, k_ref, v_ref,
                              ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref, *,
                              sm_scale: float, window: Optional[int],
                              softcap: Optional[float], block_size: int,
                              num_blocks: int):
    """Online-softmax body of ``_paged_decode_kernel`` with the int8 read
    fused in: K/V blocks arrive as int8 plus their (block_size, 1) per-row
    scales, and the dequantize happens in VMEM right before the dot — the
    pool is never materialized in floating point in HBM."""
    b = pl.program_id(0)
    ik = pl.program_id(2)
    kv_len = kv_len_ref[b]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_first = ik * block_size
    live = k_first < kv_len
    if window is not None:
        k_last = k_first + block_size - 1
        live &= k_last >= (kv_len - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale          # (G, D)
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]      # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (G, bs)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        G = s.shape[0]
        kpos = ik * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (G, block_size), 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos >= (kv_len - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == num_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_decode_attention_int8(q: jnp.ndarray, k_pool: jnp.ndarray,
                                v_pool: jnp.ndarray, k_scale_pool: jnp.ndarray,
                                v_scale_pool: jnp.ndarray,
                                block_tables: jnp.ndarray,
                                kv_len: jnp.ndarray, *,
                                window: Optional[int] = None,
                                softcap: Optional[float] = None,
                                interpret: bool = True) -> jnp.ndarray:
    """Flash-decode reading an int8-quantized paged KV cache in-kernel.

    q: (B, Hq, 1, D); k_pool/v_pool: (num_blocks, Hkv, block_size, D) int8;
    k_scale_pool/v_scale_pool: (num_blocks, Hkv, block_size, 1) f32 per-row
    scales; block_tables (B, max_blocks) int32; kv_len (B,) int32.

    The scale pools ride the same scalar-prefetched block-table addressing
    as K/V, so each grid step DMAs the int8 block plus its scale column and
    dequantizes in VMEM — halving the HBM read traffic vs the historical
    gather-then-dequantize composition, which materializes full-precision
    copies of both caches before the dense kernel even starts.
    """
    B, Hq, one, D = q.shape
    assert one == 1
    _, Hkv, block_size, _ = k_pool.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    sm_scale = D ** -0.5
    mb = block_tables.shape[1]

    qg = q.reshape(B, Hkv, G, D)
    kv_len = kv_len.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_int8_kernel, sm_scale=sm_scale, window=window,
        softcap=softcap, block_size=block_size, num_blocks=mb)

    def _table_map(b, h, ik, kv_len_ref, tables_ref):
        return (tables_ref[b, ik], h, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_size, D), _table_map),
            pl.BlockSpec((1, 1, block_size, D), _table_map),
            pl.BlockSpec((1, 1, block_size, 1), _table_map),
            pl.BlockSpec((1, 1, block_size, 1), _table_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len, block_tables, qg, k_pool, v_pool, k_scale_pool, v_scale_pool)
    return out.reshape(B, Hq, 1, D)


@functools.partial(jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                           kv_len: jnp.ndarray, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = True) -> jnp.ndarray:
    """Flash-decode over a paged KV cache.

    q: (B, Hq, 1, D); pools: (num_blocks, Hkv, block_size, D);
    block_tables: (B, max_blocks) int32 — entry j is the pool block holding
    lane b's positions [j*block_size, (j+1)*block_size); dead entries must
    still be valid indices (the batcher points them at the reserved null
    block 0, and the kernel skips them structurally via kv_len).
    kv_len: (B,) int32. Returns (B, Hq, 1, D).

    Both kv_len and the block table ride in SMEM via scalar prefetch: the
    table steers the K/V DMA source block per grid step, so the split-KV scan
    touches only the lane's own blocks — no contiguous copy of the cache.
    """
    B, Hq, one, D = q.shape
    assert one == 1
    _, Hkv, block_size, _ = k_pool.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    sm_scale = D ** -0.5
    mb = block_tables.shape[1]

    qg = q.reshape(B, Hkv, G, D)
    kv_len = kv_len.astype(jnp.int32)
    block_tables = block_tables.astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel, sm_scale=sm_scale, window=window,
        softcap=softcap, block_size=block_size, num_blocks=mb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_size, D),
                         lambda b, h, ik, kv_len_ref, tables_ref:
                         (tables_ref[b, ik], h, 0, 0)),
            pl.BlockSpec((1, 1, block_size, D),
                         lambda b, h, ik, kv_len_ref, tables_ref:
                         (tables_ref[b, ik], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len, block_tables, qg, k_pool, v_pool)
    return out.reshape(B, Hq, 1, D)


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "block_k", "interpret"),
)
def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     kv_len: jnp.ndarray, *, window: Optional[int] = None,
                     softcap: Optional[float] = None, block_k: int = 128,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, 1, D); caches (B, Hkv, Smax, D); kv_len (B,) int32.

    Returns (B, Hq, 1, D). The new token's K/V must already be written into the
    cache at position kv_len-1.
    """
    B, Hq, one, D = q.shape
    assert one == 1
    _, Hkv, Smax, _ = k_cache.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    sm_scale = D ** -0.5

    pad_k = (-Smax) % block_k
    if pad_k:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Skp = Smax + pad_k
    nk = Skp // block_k

    # grouped-query packing: (B, Hkv, G, D)
    qg = q.reshape(B, Hkv, G, D)
    kv_len = kv_len.astype(jnp.int32)

    kernel = functools.partial(
        _decode_kernel, sm_scale=sm_scale, window=window, softcap=softcap,
        block_k=block_k, num_k_blocks=nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, *_: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, *_: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(kv_len, qg, k_cache, v_cache)
    return out.reshape(B, Hq, 1, D)
