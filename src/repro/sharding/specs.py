"""Partition specs for params, caches and batches.

Strategy (Megatron-style tensor parallel on the "model" axis, data parallel
on "data", pure replicas across "pod"):

  * embeddings / unembed: vocab dim on "model";
  * attention q/k/v: output (head) dim on "model" where divisible, o: input
    dim on "model";
  * mlp up/gate: d_ff on "model"; down: d_ff (input) on "model";
  * MoE: expert dim on "model" when expert count divides, else d_ff within
    experts on "model" (grok: 8 experts on a 16-way axis);
  * mamba: d_inner-shaped dims on "model";
  * batch dims on "data"; for decode shapes whose batch doesn't divide the
    axis, the KV-cache *sequence* dim shards on "data" instead (long-context
    mode) — the flash-decode masking makes per-shard softmax partials exact
    under GSPMD's collective lowering.

Every spec passes through ``_fit``: any dim not divisible by its mesh axis is
replicated, so one rule set serves all 10 architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape.get(name, 1) if hasattr(mesh.shape, "get") else mesh.shape[name]


def model_axes(mesh: Mesh):
    """The tensor-parallel axis group: "model", or ("expert","tp") on MoE
    meshes where the 16-way axis is factorized for expert parallelism."""
    return ("expert", "tp") if "expert" in mesh.shape else "model"


def _remap(mesh: Mesh, spec: P) -> P:
    """Replace the logical "model" axis with the mesh's TP axis group."""
    ma = model_axes(mesh)
    if ma == "model":
        return spec
    out = []
    for ax in tuple(spec):
        if ax == "model":
            out.append(ma)
        elif isinstance(ax, tuple):
            out.append(tuple(ma if a == "model" else a for a in ax))
        else:
            out.append(ax)
    return P(*out)


def _fit(mesh: Mesh, shape, spec: P) -> P:
    """Drop sharding on dims that the mesh axis doesn't divide."""
    fixed = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        total = int(np.prod([_axis_size(mesh, a) for a in axes]))
        fixed.append(ax if dim % total == 0 else None)
    return P(*fixed)


def named(mesh: Mesh, shape, *axes) -> NamedSharding:
    return NamedSharding(mesh, _fit(mesh, shape, P(*axes)))


# --------------------------------------------------------------------- params
def param_spec(cfg: ModelConfig, path: str, shape, expert_mesh: bool = False) -> P:
    """Logical spec by param path (joined with '/'). Leading stacked-layer
    dims (from lax.scan stacking) are never sharded."""
    n = path.split("/")
    leaf = n[-1]
    parent = n[-2] if len(n) >= 2 else ""
    gp = n[-3] if len(n) >= 3 else ""

    def base(spec: P) -> P:
        # prepend None for the stacked layer dim if present
        extra = len(shape) - len(spec)
        return P(*((None,) * extra + tuple(spec))) if extra > 0 else spec

    if leaf == "emb":           # (V, d) token / (S, d) position embeddings
        if parent in ("dec_pos",):
            return base(P(None, None))
        return base(P("model", None))
    if parent in ("q", "k", "v") or gp in ("q", "k", "v"):
        if leaf == "w":
            return base(P(None, "model"))
        return base(P("model"))            # bias on the sharded output dim
    if parent == "o":
        return base(P("model", None)) if leaf == "w" else base(P(None))
    if parent in ("gate", "up"):
        return base(P(None, "model")) if leaf == "w" else base(P("model"))
    if parent == "down":
        return base(P("model", None)) if leaf == "w" else base(P(None))
    if parent == "router":
        return base(P(None, None))
    if leaf in ("gate", "up") and "moe" in n:       # moe expert stacks (E, d, ff)
        if expert_mesh:
            return base(P("expert", None, "tp"))
        return base(P("model", None, None)) if shape_div(shape, -3) else base(P(None, None, "model"))
    if leaf == "down" and "moe" in n:               # (E, ff, d)
        if expert_mesh:
            return base(P("expert", "tp", None))
        return base(P("model", None, None)) if shape_div(shape, -3) else base(P(None, "model", None))
    if parent in ("zx_proj", "bc_proj"):            # mamba (d, 2di) / (d, 2N)
        return base(P(None, "model")) if leaf == "w" else base(P("model"))
    if parent == "dt_proj":                         # (d, H): H rarely divides
        return base(P(None, None))
    if parent == "out_proj":                        # (di, d)
        return base(P("model", None)) if leaf == "w" else base(P(None))
    if leaf in ("conv_w", "conv_b"):
        return base(P()) if leaf == "conv_b" else base(P(None, None))
    # norms, A_log, D, dt_bias, scalars
    return P(*((None,) * len(shape)))


def shape_div(shape, idx: int, by: int = 16) -> bool:
    try:
        return shape[idx] % by == 0
    except IndexError:
        return False


def params_shardings(mesh: Mesh, params_tree) -> Any:
    """NamedSharding tree matching a (possibly abstract) params pytree.

    REPRO_SHARDING=replicated switches to pure data parallelism (params
    replicated, batch sharded) — the right-sized strategy for models far
    smaller than the mesh (§Perf: smollm-360m), where TP activation
    all-reduces dominate and per-chip weights are tiny anyway.
    """
    import os
    replicated = os.environ.get("REPRO_SHARDING") == "replicated"
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    out = []
    for path, leaf in flat:
        if replicated:
            out.append(NamedSharding(mesh, P()))
            continue
        keys = [getattr(p, "key", getattr(p, "idx", "")) for p in path]
        pstr = "/".join(str(k) for k in keys)
        spec = param_spec_from_path(pstr, leaf.shape,
                                    expert_mesh="expert" in mesh.shape)
        spec = _remap(mesh, spec)
        out.append(NamedSharding(mesh, _fit(mesh, leaf.shape, spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


def param_spec_from_path(pstr: str, shape, expert_mesh: bool = False) -> P:
    # cfg not needed: rules are purely structural
    return param_spec(None, pstr, shape, expert_mesh)   # type: ignore[arg-type]


def opt_state_shardings(mesh: Mesh, mu_tree) -> Any:
    """Optimizer-moment shardings. REPRO_ZERO=1 adds ZeRO-1: each moment
    leaf additionally shards its first divisible, still-unsharded dim over
    "data" (moments are only touched at the update point, so slicing them
    across data ranks costs one reduce-scatter/all-gather pair per step but
    divides their 8-bytes/param residency by the data-axis size)."""
    import os
    base = params_shardings(mesh, mu_tree)
    if os.environ.get("REPRO_ZERO") != "1":
        return base
    dsize = _axis_size(mesh, "data")
    flat_b, treedef = jax.tree_util.tree_flatten(base)
    flat_l = jax.tree_util.tree_leaves(mu_tree)
    out = []
    for sh, leaf in zip(flat_b, flat_l):
        spec = list(tuple(sh.spec) + (None,) * (leaf.ndim - len(tuple(sh.spec))))
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec)):
            if ax is None and dim % dsize == 0:
                spec[i] = "data"
                break
        out.append(NamedSharding(mesh, P(*spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


# --------------------------------------------------------------------- batch/cache
def batch_shardings(mesh: Mesh, batch_tree, shape_kind: str = "train") -> Any:
    """Shard batch dims on ("pod","data") when divisible; else replicate."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)

    def one(leaf):
        spec = [dp if i == 0 else None for i in range(leaf.ndim)]
        return NamedSharding(mesh, _fit(mesh, leaf.shape, P(*spec)))
    return jax.tree.map(one, batch_tree)


def cache_shardings(mesh: Mesh, cache_tree, *, seq_shard: bool = False) -> Any:
    """KV caches: (L, B, Hkv, S, D) — batch on data, heads on model.
    seq_shard=True (long-context, batch=1): shard the sequence dim on data
    instead of batch."""
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)

    def one_named(path, leaf):
        keys = [str(getattr(p, "key", "")) for p in path]
        name = keys[-1] if keys else ""
        nd = leaf.ndim
        if name == "pos":
            spec = P(dp) if not seq_shard else P(None)
        elif name in ("k", "v", "ak", "av", "ck", "cv",
                      "k_scale", "v_scale") and nd == 5:
            # (L, B, Hkv, S, D)
            ma = model_axes(mesh)
            tp_total = (int(np.prod([_axis_size(mesh, a) for a in ma]))
                        if isinstance(ma, tuple) else _axis_size(mesh, ma))
            if seq_shard:
                # long-context mode (batch < data axis): sequence on data
                spec = P(None, None, "model", dp, None)
            elif leaf.shape[2] % tp_total == 0:
                spec = P(None, dp, "model", None, None)
            else:
                # GQA head count doesn't divide the model axis: shard the
                # sequence dim on "model" instead of replicating 16x the cache
                # (flash-decode partials merge exactly under GSPMD collectives)
                spec = P(None, dp, None, "model", None)
        elif name == "conv" and nd == 4:        # (L, B, W-1, ch)
            spec = P(None, dp if not seq_shard else None, None, "model")
        elif name == "ssm" and nd == 5:         # (L, B, H, P, N)
            spec = P(None, dp if not seq_shard else None, "model", None, None)
        else:
            spec = P(*(None,) * nd)
        return NamedSharding(mesh, _fit(mesh, leaf.shape, _remap(mesh, spec)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    return jax.tree_util.tree_unflatten(
        treedef, [one_named(p, l) for p, l in flat])
