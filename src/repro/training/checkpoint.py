"""Checkpointing: flatten param/optimizer pytrees to a single .npz + json meta."""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def save(path: str, params, opt_state=None, meta: Dict[str, Any] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten({"params": params, **({"opt": opt_state} if opt_state else {})})
    np.savez(path, **flat)
    with open(path + ".meta.json", "w") as f:
        json.dump(meta or {}, f, indent=2, default=str)


def restore(path: str, params_template, opt_template=None) -> Tuple[Any, Any, Dict]:
    """Restore into the structure of the given templates."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    meta = {}
    mp = path + ".meta.json"
    if os.path.exists(mp):
        with open(mp) as f:
            meta = json.load(f)

    def rebuild(template, prefix):
        if isinstance(template, dict):
            return {k: rebuild(v, f"{prefix}{k}/") for k, v in template.items()}
        if isinstance(template, (list, tuple)):
            return type(template)(rebuild(v, f"{prefix}{i}/")
                                  for i, v in enumerate(template))
        arr = data[prefix[:-1]]
        return jnp.asarray(arr, dtype=template.dtype if hasattr(template, "dtype") else None)

    params = rebuild(params_template, "params/")
    opt = rebuild(opt_template, "opt/") if opt_template is not None else None
    return params, opt, meta
