"""Training loop: loss, train_step, metrics."""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training import optimizer as OPT


def _nll(pred, tgt):
    """Per-position negative log likelihood.

    REPRO_LOSS_IMPL selects the implementation (perf-iteration lever):
      softmax   — materialize the full (B, S, V) f32 log_softmax (baseline)
      logsumexp — nll = logsumexp(logits) - logits[target]: only (B, S) f32
                  temporaries beyond the bf16 logits themselves (optimized)
    """
    impl = os.environ.get("REPRO_LOSS_IMPL", "softmax")  # baseline default;
    # §Perf runs flip to logsumexp and record the delta
    if impl == "softmax":
        logp = jax.nn.log_softmax(pred.astype(jnp.float32), axis=-1)
        return -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    predf = pred.astype(jnp.float32)
    lse = jax.nn.logsumexp(predf, axis=-1)                       # (B, S)
    picked = jnp.take_along_axis(predf, tgt[..., None], axis=-1)[..., 0]
    return lse - picked


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray], *,
            backend: str = "auto", remat: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross entropy.

    batch["tokens"] (B, S) — positions 1..S-1 are predicted from 0..S-2.
    batch["loss_mask"] optional (B, S): 1 where the *target* counts.
    VLM: loss applies to text positions only (vision tokens are inputs).
    """
    logits, aux = M.forward_train(params, cfg, batch, backend=backend, remat=remat)
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    n_vis = logits.shape[1] - S_text          # 0 except VLM
    logits = logits[:, n_vis:, :]             # text-aligned
    nll = _nll(logits[:, :-1], tokens[:, 1:])
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(nll) if mask is None else mask[:, 1:].astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(1.0, jnp.sum(mask))
    return loss + aux, {"ce": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, opt: OPT.AdamWConfig, *,
                    backend: str = "auto", remat: bool = False):
    """Returns a jit-able train_step(params, opt_state, batch)."""

    def train_step(params, opt_state, batch):
        (loss, parts), grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, backend=backend, remat=remat),
            has_aux=True)(params)
        params, opt_state, om = OPT.apply_updates(opt, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def train_loop(cfg: ModelConfig, params, batches, opt: OPT.AdamWConfig, *,
               backend: str = "auto", remat: bool = False, log_every: int = 10,
               log=print):
    step_fn = jax.jit(make_train_step(cfg, opt, backend=backend, remat=remat))
    state = OPT.init_state(params)
    history = []
    for i, batch in enumerate(batches):
        params, state, m = step_fn(params, state, batch)
        if i % log_every == 0:
            loss = float(m["loss"])
            history.append((i, loss))
            log(f"step {i:5d}  loss {loss:.4f}  lr {float(m['lr']):.2e}  "
                f"gnorm {float(m['grad_norm']):.2f}")
    return params, state, history
