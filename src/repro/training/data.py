"""Synthetic data pipeline: seeded, shard-aware, learnable tasks.

``arithmetic_stream`` produces a fully learnable LM task (t_{i+1} =
(a*t_i + c) mod V) so example training shows a decreasing loss without any
external dataset. ``uniform_stream`` is for pure throughput benchmarking.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def arithmetic_stream(cfg: ModelConfig, batch_size: int, seq_len: int,
                      steps: int, seed: int = 0, a: int = 5, c: int = 7,
                      ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Affine-recurrence token stream — next token is a deterministic
    function of the previous one, so a 1-layer model can reach ~0 loss."""
    rng = np.random.default_rng(seed)
    V = cfg.vocab_size
    for _ in range(steps):
        t0 = rng.integers(0, V, size=(batch_size, 1))
        seq = [t0]
        for _ in range(seq_len - 1):
            seq.append((a * seq[-1] + c) % V)
        tokens = jnp.asarray(np.concatenate(seq, axis=1), jnp.int32)
        yield _attach_modalities(cfg, {"tokens": tokens}, rng)


def uniform_stream(cfg: ModelConfig, batch_size: int, seq_len: int,
                   steps: int, seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                          size=(batch_size, seq_len)), jnp.int32)
        yield _attach_modalities(cfg, {"tokens": tokens}, rng)


def _attach_modalities(cfg: ModelConfig, batch: Dict, rng) -> Dict:
    B = batch["tokens"].shape[0]
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_vision_tokens, cfg.d_model)), jnp.float32)
    return batch
