"""AdamW + schedules, raw pytree implementation (no optax in this container)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> dict:
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return {"mu": zeros(), "nu": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state) -> Tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a); new_mu.append(b); new_nu.append(c)
    return (jax.tree.unflatten(tdef, new_p),
            {"mu": jax.tree.unflatten(tdef, new_mu),
             "nu": jax.tree.unflatten(tdef, new_nu), "step": step},
            {"lr": lr, "grad_norm": gnorm})
