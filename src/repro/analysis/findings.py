"""Finding model shared by every repro-lint checker.

A ``Finding`` is one diagnostic: a rule id, a severity, a location and a
message. Findings are value objects (frozen, ordered) so the CLI can sort,
de-duplicate and diff them against a committed baseline.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from typing import Optional

WARNING = "warning"
ERROR = "error"

#: severity rank used by ``--fail-on`` (higher = more severe)
SEVERITY_RANK = {WARNING: 1, ERROR: 2}


@dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic emitted by a checker."""
    path: str
    line: int
    col: int
    rule: str
    severity: str
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITY_RANK:
            raise ValueError(f"unknown severity {self.severity!r}")

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.severity} [{self.rule}] {self.message}")

    def key(self) -> tuple:
        """Baseline identity: location-insensitive so grandfathered findings
        survive unrelated line churn in the same file."""
        return (self.path, self.rule, self.message)


@dataclass
class RawFinding:
    """Checker-side finding, pre-location: carries the AST node so the
    framework can resolve line/col and statement-extent suppressions
    uniformly."""
    node: ast.AST
    rule: str
    severity: str
    message: str

    def at(self, path: str) -> Finding:
        return Finding(path=path,
                       line=getattr(self.node, "lineno", 1),
                       col=getattr(self.node, "col_offset", 0),
                       rule=self.rule, severity=self.severity,
                       message=self.message)
