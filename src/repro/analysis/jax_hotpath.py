"""Host-sync and trace hazards on JAX hot paths.

The serving contract since PR 3 is *one* host sync per batcher tick; jitted
step functions must stay on device. This checker tracks device provenance
through a function body (values produced by ``jnp.*``/``jax.*`` calls,
engine step methods, or class attributes assigned device values anywhere in
the class) and flags operations that force a device->host transfer or a
retrace where they hurt:

  hot scopes
    * functions decorated ``@jax.jit`` (also via ``functools.partial``) —
      every parameter is a tracer there;
    * any method of a class whose name contains ``Batcher`` (tick loops);
    * the body of any ``for``/``while`` loop elsewhere (per-iteration sync).

  rules
    jax-host-sync      np.asarray/np.array/int()/float()/bool()/.item()/
                       .tolist() applied to a traced value in a hot scope
    jax-traced-branch  Python ``if``/``while``/ternary/``assert`` on a
                       traced value, or iterating one, in a hot scope
    jax-recompile      inside @jax.jit: numpy ops on tracers or python
                       slicing with traced bounds (shape becomes dynamic)

``np.asarray(x)`` yields a *host* value: subsequent ``int(toks[i])`` is
clean. Intentional syncs (the batcher's single per-tick transfer, EOS
checks) are marked ``# repro-lint: allow[jax-host-sync]`` at the call site.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from repro.analysis.findings import ERROR, WARNING, RawFinding
from repro.analysis.framework import (ParsedModule, decorator_names,
                                      dotted_name, root_name)

#: methods whose results live on device (engine/model step functions)
_PRODUCER_METHODS = {
    "prefill", "decode", "decode_paged", "prefill_chunk", "generate_step",
    "_prefill", "_decode", "_decode_paged", "_prefill_chunk", "_select",
    "new_cache", "new_paged_cache", "init_cache", "init_paged_cache",
    "apply", "sample",
}

_SYNC_BUILTINS = {"int", "float", "bool", "complex"}
_SYNC_METHODS = {"item", "tolist", "__array__"}
_NP_MODULES = {"np", "numpy", "onp"}
_JAX_MODULES = {"jnp", "jax", "lax"}
#: attribute reads that are static metadata, not device data
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}
#: calls that return host/static values even on traced args
_HOST_RESULT_CALLS = {"len", "range", "isinstance", "getattr", "type", "id",
                      "repr", "str"}


_JIT_NAMES = ("jax.jit", "jit", "pjit", "jax.pjit")


def _is_jit_decorated(fn) -> bool:
    names = decorator_names(fn)
    return any(n in _JIT_NAMES for n in names)


def _jit_static_params(fn) -> Set[str]:
    """Parameter names marked static via static_argnames/static_argnums in a
    ``@jax.jit``/``functools.partial(jax.jit, ...)`` decorator — these are
    Python values, not tracers."""
    static: Set[str] = set()
    a = fn.args
    positional = [p.arg for p in (a.posonlyargs + a.args)]
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        involved = [dotted_name(dec.func)] + \
            [dotted_name(x) for x in dec.args]
        if not any(n in _JIT_NAMES for n in involved if n):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value, str):
                        static.add(c.value)
            elif kw.arg == "static_argnums":
                for c in ast.walk(kw.value):
                    if isinstance(c, ast.Constant) and isinstance(c.value, int):
                        if 0 <= c.value < len(positional):
                            static.add(positional[c.value])
    return static


def _is_jaxish_call(node: ast.Call) -> bool:
    callee = dotted_name(node.func)
    if callee:
        head = callee.split(".", 1)[0]
        if head in _JAX_MODULES:
            return callee not in ("jax.jit", "jax.block_until_ready")
    if isinstance(node.func, ast.Attribute) \
            and node.func.attr in _PRODUCER_METHODS:
        return True
    return False


class JaxHotPathChecker:
    name = "jax-hot-path"
    rules = {
        "jax-host-sync": "device->host transfer on a JAX hot path",
        "jax-traced-branch": "Python control flow on a traced/device value",
        "jax-recompile": "recompile/host-fallback hazard inside @jax.jit",
    }

    def check(self, module: ParsedModule) -> Iterable[RawFinding]:
        out: List[RawFinding] = []
        for node in module.tree.body:
            self._walk_toplevel(node, out, class_ctx=None)
        return out

    def _walk_toplevel(self, node, out, class_ctx) -> None:
        if isinstance(node, ast.ClassDef):
            traced_attrs = _class_traced_attrs(node)
            hot_class = "Batcher" in node.name or "Engine" in node.name
            for sub in node.body:
                self._walk_toplevel(sub, out,
                                    class_ctx=(hot_class, traced_attrs))
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            hot_class, traced_attrs = class_ctx or (False, frozenset())
            out.extend(_FunctionScan(node, jit=_is_jit_decorated(node),
                                     hot_method=hot_class,
                                     traced_attrs=traced_attrs).run())
            for sub in ast.walk(node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub is not node:
                    out.extend(_FunctionScan(
                        sub, jit=_is_jit_decorated(sub),
                        hot_method=hot_class,
                        traced_attrs=traced_attrs).run())


def _class_traced_attrs(cls: ast.ClassDef) -> frozenset:
    """Attributes assigned device values anywhere in the class body
    (``self.cache = jnp.zeros(...)`` in __init__ makes ``self.cache``
    traced in every method)."""
    attrs: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign):
            if _seed_traced_expr(node.value, attrs):
                flat = []
                for t in node.targets:
                    flat.extend(t.elts if isinstance(t, (ast.Tuple, ast.List))
                                else [t])
                for t in flat:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        attrs.add(t.attr)
    return frozenset(attrs)


def _seed_traced_expr(node, attrs: Set[str]) -> bool:
    """Conservative 'is this expression device-valued' for attr seeding."""
    if isinstance(node, ast.Call):
        if _is_jaxish_call(node):
            return True
        callee = dotted_name(node.func)
        if callee in ("dict",) or (callee and callee.startswith("dict")):
            return any(_seed_traced_expr(kw.value, attrs)
                       for kw in node.keywords)
        return False
    if isinstance(node, (ast.Subscript, ast.Attribute)):
        return _seed_traced_expr(node.value, attrs)
    if isinstance(node, ast.Name):
        return False
    if isinstance(node, ast.Attribute):
        return node.attr in attrs
    return False


class _FunctionScan:
    def __init__(self, fn, *, jit: bool, hot_method: bool,
                 traced_attrs: frozenset):
        self.fn = fn
        self.jit = jit
        self.hot_method = hot_method
        self.traced_attrs = traced_attrs
        self.loop_depth = 0
        self.findings: List[RawFinding] = []
        self.traced: Set[str] = set()
        if jit:
            static = _jit_static_params(fn)
            a = fn.args
            for p in (a.posonlyargs + a.args + a.kwonlyargs):
                if p.arg not in ("self", "cls") and p.arg not in static:
                    self.traced.add(p.arg)

    # hot = a per-iteration context where a sync is a per-tick cost
    @property
    def hot(self) -> bool:
        return self.jit or self.hot_method or self.loop_depth > 0

    def run(self) -> List[RawFinding]:
        for stmt in self.fn.body:
            self.stmt(stmt)
        return self.findings

    def report(self, node, rule, severity, message):
        self.findings.append(RawFinding(node, rule, severity, message))

    # -------------------------------------------------------------- tracking
    def is_traced(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.traced
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            if isinstance(node.value, ast.Name) and node.value.id == "self":
                return node.attr in self.traced_attrs
            return self.is_traced(node.value)
        if isinstance(node, ast.Subscript):
            return self.is_traced(node.value)
        if isinstance(node, ast.Call):
            return self.call_traced(node)
        if isinstance(node, ast.BinOp):
            return self.is_traced(node.left) or self.is_traced(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_traced(node.operand)
        if isinstance(node, ast.Compare):
            return self.is_traced(node.left) \
                or any(self.is_traced(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.is_traced(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.is_traced(node.body) or self.is_traced(node.orelse)
        return False

    def call_traced(self, node: ast.Call) -> bool:
        callee = dotted_name(node.func)
        if callee:
            head = callee.split(".", 1)[0]
            leaf = callee.rsplit(".", 1)[-1]
            if callee in _HOST_RESULT_CALLS or leaf in _SYNC_METHODS \
                    or callee in _SYNC_BUILTINS:
                return False            # result lands on host
            if head in _NP_MODULES:
                return False            # numpy result is host-side
        if _is_jaxish_call(node):
            return True
        # method call on a traced receiver (.astype, .at[i].set, ...)
        if isinstance(node.func, ast.Attribute) \
                and self.is_traced(node.func.value):
            return True
        # calling a traced callable (self._prefill = jax.jit(...))
        if self.is_traced(node.func) and not isinstance(node.func,
                                                        ast.Attribute):
            return True
        # plain constructors propagate (dict(cache, k=traced), tuple, ...)
        if callee in ("dict", "tuple", "list"):
            return any(self.is_traced(a) for a in node.args) \
                or any(self.is_traced(k.value) for k in node.keywords)
        return False

    def mark(self, target, traced: bool) -> None:
        if isinstance(target, ast.Name):
            if traced:
                self.traced.add(target.id)
            else:
                self.traced.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self.mark(e, traced)
        elif isinstance(target, ast.Starred):
            self.mark(target.value, traced)

    # ------------------------------------------------------------ statements
    def stmt(self, s) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return                      # scanned separately
        if isinstance(s, ast.Assign):
            self.expr(s.value)
            t = self.is_traced(s.value)
            for target in s.targets:
                self.mark(target, t)
            return
        if isinstance(s, (ast.AnnAssign, ast.AugAssign)):
            if getattr(s, "value", None) is not None:
                self.expr(s.value)
                if isinstance(s.target, ast.Name):
                    if isinstance(s, ast.AugAssign):
                        if self.is_traced(s.value):
                            self.traced.add(s.target.id)
                    else:
                        self.mark(s.target, self.is_traced(s.value))
            return
        if isinstance(s, (ast.If, ast.While)):
            self.expr(s.test)
            # a while-test re-evaluates every iteration: hot by definition
            if (self.hot or isinstance(s, ast.While)) \
                    and self.is_traced(s.test) \
                    and not _is_sync_call(s.test):
                kind = "if" if isinstance(s, ast.If) else "while"
                self.report(s, "jax-traced-branch", ERROR,
                            f"`{kind}` on a traced value forces a host sync "
                            f"per evaluation; use jnp.where/lax.cond or sync "
                            f"once outside the loop")
            if isinstance(s, ast.While):
                self.loop_depth += 1
            for b in s.body + s.orelse:
                self.stmt(b)
            if isinstance(s, ast.While):
                self.loop_depth -= 1
            return
        if isinstance(s, ast.For):
            self.expr(s.iter)
            if self.hot and self.is_traced(s.iter):
                self.report(s, "jax-traced-branch", ERROR,
                            "Python iteration over a traced value transfers "
                            "one element per step; transfer once with "
                            "np.asarray and iterate the host copy")
            self.mark(s.target, False)
            self.loop_depth += 1
            for b in s.body + s.orelse:
                self.stmt(b)
            self.loop_depth -= 1
            return
        if isinstance(s, ast.Return):
            if s.value is not None:
                self.expr(s.value)
            return
        if isinstance(s, ast.Expr):
            self.expr(s.value)
            return
        if isinstance(s, ast.With):
            for item in s.items:
                self.expr(item.context_expr)
            for b in s.body:
                self.stmt(b)
            return
        if isinstance(s, ast.Try):
            for b in s.body + s.orelse + s.finalbody:
                self.stmt(b)
            for h in s.handlers:
                for b in h.body:
                    self.stmt(b)
            return
        if isinstance(s, ast.Assert):
            self.expr(s.test)
            if self.hot and self.is_traced(s.test):
                self.report(s, "jax-traced-branch", ERROR,
                            "assert on a traced value syncs the device; use "
                            "checkify or debug.print, or assert on shapes")
            return

    # ----------------------------------------------------------- expressions
    def expr(self, node) -> None:
        if node is None:
            return
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self.check_call(sub)
            elif isinstance(sub, ast.IfExp) and self.hot \
                    and self.is_traced(sub.test):
                self.report(sub, "jax-traced-branch", ERROR,
                            "ternary on a traced value forces a host sync")
            elif isinstance(sub, ast.Subscript) and self.jit \
                    and isinstance(sub.slice, ast.Slice):
                bounds = [b for b in (sub.slice.lower, sub.slice.upper,
                                      sub.slice.step) if b is not None]
                if any(self.is_traced(b) for b in bounds):
                    self.report(sub, "jax-recompile", WARNING,
                                "slice bounds depend on a traced value: "
                                "dynamic shapes retrace or fail under jit; "
                                "use lax.dynamic_slice")

    def check_call(self, node: ast.Call) -> None:
        callee = dotted_name(node.func)
        if not callee:
            return
        head = callee.split(".", 1)[0]
        leaf = callee.rsplit(".", 1)[-1]
        args_traced = any(self.is_traced(a) for a in node.args)
        if callee in _SYNC_BUILTINS and len(node.args) == 1 and args_traced:
            if self.hot:
                self.report(node, "jax-host-sync", ERROR,
                            f"{callee}() on a traced value blocks on the "
                            f"device in a hot scope")
            return
        if leaf in _SYNC_METHODS and isinstance(node.func, ast.Attribute) \
                and self.is_traced(node.func.value):
            if self.hot:
                self.report(node, "jax-host-sync", ERROR,
                            f".{leaf}() on a traced value blocks on the "
                            f"device in a hot scope")
            return
        if head in _NP_MODULES and args_traced:
            if self.jit:
                self.report(node, "jax-recompile", WARNING,
                            f"numpy op {callee}() on a tracer inside @jax.jit"
                            f" constant-folds or fails; use jnp.{leaf}")
            elif self.hot:
                self.report(node, "jax-host-sync", ERROR,
                            f"{callee}() transfers a device value to host in "
                            f"a hot scope")
            return


def _is_sync_call(node) -> bool:
    """`if bool(x):` is already reported at the bool() call."""
    return isinstance(node, ast.Call)
