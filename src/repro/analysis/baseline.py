"""Baseline files: grandfather existing findings, gate only on new ones.

A baseline is a JSON document listing known findings by their
location-insensitive key (path, rule, message). ``filter_findings`` removes
current findings that match an entry (consuming entries one-for-one, so two
identical findings need two baseline entries) and reports entries that no
longer match anything — stale entries mean the debt was paid and the baseline
should be regenerated with ``--write-baseline``.

The shipped baseline (``analysis/baseline.json``) is empty: src/ and
benchmarks/ lint clean, and the CI gate keeps them that way.
"""
from __future__ import annotations

import json
from collections import Counter
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from repro.analysis.findings import Finding

FORMAT_VERSION = 1


def save_baseline(path: str, findings: Sequence[Finding]) -> None:
    doc = {
        "version": FORMAT_VERSION,
        "findings": [
            {"path": f.path, "rule": f.rule, "message": f.message,
             "line": f.line}
            for f in sorted(findings)
        ],
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")


def load_baseline(path: str) -> List[Tuple[str, str, str]]:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("version") != FORMAT_VERSION:
        raise ValueError(f"unsupported baseline version {doc.get('version')!r}"
                         f" in {path}")
    return [(e["path"], e["rule"], e["message"]) for e in doc["findings"]]


@dataclass
class BaselineResult:
    new: List[Finding]          # findings not covered by the baseline
    matched: List[Finding]      # grandfathered findings
    stale: List[Tuple[str, str, str]]   # baseline entries with no match


def filter_findings(findings: Sequence[Finding],
                    entries: Sequence[Tuple[str, str, str]]) -> BaselineResult:
    budget = Counter(entries)
    new: List[Finding] = []
    matched: List[Finding] = []
    for f in sorted(findings):
        k = f.key()
        if budget.get(k, 0) > 0:
            budget[k] -= 1
            matched.append(f)
        else:
            new.append(f)
    stale = [k for k, n in budget.items() for _ in range(n) if n > 0]
    return BaselineResult(new=new, matched=matched, stale=stale)
