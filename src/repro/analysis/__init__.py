"""repro.analysis — static analysis for the repro codebase.

Three checkers over a shared AST framework (see ``framework``):

* ``units``           dimensional analysis from the unit-suffix convention
* ``jax-hot-path``    host-sync / trace hazards on JAX hot paths
* ``scheduler-purity`` no self-mutation in Scheduler.choose/dispatch

Run with ``python -m repro.analysis`` or the ``repro-lint`` entry point.
"""
from repro.analysis.findings import ERROR, WARNING, Finding, RawFinding
from repro.analysis.framework import (analyze_paths, analyze_source,
                                      default_checkers)

__all__ = ["ERROR", "WARNING", "Finding", "RawFinding", "analyze_paths",
           "analyze_source", "default_checkers", "main"]


def main(argv=None):
    from repro.analysis.cli import main as _main
    return _main(argv)
