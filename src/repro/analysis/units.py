"""Dimensional analysis over the repo's unit-suffix naming convention.

Every physical quantity in this codebase carries its unit in its name
(``wait_s``, ``energy_j``, ``power_w``, ``j_per_token``, ``t_prefill``);
this checker turns that convention into algebra. Dimensions are exponent
vectors over (time, energy, tokens):

    time  [s]        (1, 0, 0)      seconds/ms/us/hours
    energy [J]       (0, 1, 0)      joules/Wh/kWh
    power  [W]       (-1, 1, 0)     energy per time
    tokens           (0, 0, 1)      token counts
    s/token          (1, 0, -1)
    J/token          (0, 1, -1)
    dimensionless    (0, 0, 0)      counts, fractions, literals

Multiplication/division adds/subtracts exponents (``_w * _s`` is energy,
``_j / tokens`` is J/token); addition, subtraction, comparison and min/max
require equal exponents. Unknown names are wildcards — the checker only
speaks when both sides of an operation are known, so it is quiet on code
that ignores the convention and precise on code that uses it.

Rules:
  unit-add            mixing dimensions in +/-/comparison/min/max
  unit-assign         value of one dimension bound to a name of another
  unit-return         function's suffix dimension != its return dimension
  unit-derived-name   product/quotient of unit-bearing names assigned to a
                      name with no unit suffix (warning)
  unit-field          numeric dataclass field naming an energy/power/time
                      quantity without a unit suffix
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, replace
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Tuple

from repro.analysis.findings import ERROR, WARNING, RawFinding
from repro.analysis.framework import ParsedModule, decorator_names, dotted_name

# ------------------------------------------------------------------ dimensions

Exp = Tuple[int, int, int]     # (time, energy, tokens) exponents

SCALAR_EXP: Exp = (0, 0, 0)
TIME_EXP: Exp = (1, 0, 0)
ENERGY_EXP: Exp = (0, 1, 0)
POWER_EXP: Exp = (-1, 1, 0)
TOKENS_EXP: Exp = (0, 0, 1)
T_PER_TOK_EXP: Exp = (1, 0, -1)
E_PER_TOK_EXP: Exp = (0, 1, -1)

_EXP_NAMES = {
    SCALAR_EXP: "dimensionless",
    TIME_EXP: "time [s]",
    ENERGY_EXP: "energy [J]",
    POWER_EXP: "power [W]",
    TOKENS_EXP: "token count",
    T_PER_TOK_EXP: "time per token [s/token]",
    E_PER_TOK_EXP: "energy per token [J/token]",
}
RECOGNIZED = frozenset(_EXP_NAMES)

#: derived results worth a naming complaint when bound to a unit-less name
_INTERESTING = frozenset({TIME_EXP, ENERGY_EXP, POWER_EXP,
                          T_PER_TOK_EXP, E_PER_TOK_EXP})


@dataclass(frozen=True)
class Dim:
    exp: Exp
    scale: float = 1.0          # e.g. _ms -> 1e-3 relative to seconds
    reliable: bool = False      # scale read straight off a suffix
    derived: bool = False       # produced by unit arithmetic (*, /)

    @property
    def name(self) -> str:
        return _EXP_NAMES[self.exp]

    @property
    def nonscalar(self) -> bool:
        return self.exp != SCALAR_EXP


SCALAR = Dim(SCALAR_EXP)
TIME = Dim(TIME_EXP, reliable=True)
ENERGY = Dim(ENERGY_EXP, reliable=True)
POWER = Dim(POWER_EXP, reliable=True)
TOKENS = Dim(TOKENS_EXP)
T_PER_TOK = Dim(T_PER_TOK_EXP)
E_PER_TOK = Dim(E_PER_TOK_EXP)


def _mul_exp(a: Exp, b: Exp, sign: int) -> Optional[Exp]:
    exp = tuple(x + sign * y for x, y in zip(a, b))
    return exp if exp in RECOGNIZED else None


def dim_mul(a: Optional[Dim], b: Optional[Dim], sign: int = 1) -> Optional[Dim]:
    """sign=+1 multiply, -1 divide. None (unknown) contaminates."""
    if a is None or b is None:
        return None
    exp = _mul_exp(a.exp, b.exp, sign)
    if exp is None:
        return None
    derived = ((a.nonscalar and b.nonscalar) or a.derived or b.derived) \
        and exp != SCALAR_EXP
    return Dim(exp, derived=derived)


# ---------------------------------------------------------------- name grammar

_TIME_UNITS = {"s": 1.0, "sec": 1.0, "secs": 1.0, "second": 1.0,
               "seconds": 1.0, "ms": 1e-3, "us": 1e-6, "ns": 1e-9,
               "hour": 3600.0, "hours": 3600.0, "hr": 3600.0, "hrs": 3600.0}
_ENERGY_UNITS = {"j": 1.0, "joule": 1.0, "joules": 1.0,
                 "wh": 3600.0, "kwh": 3.6e6}
_POWER_UNITS = {"w": 1.0, "watt": 1.0, "watts": 1.0, "kw": 1e3}
_TOKEN_WORDS = {"tokens", "token", "toks"}
_COUNT_WORDS = {"count", "counts", "len", "blocks", "slots", "instances",
                "chips", "queries", "lanes", "steps", "iters", "ticks",
                "wakes", "hits", "misses", "layers", "experts",
                "bytes", "byte"}
#: unit-bearing but outside the modeled algebra (rates, bandwidths etc.)
_RATE_WORDS = {"qps", "hz", "rps", "gbps"}
#: one-letter/short unit tokens need a preceding underscore to count
_SHORT_UNITS = {"s", "j", "w", "ms", "us", "ns", "wh", "kw", "hr", "sec"}

#: full words that imply a dimension even without a unit suffix
_KEYWORD_DIMS = {
    "energy": ENERGY, "joule": ENERGY, "joules": ENERGY,
    "power": POWER, "watts": POWER, "wattage": POWER,
    "latency": TIME, "runtime": TIME, "wait": TIME, "delay": TIME,
    "duration": TIME, "linger": TIME, "timeout": TIME, "period": TIME,
    "interval": TIME, "elapsed": TIME, "horizon": TIME, "uptime": TIME,
    "time": TIME,
}

#: method names whose return dimension is part of the repo's API contract
_KNOWN_CALLS = {"power": POWER, "state_power": POWER, "energy": ENERGY,
                "runtime": TIME}
_MODULE_RECEIVERS = {"np", "jnp", "jax", "numpy", "math", "scipy", "lax"}

#: numeric pass-through callables: result dim = dim of the first data arg
_PASSTHROUGH = {"abs", "float", "int", "round", "sum"}
_NP_PASSTHROUGH = {"sum", "mean", "median", "percentile", "min", "max",
                   "abs", "maximum", "minimum", "asarray", "array",
                   "cumsum", "float64", "float32"}

#: names that declare themselves dimensionless
_DIMLESS_WORDS = {"frac", "fraction", "ratio", "norm", "factor", "scale",
                  "coeff", "coef", "util", "utilization", "pct", "percent",
                  "share", "weight", "lam", "attainment", "eff"}

#: dataclass-field words that name a physical quantity (rule unit-field)
_QUANTITY_WORDS = {"energy", "joule", "joules", "power", "watt", "watts",
                   "wattage", "draw", "latency", "wait", "delay", "duration",
                   "runtime", "linger", "timeout", "period", "interval",
                   "elapsed", "horizon", "uptime", "time"}


@dataclass(frozen=True)
class NameInfo:
    dim: Optional[Dim]
    has_unit: bool              # satisfies the suffix convention


_UNKNOWN = NameInfo(None, False)
_ANNOTATED = NameInfo(None, True)


@lru_cache(maxsize=4096)
def classify_name(name: str) -> NameInfo:
    toks = [t for t in name.lower().lstrip("_").split("_") if t]
    if not toks:
        return _UNKNOWN
    # t_ prefix convention: t_prefill, t_decode, t_tok are seconds.
    # t_in/t_out are the paper's token-count *thresholds* — repo idiom,
    # explicitly excluded.
    if toks[0] == "t" and len(toks) > 1 and toks[1] not in ("in", "out"):
        return NameInfo(TIME, True)
    # per-patterns: j_per_token, fleet_j_per_token, g_per_kwh, qps ...
    if "per" in toks[1:]:
        i = len(toks) - 1 - toks[::-1].index("per")
        base, denom = toks[:i], toks[i + 1:]
        if denom in (["token"], ["tok"], ["toks"], ["query"]):
            last = base[-1] if base else ""
            if last in _ENERGY_UNITS or last in ("energy",):
                return NameInfo(E_PER_TOK, True)
            if last in _TIME_UNITS or last in ("latency", "runtime"):
                return NameInfo(T_PER_TOK, True)
        return _ANNOTATED
    last = toks[-1]
    if last in _SHORT_UNITS and len(toks) < 2:
        return _UNKNOWN                      # bare 's'/'j'/'w' names
    if last in _TIME_UNITS:
        return NameInfo(Dim(TIME_EXP, scale=_TIME_UNITS[last], reliable=True),
                        True)
    if last in _ENERGY_UNITS:
        return NameInfo(Dim(ENERGY_EXP, scale=_ENERGY_UNITS[last],
                            reliable=True), True)
    if last in _POWER_UNITS:
        return NameInfo(Dim(POWER_EXP, scale=_POWER_UNITS[last],
                            reliable=True), True)
    if last in _TOKEN_WORDS:
        return NameInfo(TOKENS, True)
    if last in _COUNT_WORDS:
        return NameInfo(SCALAR, True)
    if last in _RATE_WORDS:
        return _ANNOTATED
    if last in _DIMLESS_WORDS:
        # declared dimensionless-ish, but opaque to the algebra: dividing
        # energy by `e_norm` (a same-dimension reference) NORMALIZES it —
        # treating the norm as a plain scalar would mislabel the quotient
        return _ANNOTATED
    if last in _KEYWORD_DIMS:
        return NameInfo(_KEYWORD_DIMS[last], False)
    return _UNKNOWN


# ----------------------------------------------------------------- the checker

class UnitsChecker:
    name = "units"
    rules = {
        "unit-add": "mixing dimensions in addition/subtraction/comparison",
        "unit-assign": "value of one dimension bound to a name of another",
        "unit-return": "function suffix dimension != returned dimension",
        "unit-derived-name": "unit arithmetic result assigned to a "
                             "suffix-less name",
        "unit-field": "numeric dataclass field names a physical quantity "
                      "but carries no unit suffix",
    }

    def check(self, module: ParsedModule) -> Iterable[RawFinding]:
        out: List[RawFinding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                out.extend(self._check_dataclass(node))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(_FunctionUnits(node).run())
        return out

    # dataclass field rule -------------------------------------------------
    def _check_dataclass(self, cls: ast.ClassDef) -> Iterable[RawFinding]:
        decs = decorator_names(cls)
        if not any(d == "dataclass" or d.endswith(".dataclass") for d in decs):
            return
        for stmt in cls.body:
            if not (isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)):
                continue
            if not _numeric_annotation(stmt.annotation):
                continue
            fname = stmt.target.id
            info = classify_name(fname)
            if info.has_unit:
                continue
            toks = set(fname.lower().lstrip("_").split("_"))
            if toks & _DIMLESS_WORDS:
                continue
            hit = toks & _QUANTITY_WORDS
            if hit:
                yield RawFinding(
                    stmt, "unit-field", ERROR,
                    f"field '{cls.name}.{fname}' names a physical quantity "
                    f"({'/'.join(sorted(hit))}) but has no unit suffix — "
                    f"append _s/_j/_w (or _per_token)")


def _numeric_annotation(ann: ast.AST) -> bool:
    if isinstance(ann, ast.Name):
        return ann.id in ("int", "float")
    if isinstance(ann, ast.Subscript):      # Optional[float]
        base = dotted_name(ann.value) or ""
        if base.split(".")[-1] == "Optional":
            return _numeric_annotation(ann.slice)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return _numeric_annotation(ann.left) or _numeric_annotation(ann.right)
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value in ("int", "float")
    return False


class _FunctionUnits:
    """Single-pass dimensional walk of one function body."""

    def __init__(self, fn):
        self.fn = fn
        self.env: Dict[str, Optional[Dim]] = {}
        self.findings: List[RawFinding] = []
        self.fn_dim = classify_name(fn.name).dim
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs):
            self.env[a.arg] = classify_name(a.arg).dim
        if args.vararg:
            self.env[args.vararg.arg] = None
        if args.kwarg:
            self.env[args.kwarg.arg] = None

    def run(self) -> List[RawFinding]:
        for stmt in self.fn.body:
            self.stmt(stmt)
        return self.findings

    def report(self, node, rule, severity, message):
        self.findings.append(RawFinding(node, rule, severity, message))

    # ------------------------------------------------------------ statements
    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef)):
            return                          # analyzed independently
        if isinstance(s, ast.Assign):
            v = self.expr(s.value)
            for t in s.targets:
                self.bind(t, v, s.value)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self.bind(s.target, self.expr(s.value), s.value)
        elif isinstance(s, ast.AugAssign):
            self.augassign(s)
        elif isinstance(s, ast.Return):
            if s.value is not None:
                v = self.expr(s.value)
                if (self.fn_dim is not None and v is not None
                        and self.fn_dim.nonscalar and v.nonscalar
                        and v.exp != self.fn_dim.exp):
                    self.report(s, "unit-return", ERROR,
                                f"'{self.fn.name}' is named as "
                                f"{self.fn_dim.name} but returns {v.name}")
        elif isinstance(s, (ast.If, ast.While)):
            self.expr(s.test)
            for b in s.body + s.orelse:
                self.stmt(b)
        elif isinstance(s, ast.For):
            it = self.expr(s.iter)
            self.bind(s.target, it, s.iter, check=False)
            for b in s.body + s.orelse:
                self.stmt(b)
        elif isinstance(s, ast.With):
            for item in s.items:
                self.expr(item.context_expr)
            for b in s.body:
                self.stmt(b)
        elif isinstance(s, ast.Try):
            for b in s.body + s.orelse + s.finalbody:
                self.stmt(b)
            for h in s.handlers:
                for b in h.body:
                    self.stmt(b)
        elif isinstance(s, ast.Expr):
            self.expr(s.value)
        elif isinstance(s, ast.Assert):
            self.expr(s.test)
        elif isinstance(s, (ast.Raise,)):
            if s.exc is not None:
                self.expr(s.exc)
        # Pass/Break/Continue/Import/Global/Delete: nothing dimensional

    def bind(self, target, v: Optional[Dim], value_node, check: bool = True):
        if isinstance(target, ast.Name):
            declared = classify_name(target.id)
            if check:
                self.assign_check(target, target.id, declared, v, value_node)
            if _is_literal(value_node):
                # `x = 0.0` declares nothing: keep the name's own dimension
                # so later `x += e_j` accumulation is still visible
                self.env[target.id] = declared.dim
            else:
                self.env[target.id] = v if v is not None else declared.dim
        elif isinstance(target, ast.Attribute):
            declared = classify_name(target.attr)
            if check:
                self.assign_check(target, target.attr, declared, v, value_node)
        elif isinstance(target, (ast.Tuple, ast.List)):
            if isinstance(value_node, (ast.Tuple, ast.List)) \
                    and len(value_node.elts) == len(target.elts):
                for t, vn in zip(target.elts, value_node.elts):
                    self.bind(t, self.expr_cached(vn), vn, check=check)
            else:
                for t in target.elts:
                    self.bind(t, None, value_node, check=False)
        elif isinstance(target, ast.Subscript):
            self.expr(target.value)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, None, value_node, check=False)

    # Tuple-value elements were already visited by self.expr on the whole
    # value; re-deriving their dim must not double-report, so route through a
    # no-report evaluation.
    def expr_cached(self, node) -> Optional[Dim]:
        mark = len(self.findings)
        d = self.expr(node)
        del self.findings[mark:]
        return d

    def assign_check(self, node, name: str, declared: NameInfo,
                     v: Optional[Dim], value_node) -> None:
        if v is None or not v.nonscalar:
            return
        if _is_literal(value_node):
            return
        if declared.dim is not None and declared.dim.nonscalar:
            if declared.dim.exp != v.exp:
                self.report(node, "unit-assign", ERROR,
                            f"'{name}' is named as {declared.dim.name} but "
                            f"is assigned {v.name}")
            return
        if v.derived and v.exp in _INTERESTING and not declared.has_unit:
            suffix = {TIME_EXP: "_s", ENERGY_EXP: "_j", POWER_EXP: "_w",
                      T_PER_TOK_EXP: "_s_per_token",
                      E_PER_TOK_EXP: "_j_per_token"}[v.exp]
            self.report(node, "unit-derived-name", WARNING,
                        f"{v.name} result assigned to '{name}' which has no "
                        f"unit suffix (expected e.g. '{name}{suffix}')")

    def augassign(self, s: ast.AugAssign) -> None:
        v = self.expr(s.value)
        t: Optional[Dim] = None
        nm = None
        if isinstance(s.target, ast.Name):
            nm = s.target.id
            t = self.env.get(nm, classify_name(nm).dim)
        elif isinstance(s.target, ast.Attribute):
            nm = s.target.attr
            t = classify_name(nm).dim
        if isinstance(s.op, (ast.Add, ast.Sub)):
            r = self.add_combine(t, v, s, "augmented assignment")
            declared = classify_name(nm) if nm is not None else _UNKNOWN
            if (v is not None and v.derived and v.exp in _INTERESTING
                    and declared.dim is None and not declared.has_unit
                    and (t is None or not t.nonscalar)):
                suffix = {TIME_EXP: "_s", ENERGY_EXP: "_j", POWER_EXP: "_w",
                          T_PER_TOK_EXP: "_s_per_token",
                          E_PER_TOK_EXP: "_j_per_token"}[v.exp]
                self.report(s, "unit-derived-name", WARNING,
                            f"{v.name} accumulates into '{nm}' which has no "
                            f"unit suffix (expected e.g. '{nm}{suffix}')")
            if isinstance(s.target, ast.Name) and nm is not None:
                self.env[nm] = r
        elif isinstance(s.op, (ast.Mult, ast.Div)):
            if isinstance(s.target, ast.Name) and nm is not None:
                self.env[nm] = dim_mul(t, v, 1 if isinstance(s.op, ast.Mult)
                                       else -1)

    # ----------------------------------------------------------- expressions
    def expr(self, node) -> Optional[Dim]:
        if node is None:
            return None
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)) \
                    and not isinstance(node.value, bool):
                return SCALAR
            return None
        if isinstance(node, ast.Name):
            if node.id in self.env:
                return self.env[node.id]
            return classify_name(node.id).dim
        if isinstance(node, ast.Attribute):
            self.expr(node.value)
            return classify_name(node.attr).dim
        if isinstance(node, ast.BinOp):
            return self.binop(node)
        if isinstance(node, ast.UnaryOp):
            d = self.expr(node.operand)
            return d if isinstance(node.op, (ast.USub, ast.UAdd)) else None
        if isinstance(node, ast.Compare):
            l = self.expr(node.left)
            for op, comp in zip(node.ops, node.comparators):
                r = self.expr(comp)
                if isinstance(op, (ast.Lt, ast.Gt, ast.LtE, ast.GtE,
                                   ast.Eq, ast.NotEq)):
                    self.add_combine(l, r, node, "comparison")
            return SCALAR
        if isinstance(node, ast.Call):
            return self.call(node)
        if isinstance(node, ast.IfExp):
            self.expr(node.test)
            a, b = self.expr(node.body), self.expr(node.orelse)
            return a if a is not None else b
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.expr(v)
            return None
        if isinstance(node, (ast.List, ast.Set)):
            d = None
            for e in node.elts:
                ed = self.expr(e)
                d = d if d is not None else ed
            return d
        if isinstance(node, ast.Tuple):
            for e in node.elts:
                self.expr(e)
            return None
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            return self.comprehension(node)
        if isinstance(node, ast.DictComp):
            self.bind_comprehension_targets(node.generators)
            self.expr(node.key)
            self.expr(node.value)
            return None
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if k is not None:
                    self.expr(k)
            for v in node.values:
                self.expr(v)
            return None
        if isinstance(node, ast.Subscript):
            d = self.expr(node.value)
            self.expr(node.slice) if not isinstance(node.slice, ast.Slice) \
                else None
            return d
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self.expr(v.value)
            return None
        if isinstance(node, ast.FormattedValue):
            self.expr(node.value)
            return None
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.Lambda):
            return None                     # opaque
        if isinstance(node, ast.Await):
            return self.expr(node.value)
        return None

    def binop(self, node: ast.BinOp) -> Optional[Dim]:
        l, r = self.expr(node.left), self.expr(node.right)
        if isinstance(node.op, (ast.Add, ast.Sub)):
            return self.add_combine(
                l, r, node,
                "addition" if isinstance(node.op, ast.Add) else "subtraction")
        if isinstance(node.op, ast.Mult):
            return dim_mul(l, r, 1)
        if isinstance(node.op, (ast.Div, ast.FloorDiv)):
            return dim_mul(l, r, -1)
        if isinstance(node.op, ast.Mod):
            return l
        return None

    def add_combine(self, a: Optional[Dim], b: Optional[Dim], node,
                    what: str) -> Optional[Dim]:
        if a is None:
            return None if b is None else replace(b, reliable=False)
        if b is None:
            return replace(a, reliable=False)
        if not a.nonscalar:
            return replace(b, reliable=False)
        if not b.nonscalar:
            return replace(a, reliable=False)
        if a.exp != b.exp:
            self.report(node, "unit-add", ERROR,
                        f"{what} mixes {a.name} and {b.name}")
        elif a.reliable and b.reliable and a.scale != b.scale:
            self.report(node, "unit-add", ERROR,
                        f"{what} mixes two {a.name} values with different "
                        f"unit scales (e.g. _s vs _ms)")
        return replace(a, reliable=False, derived=False)

    def call(self, node: ast.Call) -> Optional[Dim]:
        # keyword bindings are assignments in disguise
        for kw in node.keywords:
            v = self.expr(kw.value)
            if kw.arg is not None:
                self.assign_check(kw.value, kw.arg, classify_name(kw.arg), v,
                                  kw.value)
        func = node.func
        callee = dotted_name(func)
        argdims = [self.expr(a) for a in node.args]
        if isinstance(func, (ast.Attribute, ast.Subscript, ast.Call)):
            # visiting the receiver chain (dotted_name doesn't recurse dims)
            self.expr(func.value if not isinstance(func, ast.Call) else func)
        # min/max behave like addition across their arguments
        if callee in ("min", "max") and len(node.args) > 1:
            d: Optional[Dim] = None
            for a, ad in zip(node.args, argdims):
                if _is_literal(a):
                    continue
                d = self.add_combine(d, ad, node, f"{callee}()") \
                    if d is not None else ad
            return None if d is None else replace(d, reliable=False)
        if callee in ("min", "max", "sorted") and len(node.args) == 1:
            return argdims[0] if argdims else None
        if callee in _PASSTHROUGH and len(node.args) >= 1:
            return argdims[0]
        if callee and "." in callee:
            head, leaf = callee.split(".", 1)[0], callee.rsplit(".", 1)[-1]
            if head in _MODULE_RECEIVERS:
                if leaf in _NP_PASSTHROUGH and argdims:
                    return argdims[0]
                return None
            if leaf in _KNOWN_CALLS:
                return _KNOWN_CALLS[leaf]
            return classify_name(leaf).dim
        if callee:
            if callee in _KNOWN_CALLS:
                return _KNOWN_CALLS[callee]
            return classify_name(callee).dim
        return None

    def comprehension(self, node) -> Optional[Dim]:
        self.bind_comprehension_targets(node.generators)
        return self.expr(node.elt)

    def bind_comprehension_targets(self, generators) -> None:
        for gen in generators:
            it = self.expr(gen.iter)
            self.bind(gen.target, it, gen.iter, check=False)
            for cond in gen.ifs:
                self.expr(cond)


def _is_literal(node) -> bool:
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.UnaryOp) and isinstance(node.operand, ast.Constant):
        return True
    return False
