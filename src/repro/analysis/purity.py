"""Scheduler purity: ``choose``/``dispatch``/``dispatch_rid`` must not
write to ``self``.

The PR-2 contract: pricing a query (``choose``/``dispatch``, and since the
vectorized engine's table path, ``dispatch_rid``) is a pure function of
(query, fleet state) so policies can be replayed, A/B-compared and priced
speculatively; all state commits happen in ``observe()``/``observe_rid()``
after the caller accepts the decision. This checker walks every class named
(or inheriting from a base named) ``*Scheduler``, computes the set of
methods reachable from the entry points through ``self.<m>()`` calls —
stopping at the commit methods — and flags any mutation of ``self`` state
inside them: attribute/subscript assignment, ``del``, mutating container
methods (``append``/``update``/``heappush`` & co.), and ``heapq.*`` calls
whose first argument is rooted at ``self``. Plan-constructing helpers
(``_price_terms``, ``_as_plan``, ...) are ordinary ``self.<m>()`` calls, so
the trace follows dispatch through them automatically.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set

from repro.analysis.findings import ERROR, RawFinding
from repro.analysis.framework import ParsedModule, dotted_name, root_name

_ENTRY_METHODS = ("choose", "dispatch", "dispatch_rid")
_COMMIT_METHODS = {"observe", "observe_rid"}

_MUTATOR_METHODS = {"append", "extend", "insert", "remove", "pop", "popitem",
                    "clear", "update", "add", "discard", "setdefault", "sort",
                    "reverse", "appendleft", "popleft", "push"}
_HEAP_FUNCS = {"heappush", "heappop", "heapreplace", "heappushpop", "heapify"}


def _is_scheduler_class(cls: ast.ClassDef) -> bool:
    if cls.name.endswith("Scheduler"):
        return True
    for base in cls.bases:
        name = dotted_name(base)
        if name and name.split(".")[-1].endswith("Scheduler"):
            return True
    return False


class SchedulerPurityChecker:
    name = "scheduler-purity"
    rules = {
        "scheduler-purity": "self-mutation reachable from Scheduler."
                            "choose/dispatch/dispatch_rid (must go through "
                            "observe()/observe_rid())",
    }

    def check(self, module: ParsedModule) -> Iterable[RawFinding]:
        out: List[RawFinding] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef) and _is_scheduler_class(node):
                out.extend(self._check_class(node))
        return out

    def _check_class(self, cls: ast.ClassDef) -> Iterable[RawFinding]:
        methods: Dict[str, ast.FunctionDef] = {
            n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        reachable: Dict[str, str] = {}          # method -> entry it serves
        queue = [(m, m) for m in _ENTRY_METHODS if m in methods]
        while queue:
            name, entry = queue.pop()
            if name in reachable:
                continue
            reachable[name] = entry
            for sub in ast.walk(methods[name]):
                if isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and isinstance(sub.func.value, ast.Name) \
                        and sub.func.value.id == "self":
                    callee = sub.func.attr
                    if callee in methods and callee not in _COMMIT_METHODS \
                            and callee not in reachable:
                        queue.append((callee, entry))
        for name, entry in sorted(reachable.items()):
            yield from self._check_method(cls, methods[name], entry)

    def _check_method(self, cls, fn, entry: str) -> Iterable[RawFinding]:
        via = "" if fn.name == entry else f" (reachable from {entry}())"
        for node in ast.walk(fn):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not fn:
                continue
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                targets = [node.target]
            elif isinstance(node, ast.Delete):
                targets = node.targets
            for t in targets:
                attr = _self_target(t)
                if attr:
                    yield RawFinding(
                        node, "scheduler-purity", ERROR,
                        f"{cls.name}.{fn.name} writes self.{attr}{via}; "
                        f"schedulers may only mutate state in observe()")
            if isinstance(node, ast.Call):
                attr = self._mutating_call(node)
                if attr:
                    yield RawFinding(
                        node, "scheduler-purity", ERROR,
                        f"{cls.name}.{fn.name} mutates self.{attr}{via}; "
                        f"schedulers may only mutate state in observe()")

    def _mutating_call(self, node: ast.Call) -> Optional[str]:
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr in _MUTATOR_METHODS:
            if root_name(func.value) == "self":
                return _describe(func.value) + f".{func.attr}(...)"
        callee = dotted_name(func)
        if callee:
            leaf = callee.rsplit(".", 1)[-1]
            if leaf in _HEAP_FUNCS and node.args \
                    and root_name(node.args[0]) == "self":
                return _describe(node.args[0]) + f" via {leaf}()"
        return None


def _self_target(t: ast.AST) -> Optional[str]:
    if isinstance(t, (ast.Tuple, ast.List)):
        for e in t.elts:
            got = _self_target(e)
            if got:
                return got
        return None
    if isinstance(t, (ast.Attribute, ast.Subscript, ast.Starred)):
        if root_name(t) == "self":
            return _describe(t)
    return None


def _describe(node: ast.AST) -> str:
    parts: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            parts.append("[...]")
            node = node.value
        elif isinstance(node, ast.Call):
            node = node.func
        else:
            break
    parts.reverse()
    out = ""
    for p in parts:
        out += p if p == "[...]" else ("." + p if out else p)
    return out or "<attr>"
