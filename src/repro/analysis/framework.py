"""Shared AST framework: parsed modules, suppressions, checker registry.

Checkers implement a tiny protocol::

    class MyChecker:
        name = "my-checker"
        rules = {"my-rule": "what it means"}
        def check(self, module: ParsedModule) -> Iterable[RawFinding]: ...

``analyze_paths`` walks ``.py`` files, parses each once, runs every
registered checker and resolves suppressions. Inline suppressions use::

    x = a_j + b_w  # repro-lint: allow[unit-add]

The comment may sit on any physical line of the flagged statement or on the
line directly above it; ``allow[*]`` silences every rule.
"""
from __future__ import annotations

import ast
import os
import re
import tokenize
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import ERROR, Finding, RawFinding

_ALLOW_RE = re.compile(r"#\s*repro-lint:\s*allow\[([^\]]*)\]")


def parse_suppressions(source: str) -> Dict[int, frozenset]:
    """Map line number -> set of rule ids allowed there ('*' = all)."""
    out: Dict[int, frozenset] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        m = _ALLOW_RE.search(line)
        if m:
            rules = frozenset(r.strip() for r in m.group(1).split(",")
                              if r.strip())
            out[i] = rules
    return out


@dataclass
class ParsedModule:
    path: str
    source: str
    tree: ast.Module
    suppressions: Dict[int, frozenset]

    @classmethod
    def from_source(cls, source: str, path: str = "<string>") -> "ParsedModule":
        tree = ast.parse(source, filename=path)
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                child._repro_parent = parent  # type: ignore[attr-defined]
        return cls(path=path, source=source, tree=tree,
                   suppressions=parse_suppressions(source))

    def is_suppressed(self, node: ast.AST, rule: str) -> bool:
        if not self.suppressions:
            return False
        lo = getattr(node, "lineno", None)
        if lo is None:
            return False
        hi = getattr(node, "end_lineno", lo) or lo
        # widen to the enclosing statement so a trailing comment on any
        # physical line of a multi-line statement applies
        stmt = node
        while stmt is not None and not isinstance(stmt, ast.stmt):
            stmt = getattr(stmt, "_repro_parent", None)
        if stmt is not None:
            lo = min(lo, stmt.lineno)
            hi = max(hi, getattr(stmt, "end_lineno", stmt.lineno) or stmt.lineno)
        for line in range(lo - 1, hi + 1):   # lo-1: comment-above form
            rules = self.suppressions.get(line)
            if rules and ("*" in rules or rule in rules):
                return True
        return False


def iter_py_files(paths: Sequence[str]) -> List[str]:
    files: List[str] = []
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            files.append(p)
        elif os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for n in sorted(names):
                    if n.endswith(".py"):
                        files.append(os.path.join(root, n))
    return files


def default_checkers() -> List:
    from repro.analysis.jax_hotpath import JaxHotPathChecker
    from repro.analysis.purity import SchedulerPurityChecker
    from repro.analysis.units import UnitsChecker
    return [UnitsChecker(), JaxHotPathChecker(), SchedulerPurityChecker()]


def all_rules(checkers: Optional[Sequence] = None) -> Dict[str, str]:
    rules: Dict[str, str] = {"parse-error": "file failed to parse"}
    for c in (checkers if checkers is not None else default_checkers()):
        rules.update(c.rules)
    return rules


def analyze_module(module: ParsedModule,
                   checkers: Optional[Sequence] = None) -> List[Finding]:
    findings: List[Finding] = []
    for checker in (checkers if checkers is not None else default_checkers()):
        for raw in checker.check(module):
            if not module.is_suppressed(raw.node, raw.rule):
                findings.append(raw.at(module.path))
    return sorted(set(findings))


def analyze_source(source: str, path: str = "<string>",
                   checkers: Optional[Sequence] = None) -> List[Finding]:
    return analyze_module(ParsedModule.from_source(source, path), checkers)


def analyze_paths(paths: Sequence[str],
                  checkers: Optional[Sequence] = None) -> List[Finding]:
    if checkers is None:
        checkers = default_checkers()
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        try:
            with tokenize.open(path) as f:
                source = f.read()
            module = ParsedModule.from_source(source, path)
        except (SyntaxError, UnicodeDecodeError, OSError) as exc:
            findings.append(Finding(path=path, line=getattr(exc, "lineno", 1) or 1,
                                    col=0, rule="parse-error", severity=ERROR,
                                    message=str(exc)))
            continue
        findings.extend(analyze_module(module, checkers))
    return sorted(set(findings))


# --------------------------------------------------------------- AST helpers

def dotted_name(node: ast.AST) -> Optional[str]:
    """'np.asarray' for Attribute/Name chains, None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of an Attribute/Subscript/Call chain (e.g. 'self' for
    self.pool.free_at[0].append)."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def decorator_names(node) -> List[str]:
    names = []
    for dec in getattr(node, "decorator_list", []):
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = dotted_name(target)
        if name:
            names.append(name)
        if isinstance(dec, ast.Call):   # functools.partial(jax.jit, ...)
            for arg in dec.args:
                inner = dotted_name(arg)
                if inner:
                    names.append(inner)
    return names
