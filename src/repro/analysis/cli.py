"""repro-lint CLI.

    python -m repro.analysis [--fail-on warning] [--baseline FILE] [paths]

Exit status is 1 when any finding at or above ``--fail-on`` severity
survives baseline filtering, else 0. ``--write-baseline FILE`` records the
current findings as grandfathered debt instead of failing.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis import baseline as bl
from repro.analysis.findings import SEVERITY_RANK
from repro.analysis.framework import (all_rules, analyze_paths,
                                      default_checkers, iter_py_files)


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro-lint",
        description="dimensional-analysis / JAX hot-path / scheduler-purity "
                    "linter for the repro codebase")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to analyze (default: src)")
    p.add_argument("--fail-on", choices=sorted(SEVERITY_RANK),
                   default="error",
                   help="minimum severity that fails the run "
                        "(default: error)")
    p.add_argument("--baseline", metavar="FILE",
                   help="JSON baseline of grandfathered findings to ignore")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="write current findings to FILE and exit 0")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--list-rules", action="store_true",
                   help="print every rule id and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    checkers = default_checkers()
    if args.list_rules:
        for rule, desc in sorted(all_rules(checkers).items()):
            print(f"{rule}: {desc}")
        return 0

    findings = analyze_paths(args.paths, checkers)
    n_files = len(iter_py_files(args.paths))

    if args.write_baseline:
        bl.save_baseline(args.write_baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    stale: list = []
    grandfathered = 0
    if args.baseline:
        res = bl.filter_findings(findings, bl.load_baseline(args.baseline))
        findings, grandfathered, stale = res.new, len(res.matched), res.stale

    if args.format == "json":
        print(json.dumps([vars(f) for f in findings], indent=2))
    else:
        for f in findings:
            print(f.render())
        for path, rule, message in stale:
            print(f"note: stale baseline entry {path} [{rule}] {message!r} "
                  f"— regenerate with --write-baseline", file=sys.stderr)
        n_err = sum(1 for f in findings if f.severity == "error")
        n_warn = len(findings) - n_err
        print(f"repro-lint: {len(findings)} finding(s) "
              f"({n_err} error(s), {n_warn} warning(s)), "
              f"{grandfathered} grandfathered, {n_files} file(s) checked",
              file=sys.stderr)

    threshold = SEVERITY_RANK[args.fail_on]
    failing = [f for f in findings if SEVERITY_RANK[f.severity] >= threshold]
    return 1 if failing else 0
