"""Attention module: GQA/MQA/MHA with RoPE / M-RoPE, causal or bidirectional,
sliding window, KV-cache prefill/decode, and cross-attention (enc-dec)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops, ref
from repro.models import layers as L

MROPE_SECTIONS_FRAC = (0.25, 0.375, 0.375)  # qwen2-vl [16, 24, 24] of 64 half-dims


def attn_init(key, cfg: ModelConfig, dtype, *, cross: bool = False):
    hd = cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    bias = cfg.qkv_bias
    return {
        "q": L.dense_init(kq, cfg.d_model, cfg.num_heads * hd, dtype, bias=bias),
        "k": L.dense_init(kk, cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=bias),
        "v": L.dense_init(kv, cfg.d_model, cfg.num_kv_heads * hd, dtype, bias=bias),
        "o": L.dense_init(ko, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def _split_heads(x, n_heads, hd):
    B, S, _ = x.shape
    return x.reshape(B, S, n_heads, hd).transpose(0, 2, 1, 3)  # (B, H, S, D)


def _merge_heads(x):
    B, H, S, D = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B, S, H * D)


def quantize_kv(x, axis: int = -1):
    """Symmetric per-row int8 quantization. x (..., D) -> (int8, scale (...,1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _mrope_sections(head_dim: int):
    half = head_dim // 2
    s0 = int(half * MROPE_SECTIONS_FRAC[0])
    s1 = int(half * MROPE_SECTIONS_FRAC[1])
    return (s0, s1, half - s0 - s1)


def _position_encode(cfg: ModelConfig, q, k, positions):
    if cfg.pos_emb == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.pos_emb == "mrope":
        sec = _mrope_sections(cfg.resolved_head_dim)
        q = L.apply_mrope(q, positions, cfg.rope_theta, sec)
        k = L.apply_mrope(k, positions, cfg.rope_theta, sec)
    # "learned"/"none": handled at the embedding level
    return q, k


def self_attention(params, cfg: ModelConfig, x, *, positions, causal: bool = True,
                   window: Optional[int] = None, backend: str = "auto"):
    """Full-sequence self attention (train / encoder). positions: (B,S) or (B,S,3)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)
    k = _split_heads(L.linear(params["k"], x), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], x), cfg.num_kv_heads, hd)
    q, k = _position_encode(cfg, q, k, positions)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap, backend=backend)
    return L.linear(params["o"], _merge_heads(out))


def prefill_attention(params, cfg: ModelConfig, x, *, positions, k_cache, v_cache,
                      window: Optional[int] = None, backend: str = "auto",
                      k_scale=None, v_scale=None):
    """Self attention that also writes K/V into the (zero-initialized) cache.

    x: (B, S, d); k_cache/v_cache: (B, Hkv, Smax, D) with Smax >= S.
    int8 caches (k_scale/v_scale not None) are written quantized per row.
    Returns (out, k_cache, v_cache[, k_scale, v_scale]).
    """
    hd = cfg.resolved_head_dim
    S = x.shape[1]
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)
    k = _split_heads(L.linear(params["k"], x), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], x), cfg.num_kv_heads, hd)
    q, k = _position_encode(cfg, q, k, positions)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=cfg.attn_logit_softcap, backend=backend)
    o = L.linear(params["o"], _merge_heads(out))
    if k_scale is not None:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.lax.dynamic_update_slice(k_cache, kq, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vq, (0, 0, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, 0, 0, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, 0, 0, 0))
        return o, k_cache, v_cache, k_scale, v_scale
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, 0, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, 0, 0, 0))
    return o, k_cache, v_cache


def decode_self_attention(params, cfg: ModelConfig, x, *, positions, k_cache,
                          v_cache, kv_len, window: Optional[int] = None,
                          backend: str = "auto", k_scale=None, v_scale=None):
    """One-token decode. x: (B, 1, d); kv_len (B,): length INCLUDING this token.

    The new K/V row is written at kv_len-1, then flash-decode runs over the
    cache. int8 caches (k_scale/v_scale not None) quantize the new row and
    dequantize on read. Returns (out, k_cache, v_cache[, k_scale, v_scale]).
    """
    hd = cfg.resolved_head_dim
    B = x.shape[0]
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)       # (B,H,1,D)
    k = _split_heads(L.linear(params["k"], x), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], x), cfg.num_kv_heads, hd)
    q, k = _position_encode(cfg, q, k, positions)

    # scatter the new row at position kv_len-1 (per batch element):
    # per-batch dynamic_update_slice — fuses to an in-place write under
    # donation instead of materializing masked copies of the whole cache
    idx = (kv_len - 1).astype(jnp.int32)                                # (B,)

    def _write(cache_b, new_b, i):
        return jax.lax.dynamic_update_slice(cache_b, new_b.astype(cache_b.dtype),
                                            (jnp.int32(0), i, jnp.int32(0)))

    quant = k_scale is not None
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        k_cache = jax.vmap(_write)(k_cache, kq, idx)
        v_cache = jax.vmap(_write)(v_cache, vq, idx)
        k_scale = jax.vmap(_write)(k_scale, ks, idx)
        v_scale = jax.vmap(_write)(v_scale, vs, idx)
        k_read = dequantize_kv(k_cache, k_scale, q.dtype)
        v_read = dequantize_kv(v_cache, v_scale, q.dtype)
    else:
        k_cache = jax.vmap(_write)(k_cache, k, idx)
        v_cache = jax.vmap(_write)(v_cache, v, idx)
        k_read, v_read = k_cache, v_cache

    out = ops.decode_attention(q, k_read, v_read, kv_len, window=window,
                               softcap=cfg.attn_logit_softcap, backend=backend)
    o = L.linear(params["o"], _merge_heads(out))
    if quant:
        return o, k_cache, v_cache, k_scale, v_scale
    return o, k_cache, v_cache


def paged_prefill_chunk_attention(params, cfg: ModelConfig, x, *, positions,
                                  k_pool, v_pool, table, block_ids, rows,
                                  kv_len, q_offset,
                                  window: Optional[int] = None,
                                  backend: str = "auto",
                                  k_scale_pool=None, v_scale_pool=None):
    """Chunked-prefill self attention for ONE lane of a paged cache.

    x: (1, C, d) — the lane's next C prompt tokens (rows past the valid count
    carry garbage; their writes are pre-redirected to the null block via
    ``block_ids``). The chunk's K/V rows are scattered into the shared pools
    at (block_ids, rows), then the chunk queries attend over the lane's
    gathered blocks with causal masking at absolute offset ``q_offset`` and
    validity masking at ``kv_len`` (shape (1,), = q_offset + n_valid).

    Returns (out, k_pool, v_pool[, k_scale_pool, v_scale_pool]).
    """
    hd = cfg.resolved_head_dim
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)     # (1,Hq,C,D)
    k = _split_heads(L.linear(params["k"], x), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], x), cfg.num_kv_heads, hd)
    q, k = _position_encode(cfg, q, k, positions)
    krows = k[0].transpose(1, 0, 2)                                   # (C, Hkv, D)
    vrows = v[0].transpose(1, 0, 2)
    quant = k_scale_pool is not None
    if quant:
        kq, ks = quantize_kv(krows)
        vq, vs = quantize_kv(vrows)
        k_pool = k_pool.at[block_ids, :, rows].set(kq)
        v_pool = v_pool.at[block_ids, :, rows].set(vq)
        k_scale_pool = k_scale_pool.at[block_ids, :, rows].set(ks)
        v_scale_pool = v_scale_pool.at[block_ids, :, rows].set(vs)
        k_read = dequantize_kv(ref.gather_paged_kv(k_pool, table[None]),
                               ref.gather_paged_kv(k_scale_pool, table[None]),
                               q.dtype)
        v_read = dequantize_kv(ref.gather_paged_kv(v_pool, table[None]),
                               ref.gather_paged_kv(v_scale_pool, table[None]),
                               q.dtype)
    else:
        k_pool = k_pool.at[block_ids, :, rows].set(krows.astype(k_pool.dtype))
        v_pool = v_pool.at[block_ids, :, rows].set(vrows.astype(v_pool.dtype))
        k_read = ref.gather_paged_kv(k_pool, table[None])
        v_read = ref.gather_paged_kv(v_pool, table[None])
    # chunk attention runs on the masked reference path: it needs BOTH a
    # traced q_offset and kv_len masking, which the flash prefill kernel does
    # not expose; chunks are short, so the O(C * ctx) dense scores are cheap
    out = ref.mha_attention(q, k_read, v_read, causal=True, window=window,
                            softcap=cfg.attn_logit_softcap,
                            q_offset=q_offset, kv_len=kv_len)
    o = L.linear(params["o"], _merge_heads(out))
    if quant:
        return o, k_pool, v_pool, k_scale_pool, v_scale_pool
    return o, k_pool, v_pool


def paged_decode_self_attention(params, cfg: ModelConfig, x, *, positions,
                                k_pool, v_pool, block_tables, block_ids, rows,
                                kv_len, window: Optional[int] = None,
                                backend: str = "auto",
                                k_scale_pool=None, v_scale_pool=None):
    """One-token decode over a paged cache, batched across lanes.

    x: (B, 1, d); pools: (num_blocks, Hkv, block_size, D); block_tables
    (B, max_blocks); block_ids/rows (B,) precomputed write targets (non-live
    lanes redirected to the null block by the caller); kv_len (B,) length
    INCLUDING this token. Returns (out, pools...) like the dense variant.
    """
    hd = cfg.resolved_head_dim
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)     # (B,Hq,1,D)
    k = _split_heads(L.linear(params["k"], x), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], x), cfg.num_kv_heads, hd)
    q, k = _position_encode(cfg, q, k, positions)
    krow = k[:, :, 0, :]                                              # (B, Hkv, D)
    vrow = v[:, :, 0, :]
    quant = k_scale_pool is not None
    if quant:
        kq, ks = quantize_kv(krow)
        vq, vs = quantize_kv(vrow)
        k_pool = k_pool.at[block_ids, :, rows].set(kq)
        v_pool = v_pool.at[block_ids, :, rows].set(vq)
        k_scale_pool = k_scale_pool.at[block_ids, :, rows].set(ks)
        v_scale_pool = v_scale_pool.at[block_ids, :, rows].set(vs)
        # int8 pools: the quantized read path picks gather-dequantize vs the
        # fused in-kernel int8 read (autotuned; default = historical gather)
        out = ops.paged_decode_attention_quant(
            q, k_pool, v_pool, k_scale_pool, v_scale_pool, block_tables,
            kv_len, window=window, softcap=cfg.attn_logit_softcap,
            backend=backend)
    else:
        k_pool = k_pool.at[block_ids, :, rows].set(krow.astype(k_pool.dtype))
        v_pool = v_pool.at[block_ids, :, rows].set(vrow.astype(v_pool.dtype))
        out = ops.paged_decode_attention(q, k_pool, v_pool, block_tables,
                                         kv_len, window=window,
                                         softcap=cfg.attn_logit_softcap,
                                         backend=backend)
    o = L.linear(params["o"], _merge_heads(out))
    if quant:
        return o, k_pool, v_pool, k_scale_pool, v_scale_pool
    return o, k_pool, v_pool


def cross_attention(params, cfg: ModelConfig, x, *, enc_k, enc_v, backend: str = "auto"):
    """Decoder cross-attention over precomputed encoder K/V (B, Hkv, S_enc, D)."""
    hd = cfg.resolved_head_dim
    q = _split_heads(L.linear(params["q"], x), cfg.num_heads, hd)
    if x.shape[1] == 1:
        # decode: a (1, S_enc) score row — plain jnp is the right tool
        out = ref.mha_attention(q, enc_k, enc_v, causal=False)
    else:
        out = ops.flash_attention(q, enc_k, enc_v, causal=False, backend=backend)
    return L.linear(params["o"], _merge_heads(out))


def encode_kv(params, cfg: ModelConfig, enc_out):
    """Precompute cross-attention K/V from encoder output (once per request)."""
    hd = cfg.resolved_head_dim
    k = _split_heads(L.linear(params["k"], enc_out), cfg.num_kv_heads, hd)
    v = _split_heads(L.linear(params["v"], enc_out), cfg.num_kv_heads, hd)
    return k, v
