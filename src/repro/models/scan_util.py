"""Layer-stack scan with a global unroll switch.

Default: lax.scan (one compiled body — fast compiles at 30-64 layers).
REPRO_SCAN_UNROLL=1: fully unrolled — used by the dry-run's component
compiles because XLA's HloCostAnalysis counts a while-loop body ONCE
regardless of trip count (verified empirically), so FLOP accounting needs
unrolled HLO. The dry-run unrolls tiny (L=1, L=2) variants and extrapolates.
"""
from __future__ import annotations

import os

import jax


def unroll_enabled() -> bool:
    return os.environ.get("REPRO_SCAN_UNROLL", "0") == "1"


def layer_scan(body, carry, xs, length=None):
    """jax.lax.scan honoring the global unroll flag (checked at trace time)."""
    if unroll_enabled():
        return jax.lax.scan(body, carry, xs, length=length, unroll=True)
    return jax.lax.scan(body, carry, xs, length=length)
