"""Mamba2 block (SSD form) — arXiv:2405.21060.

Projection layout (single fused in_proj, as in the reference implementation):
    [z (d_inner) | x (d_inner) | B (N) | C (N) | dt (n_heads)]
Causal depthwise conv runs over the concatenated (x, B, C) channels.
The sequence mix is the chunked SSD scan (Pallas kernel / jnp oracle).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


def mamba_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d, di, N = cfg.d_model, cfg.d_inner, s.state_dim
    H = cfg.ssm_heads
    conv_ch = di + 2 * N
    k1, k1b, k1c, k2, k3, k4 = jax.random.split(key, 6)
    return {
        # split projections (vs the reference's fused in_proj) so the output
        # dims shard cleanly on the tensor-parallel axis: 2*di and 2*N are
        # 16-divisible for every assigned config, H often is not.
        "zx_proj": L.dense_init(k1, d, 2 * di, dtype),
        "bc_proj": L.dense_init(k1b, d, 2 * N, dtype),
        "dt_proj": L.dense_init(k1c, d, H, dtype),
        "conv_w": (jax.random.normal(k2, (s.conv_width, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (jax.random.uniform(k3, (H,), jnp.float32,
                                       minval=-4.0, maxval=-1.0)),
        "gate_norm": L.rmsnorm_init(di, dtype),
        "out_proj": L.dense_init(k4, di, d, dtype),
    }


def _causal_conv(x, w, b):
    """x (B, S, C), w (W, C) depthwise causal conv, b (C,)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],      # (W, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _project(params, cfg: ModelConfig, x):
    s = cfg.ssm
    di, N = cfg.d_inner, s.state_dim
    zx = L.linear(params["zx_proj"], x)                        # (B, S, 2di)
    bc = L.linear(params["bc_proj"], x)                        # (B, S, 2N)
    dt = L.linear(params["dt_proj"], x)                        # (B, S, H)
    z, xb = jnp.split(zx, [di], axis=-1)
    Bm, Cm = jnp.split(bc, [N], axis=-1)
    return z, xb, Bm, Cm, dt


def mamba_apply(params, cfg: ModelConfig, x, *, backend: str = "auto"):
    """Full-sequence (train / prefill without cache). x (B,S,d) -> y (B,S,d)."""
    y, _, _ = mamba_apply_with_state(params, cfg, x, backend=backend)
    return y


def mamba_apply_with_state(params, cfg: ModelConfig, x, *, backend: str = "auto"):
    """Returns (y, conv_state (B, W-1, conv_ch), ssm_state (B, H, P, N))."""
    s = cfg.ssm
    B, S, d = x.shape
    di, N, H, P = cfg.d_inner, s.state_dim, cfg.ssm_heads, s.head_dim
    z, xb, Bm, Cm, dt = _project(params, cfg, x)
    conv_in = jnp.concatenate([xb, Bm, Cm], axis=-1)           # (B, S, conv_ch)
    conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"], params["conv_b"]))
    xb, Bm, Cm = jnp.split(conv_out, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (B, S, H)
    A = -jnp.exp(params["A_log"])                              # (H,) negative
    xh = xb.reshape(B, S, H, P).transpose(0, 2, 1, 3)          # (B, H, S, P)
    dth = dt.transpose(0, 2, 1)                                # (B, H, S)
    yh, final_state = ops.ssd_scan(xh, dth, A, Bm, Cm, chunk=s.chunk_size,
                                   backend=backend)
    yh = (yh + params["D"][None, :, None, None] * xh).astype(x.dtype)  # skip
    y = yh.transpose(0, 2, 1, 3).reshape(B, S, di)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z))     # gated norm
    y = L.linear(params["out_proj"], y)
    conv_state = conv_in[:, -(s.conv_width - 1):, :] if S >= s.conv_width - 1 else \
        jnp.pad(conv_in, ((0, 0), (s.conv_width - 1 - S, 0), (0, 0)))
    return y, conv_state, final_state


def mamba_decode_step(params, cfg: ModelConfig, x, conv_state, ssm_state):
    """One-token decode. x (B, 1, d); conv_state (B, W-1, conv_ch);
    ssm_state (B, H, P, N). Returns (y (B,1,d), conv_state, ssm_state)."""
    s = cfg.ssm
    B = x.shape[0]
    di, N, H, P = cfg.d_inner, s.state_dim, cfg.ssm_heads, s.head_dim
    z, xb, Bm, Cm, dt = _project(params, cfg, x)
    conv_in = jnp.concatenate([xb, Bm, Cm], axis=-1)[:, 0, :]  # (B, conv_ch)
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B, W, ch)
    w = params["conv_w"].astype(jnp.float32)                   # (W, ch)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w) \
        + params["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)           # (B, ch)
    xb1, Bm1, Cm1 = jnp.split(conv_out, [di, di + N], axis=-1)
    dt1 = jax.nn.softplus(dt[:, 0, :].astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    xh = xb1.reshape(B, H, P)
    yh, new_state = ops.ssd_decode_step(ssm_state, xh, dt1, A, Bm1, Cm1)
    yh = (yh + params["D"][None, :, None] * xh).astype(x.dtype)
    y = yh.reshape(B, 1, di)
    y = L.rmsnorm(params["gate_norm"], y * jax.nn.silu(z))
    y = L.linear(params["out_proj"], y)
    return y, window[:, 1:, :], new_state
