"""Shared neural-net layers (raw JAX pytrees — no flax).

Conventions:
  * params are nested dicts of jnp arrays;
  * every ``*_init`` returns the param subtree, every ``*_apply`` is pure;
  * compute-sensitive reductions run in f32 and cast back to the io dtype.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------- init
def dense_init(key, in_dim: int, out_dim: int, dtype, *, scale: Optional[float] = None,
               bias: bool = False):
    if scale is None:
        scale = in_dim ** -0.5
    w = (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)
    if bias:
        return {"w": w, "b": jnp.zeros((out_dim,), dtype)}
    return {"w": w}


def embed_init(key, vocab: int, dim: int, dtype):
    return {"emb": (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)}


def linear(params, x):
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


# --------------------------------------------------------------------------- norms
def rmsnorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_init(kind: str, dim: int, dtype):
    return rmsnorm_init(dim, dtype) if kind == "rmsnorm" else layernorm_init(dim, dtype)


def norm_apply(kind: str, params, x):
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# --------------------------------------------------------------------------- rope
def _rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, head_dim//2), f32."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    return positions.astype(jnp.float32)[..., None] * inv_freq


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, H, S, D), positions (B, S). Split-half (llama) convention."""
    B, H, S, D = x.shape
    ang = _rope_angles(positions, D, theta)            # (B, S, D/2)
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Sequence[int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL, arXiv:2409.12191).

    x (B, H, S, D); positions3 (B, S, 3) = (temporal, height, width) ids.
    The D/2 rotary frequencies are partitioned into 3 contiguous sections,
    each rotated by its own position id stream.
    """
    B, H, S, D = x.shape
    half = D // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # pick, per frequency index, which of the 3 position streams drives it
    sec_id = jnp.repeat(jnp.arange(3), jnp.asarray(sections), total_repeat_length=half)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),                 # (B, S, 3)
        jnp.broadcast_to(sec_id[None, None, :], (B, S, half)).astype(jnp.int32),
        axis=-1)                                        # (B, S, half)
    ang = pos * inv_freq                                # (B, S, half)
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> jnp.ndarray:
    """Classic transformer sinusoids (whisper encoder)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    idx = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10000.0 ** (2 * idx / dim))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# --------------------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, activation: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if activation in ("swiglu", "geglu"):
        return {"gate": dense_init(k1, d_model, d_ff, dtype),
                "up": dense_init(k2, d_model, d_ff, dtype),
                "down": dense_init(k3, d_ff, d_model, dtype)}
    return {"up": dense_init(k1, d_model, d_ff, dtype, bias=True),
            "down": dense_init(k2, d_ff, d_model, dtype, bias=True)}


def mlp_apply(params, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(linear(params["gate"], x)) * linear(params["up"], x)
    elif activation == "geglu":
        h = jax.nn.gelu(linear(params["gate"], x)) * linear(params["up"], x)
    else:  # gelu
        h = jax.nn.gelu(linear(params["up"], x))
    return linear(params["down"], h)
