"""Model assembly for every assigned architecture family.

Public API (all pure functions; ``cfg`` is static):

    init_params(cfg, key, dtype, max_positions=None)      -> params pytree
    forward_train(params, cfg, batch, ...)                -> (logits, aux_loss)
    init_cache(cfg, batch_size, max_len, dtype, ...)      -> cache pytree
    prefill(params, cfg, batch, cache, ...)               -> (last_logits, cache)
    decode_step(params, cfg, tokens, cache, ...)          -> (logits, cache)

Layer stacks are stored *stacked* (leading layer dim) and executed with
``jax.lax.scan`` — one compiled layer body regardless of depth (MaxText-style),
with optional ``jax.checkpoint`` remat for training.

``batch`` dict:
    tokens: (B, S) int32                 — all families
    frames: (B, S_enc, d_model) f        — audio (STUB frontend embeddings)
    vision: (B, n_vis, d_model) f        — vlm   (STUB patch embeddings)
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as ATT
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.scan_util import layer_scan

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================
def _stacked_init(fn, key, n: int):
    return jax.vmap(fn)(jax.random.split(key, n))


def _dense_layer_init(cfg: ModelConfig, dtype):
    def init(key):
        ka, km = jax.random.split(key)
        p = {"attn_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
             "attn": ATT.attn_init(ka, cfg, dtype),
             "mlp_norm": L.norm_init(cfg.norm, cfg.d_model, dtype)}
        if cfg.family == "moe":
            p["moe"] = MOE.moe_init(km, cfg, dtype)
        else:
            p["mlp"] = L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype)
        return p
    return init


def _encdec_layer_init(cfg: ModelConfig, dtype, *, cross: bool):
    def init(key):
        ka, kc, km = jax.random.split(key, 3)
        p = {"attn_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
             "attn": ATT.attn_init(ka, cfg, dtype),
             "mlp_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
             "mlp": L.mlp_init(km, cfg.d_model, cfg.d_ff, cfg.activation, dtype)}
        if cross:
            p["cross_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            p["cross"] = ATT.attn_init(kc, cfg, dtype)
        return p
    return init


def _mamba_layer_init(cfg: ModelConfig, dtype):
    def init(key):
        return {"norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
                "mamba": SSM.mamba_init(key, cfg, dtype)}
    return init


def init_params(cfg: ModelConfig, key, dtype=jnp.float32,
                max_positions: Optional[int] = None) -> Params:
    """max_positions: size of learned position tables (audio decoder)."""
    ke, kl, ku, kx = jax.random.split(key, 4)
    params: Params = {"embed": L.embed_init(ke, cfg.vocab_size, cfg.d_model, dtype),
                      "final_norm": L.norm_init(cfg.norm, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(ku, cfg.d_model, cfg.vocab_size, dtype)

    if cfg.family in ("dense", "moe", "vlm"):
        params["layers"] = _stacked_init(_dense_layer_init(cfg, dtype), kl, cfg.num_layers)
    elif cfg.family == "ssm":
        params["layers"] = _stacked_init(_mamba_layer_init(cfg, dtype), kl, cfg.num_layers)
    elif cfg.family == "hybrid":
        params["layers"] = _stacked_init(_mamba_layer_init(cfg, dtype), kl, cfg.num_layers)
        params["shared_attn"] = _encdec_layer_init(cfg, dtype, cross=False)(kx)
    elif cfg.family == "audio":
        mp = max_positions or cfg.max_seq_len
        k1, k2, k3 = jax.random.split(kl, 3)
        params["enc_layers"] = _stacked_init(
            _encdec_layer_init(cfg, dtype, cross=False), k1, cfg.encoder_layers)
        params["enc_final_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        params["dec_layers"] = _stacked_init(
            _encdec_layer_init(cfg, dtype, cross=True), k2, cfg.num_layers)
        params["dec_pos"] = {"emb": (jax.random.normal(k3, (mp, cfg.d_model), jnp.float32)
                                     * 0.01).astype(dtype)}
    else:
        raise ValueError(cfg.family)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ===========================================================================
# position helpers
# ===========================================================================
def mrope_positions(cfg: ModelConfig, B: int, seq_len: int, n_vis: int,
                    start: int = 0) -> jnp.ndarray:
    """(B, seq_len, 3) position ids: vision tokens get a (t=0, h, w) grid,
    text tokens get equal (t,h,w) = grid_side + text_index (qwen2-vl style)."""
    g = max(1, int(round(n_vis ** 0.5)))
    idx = jnp.arange(seq_len) + start
    is_vis = idx < n_vis
    t = jnp.where(is_vis, 0, idx - n_vis + g)
    h = jnp.where(is_vis, idx // g, idx - n_vis + g)
    w = jnp.where(is_vis, idx % g, idx - n_vis + g)
    pos = jnp.stack([t, h, w], axis=-1)                  # (S, 3)
    return jnp.broadcast_to(pos[None], (B, seq_len, 3)).astype(jnp.int32)


def _positions(cfg: ModelConfig, B: int, S: int, n_vis: int = 0):
    if cfg.pos_emb == "mrope":
        return mrope_positions(cfg, B, S, n_vis)
    return jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))


# ===========================================================================
# logits
# ===========================================================================
def _logits(params, cfg: ModelConfig, h):
    h = L.norm_apply(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        return h @ params["embed"]["emb"].T
    return L.linear(params["unembed"], h)


# ===========================================================================
# forward (train / full sequence)
# ===========================================================================
def _dense_block(lp, cfg: ModelConfig, h, positions, *, backend, window):
    a = L.norm_apply(cfg.norm, lp["attn_norm"], h)
    h = h + ATT.self_attention(lp["attn"], cfg, a, positions=positions,
                               causal=True, window=window, backend=backend)
    m = L.norm_apply(cfg.norm, lp["mlp_norm"], h)
    if cfg.family == "moe":
        y, aux = MOE.moe_apply(lp["moe"], cfg, m)
        return h + y, aux
    return h + L.mlp_apply(lp["mlp"], m, cfg.activation), jnp.float32(0.0)


def _hybrid_segments(cfg: ModelConfig):
    """[(start, end, attn_after?)] covering all layers."""
    every = cfg.hybrid_attn_every
    segs = []
    s = 0
    while s < cfg.num_layers:
        e = min(s + every, cfg.num_layers) if every else cfg.num_layers
        segs.append((s, e, every > 0 and e - s == every))
        s = e
    return segs


def _slice_layers(stacked, a: int, b: int):
    return jax.tree.map(lambda x: x[a:b], stacked)


def _shared_attn_block(params, cfg: ModelConfig, h, positions, *, backend):
    lp = params["shared_attn"]
    a = L.norm_apply(cfg.norm, lp["attn_norm"], h)
    h = h + ATT.self_attention(lp["attn"], cfg, a, positions=positions, causal=True,
                               window=cfg.sliding_window, backend=backend)
    m = L.norm_apply(cfg.norm, lp["mlp_norm"], h)
    return h + L.mlp_apply(lp["mlp"], m, cfg.activation)


def forward_train(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                  *, backend: str = "auto", remat: bool = False):
    """Full-sequence forward. Returns (logits (B, S_total, V), aux_loss)."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    h = params["embed"]["emb"][tokens]

    if cfg.family == "vlm":
        h = jnp.concatenate([batch["vision"].astype(h.dtype), h], axis=1)
    S = h.shape[1]
    n_vis = S - S_text if cfg.family == "vlm" else 0
    positions = _positions(cfg, B, S, n_vis)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(carry, lp):
            out, aux = _dense_block(lp, cfg, carry, positions, backend=backend,
                                    window=cfg.sliding_window)
            return out, aux
        if remat:
            body = jax.checkpoint(body)
        h, auxs = layer_scan(body, h, params["layers"])
        return _logits(params, cfg, h), jnp.sum(auxs)

    if cfg.family == "ssm":
        def body(carry, lp):
            x = L.norm_apply(cfg.norm, lp["norm"], carry)
            return carry + SSM.mamba_apply(lp["mamba"], cfg, x, backend=backend), 0.0
        if remat:
            body = jax.checkpoint(body)
        h, _ = layer_scan(body, h, params["layers"])
        return _logits(params, cfg, h), jnp.float32(0.0)

    if cfg.family == "hybrid":
        def body(carry, lp):
            x = L.norm_apply(cfg.norm, lp["norm"], carry)
            return carry + SSM.mamba_apply(lp["mamba"], cfg, x, backend=backend), 0.0
        if remat:
            body = jax.checkpoint(body)
        for (a, b, attn_after) in _hybrid_segments(cfg):
            h, _ = layer_scan(body, h, _slice_layers(params["layers"], a, b))
            if attn_after:
                h = _shared_attn_block(params, cfg, h, positions, backend=backend)
        return _logits(params, cfg, h), jnp.float32(0.0)

    if cfg.family == "audio":
        enc_out = encode(params, cfg, batch["frames"], backend=backend)
        return _decode_train(params, cfg, tokens, enc_out, backend=backend, remat=remat)

    raise ValueError(cfg.family)


# --------------------------------------------------------------------- audio
def encode(params: Params, cfg: ModelConfig, frames, *, backend: str = "auto"):
    """Bidirectional encoder over stub frame embeddings (B, S_enc, d)."""
    B, S_enc, _ = frames.shape
    h = frames + L.sinusoidal_positions(S_enc, cfg.d_model).astype(frames.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(S_enc, dtype=jnp.int32)[None], (B, S_enc))

    def body(carry, lp):
        a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
        h2 = carry + ATT.self_attention(lp["attn"], cfg, a, positions=positions,
                                        causal=False, backend=backend)
        m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
        return h2 + L.mlp_apply(lp["mlp"], m, cfg.activation), 0.0

    h, _ = layer_scan(body, h, params["enc_layers"])
    return L.norm_apply(cfg.norm, params["enc_final_norm"], h)


def _decode_train(params, cfg: ModelConfig, tokens, enc_out, *, backend, remat):
    B, S = tokens.shape
    h = params["embed"]["emb"][tokens] + params["dec_pos"]["emb"][None, :S]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(carry, lp):
        a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
        h2 = carry + ATT.self_attention(lp["attn"], cfg, a, positions=positions,
                                        causal=True, backend=backend)
        c = L.norm_apply(cfg.norm, lp["cross_norm"], h2)
        ek, ev = ATT.encode_kv(lp["cross"], cfg, enc_out)
        h2 = h2 + ATT.cross_attention(lp["cross"], cfg, c, enc_k=ek, enc_v=ev,
                                      backend=backend)
        m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
        return h2 + L.mlp_apply(lp["mlp"], m, cfg.activation), 0.0
    if remat:
        body = jax.checkpoint(body)
    h, _ = layer_scan(body, h, params["dec_layers"])
    return _logits(params, cfg, h), jnp.float32(0.0)


# ===========================================================================
# KV / state cache
# ===========================================================================
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype=jnp.float32,
               enc_len: Optional[int] = None,
               kv_quant: bool = False) -> Dict[str, jnp.ndarray]:
    """kv_quant: store K/V int8 with per-row f32 scales (dense/moe/vlm
    families) — halves (bf16) or quarters (f32) the cache residency at a
    ~1e-2 relative attention error (tested)."""
    B, hd = batch_size, cfg.resolved_head_dim
    cache: Dict[str, jnp.ndarray] = {"pos": jnp.zeros((B,), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        kv_dtype = jnp.int8 if kv_quant else dtype
        cache["k"] = jnp.zeros((cfg.num_layers, B, cfg.num_kv_heads, max_len, hd),
                               kv_dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        if kv_quant:
            cache["k_scale"] = jnp.zeros(
                (cfg.num_layers, B, cfg.num_kv_heads, max_len, 1), jnp.float32)
            cache["v_scale"] = jnp.zeros_like(cache["k_scale"])
    elif cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        ch = cfg.d_inner + 2 * s.state_dim
        cache["conv"] = jnp.zeros((cfg.num_layers, B, s.conv_width - 1, ch), dtype)
        cache["ssm"] = jnp.zeros((cfg.num_layers, B, cfg.ssm_heads, s.head_dim,
                                  s.state_dim), jnp.float32)
        if cfg.family == "hybrid":
            n_attn = sum(1 for *_, a in _hybrid_segments(cfg) if a)
            cache["ak"] = jnp.zeros((n_attn, B, cfg.num_kv_heads, max_len, hd), dtype)
            cache["av"] = jnp.zeros_like(cache["ak"])
    elif cfg.family == "audio":
        el = enc_len or cfg.encoder_seq_len
        cache["k"] = jnp.zeros((cfg.num_layers, B, cfg.num_kv_heads, max_len, hd), dtype)
        cache["v"] = jnp.zeros_like(cache["k"])
        cache["ck"] = jnp.zeros((cfg.num_layers, B, cfg.num_kv_heads, el, hd), dtype)
        cache["cv"] = jnp.zeros_like(cache["ck"])
    return cache


# ===========================================================================
# paged KV cache
# ===========================================================================
# Families whose serving cache is attention K/V and therefore pageable. SSM
# and hybrid lanes carry fixed-size recurrent state (paging buys nothing);
# audio/vlm prompts carry non-token modalities the chunked path cannot split.
PAGED_FAMILIES = ("dense", "moe")

# Pool block 0 is the NULL BLOCK: never allocated, all dead block-table
# entries point at it, and writes from padded chunk rows / idle decode lanes
# are redirected into it. Readers mask by kv_len, so its contents are
# unreachable garbage by construction.
NULL_BLOCK = 0


def init_paged_cache(cfg: ModelConfig, lanes: int, num_blocks: int,
                     block_size: int, dtype=jnp.float32, *,
                     max_blocks_per_lane: Optional[int] = None,
                     kv_quant: bool = False) -> Dict[str, jnp.ndarray]:
    """Paged KV cache: one shared block pool per instance + per-lane tables.

    Layout (vLLM-style, TPU-friendly static shapes):
      kp/vp         (layers, num_blocks, Hkv, block_size, hd)  shared pool
      block_tables  (lanes, max_blocks_per_lane) int32         logical->physical
      pos           (lanes,) int32                             valid context

    Block allocation/refcounting is host-side policy (``serving.batching``);
    this pytree only carries the device state. ``kv_quant`` stores int8
    blocks with per-row f32 scale pools, as in the dense cache.
    """
    if cfg.family not in PAGED_FAMILIES:
        raise ValueError(f"paged KV cache supports families {PAGED_FAMILIES}, "
                         f"not {cfg.family!r}")
    if num_blocks < 2:
        raise ValueError("need >= 2 blocks (block 0 is the reserved null block)")
    hd = cfg.resolved_head_dim
    mb = max_blocks_per_lane if max_blocks_per_lane is not None else num_blocks
    kv_dtype = jnp.int8 if kv_quant else dtype
    cache: Dict[str, jnp.ndarray] = {
        "pos": jnp.zeros((lanes,), jnp.int32),
        "block_tables": jnp.full((lanes, mb), NULL_BLOCK, jnp.int32),
        "kp": jnp.zeros((cfg.num_layers, num_blocks, cfg.num_kv_heads,
                         block_size, hd), kv_dtype),
    }
    cache["vp"] = jnp.zeros_like(cache["kp"])
    if kv_quant:
        cache["kp_scale"] = jnp.zeros((cfg.num_layers, num_blocks,
                                       cfg.num_kv_heads, block_size, 1),
                                      jnp.float32)
        cache["vp_scale"] = jnp.zeros_like(cache["kp_scale"])
    return cache


def prefill_paged_chunk(params: Params, cfg: ModelConfig, tokens, cache, *,
                        lane, n_valid, backend: str = "auto"):
    """Prefill ONE chunk of one lane's prompt into its allocated blocks.

    tokens: (1, C) — the next C prompt tokens of ``lane`` starting at the
    lane's current ``pos`` (rows past ``n_valid`` are padding). Writes the
    chunk's K/V into the lane's blocks, advances ``pos`` by ``n_valid``, and
    returns (logits of the LAST VALID token (1, V), cache) — the logits only
    matter on the final chunk, where they seed decode exactly like a dense
    ``prefill``.
    """
    C = tokens.shape[1]
    start = cache["pos"][lane]
    n_valid = jnp.asarray(n_valid, jnp.int32)
    h = params["embed"]["emb"][tokens]
    offs = jnp.arange(C, dtype=jnp.int32)
    positions = (start + offs)[None]                           # (1, C)
    table = cache["block_tables"][lane]                        # (mb,)
    bs = cache["kp"].shape[3]
    valid = offs < n_valid
    block_ids = jnp.where(valid, table[(start + offs) // bs], NULL_BLOCK)
    rows = (start + offs) % bs
    kv_len = (start + n_valid)[None]                           # (1,)
    quant = "kp_scale" in cache
    window = cfg.sliding_window

    def body(carry, xs):
        if quant:
            lp, kp, vp, ks, vs = xs
        else:
            lp, kp, vp = xs
            ks = vs = None
        a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
        res = ATT.paged_prefill_chunk_attention(
            lp["attn"], cfg, a, positions=positions, k_pool=kp, v_pool=vp,
            table=table, block_ids=block_ids, rows=rows, kv_len=kv_len,
            q_offset=start, window=window, backend=backend,
            k_scale_pool=ks, v_scale_pool=vs)
        h2 = carry + res[0]
        m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
        if cfg.family == "moe":
            # dropless routing: capacity-based dispatch sizes expert capacity
            # by the token count it sees, so per-chunk routing would drop
            # different tokens than the dense whole-prompt prefill. Dropless
            # makes chunked prefill chunk-size-invariant; it coincides with
            # the dense path exactly when its capacity never binds (e.g. the
            # dropless-capacity ``reduced()`` configs — pinned by the parity
            # tests and the CI smoke gate).
            y, _ = MOE.moe_apply(lp["moe"], cfg, m, dropless=True)
        else:
            y = L.mlp_apply(lp["mlp"], m, cfg.activation)
        return h2 + y, res[1:]

    xs = (params["layers"], cache["kp"], cache["vp"])
    if quant:
        xs = xs + (cache["kp_scale"], cache["vp_scale"])
    h, pools = layer_scan(body, h, xs)
    cache = dict(cache, kp=pools[0], vp=pools[1],
                 pos=cache["pos"].at[lane].set(start + n_valid))
    if quant:
        cache.update(kp_scale=pools[2], vp_scale=pools[3])
    last = jax.lax.dynamic_index_in_dim(h[0], jnp.maximum(n_valid - 1, 0), 0,
                                        keepdims=False)
    return _logits(params, cfg, last[None]), cache


def decode_step_paged(params: Params, cfg: ModelConfig, tokens, cache, *,
                      live=None, backend: str = "auto"):
    """One batched decode step over every lane of a paged cache.

    tokens (lanes, 1) int32; ``live`` (lanes,) bool — lanes that are empty or
    still prefilling run the math for shape stability, but their K/V writes
    are redirected to the null block and their ``pos`` does not advance (a
    freed lane's blocks may already belong to another request, so a stray
    write would corrupt it). Returns (logits (lanes, V), cache).
    """
    B = tokens.shape[0]
    pos = cache["pos"]
    if live is None:
        live = jnp.ones((B,), bool)
    kv_len = pos + 1
    h = params["embed"]["emb"][tokens]
    positions = pos[:, None].astype(jnp.int32)
    tables = cache["block_tables"]
    bs = cache["kp"].shape[3]
    block_ids = jnp.where(live, tables[jnp.arange(B), pos // bs], NULL_BLOCK)
    rows = pos % bs
    quant = "kp_scale" in cache
    window = cfg.sliding_window

    def body(carry, xs):
        if quant:
            lp, kp, vp, ks, vs = xs
        else:
            lp, kp, vp = xs
            ks = vs = None
        a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
        res = ATT.paged_decode_self_attention(
            lp["attn"], cfg, a, positions=positions, k_pool=kp, v_pool=vp,
            block_tables=tables, block_ids=block_ids, rows=rows, kv_len=kv_len,
            window=window, backend=backend, k_scale_pool=ks, v_scale_pool=vs)
        h2 = carry + res[0]
        m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
        if cfg.family == "moe":
            y, _ = MOE.moe_apply(lp["moe"], cfg, m, dropless=True)
        else:
            y = L.mlp_apply(lp["mlp"], m, cfg.activation)
        return h2 + y, res[1:]

    xs = (params["layers"], cache["kp"], cache["vp"])
    if quant:
        xs = xs + (cache["kp_scale"], cache["vp_scale"])
    h, pools = layer_scan(body, h, xs)
    cache = dict(cache, kp=pools[0], vp=pools[1],
                 pos=jnp.where(live, pos + 1, pos))
    if quant:
        cache.update(kp_scale=pools[2], vp_scale=pools[3])
    return _logits(params, cfg, h[:, -1]), cache


# ===========================================================================
# prefill
# ===========================================================================
def prefill(params: Params, cfg: ModelConfig, batch, cache, *,
            backend: str = "auto"):
    """Process the whole prompt, fill caches. Returns (last_logits (B,V), cache)."""
    tokens = batch["tokens"]
    B, S_text = tokens.shape
    h = params["embed"]["emb"][tokens]
    window = cfg.sliding_window

    if cfg.family == "vlm":
        h = jnp.concatenate([batch["vision"].astype(h.dtype), h], axis=1)
    S = h.shape[1]
    n_vis = S - S_text if cfg.family == "vlm" else 0
    positions = _positions(cfg, B, S, n_vis)

    if cfg.family in ("dense", "moe", "vlm"):
        quant = "k_scale" in cache

        def body(carry, xs):
            if quant:
                lp, kc, vc, ks, vs = xs
            else:
                lp, kc, vc = xs
                ks = vs = None
            a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
            res = ATT.prefill_attention(lp["attn"], cfg, a, positions=positions,
                                        k_cache=kc, v_cache=vc, window=window,
                                        backend=backend, k_scale=ks, v_scale=vs)
            attn, kc, vc = res[0], res[1], res[2]
            h2 = carry + attn
            m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
            if cfg.family == "moe":
                y, _ = MOE.moe_apply(lp["moe"], cfg, m)
            else:
                y = L.mlp_apply(lp["mlp"], m, cfg.activation)
            ys = (kc, vc, res[3], res[4]) if quant else (kc, vc)
            return h2 + y, ys

        if quant:
            h, (k_new, v_new, ks_new, vs_new) = layer_scan(
                body, h, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
            cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new, v_scale=vs_new,
                         pos=jnp.full((B,), S, jnp.int32))
        else:
            h, (k_new, v_new) = layer_scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
            cache = dict(cache, k=k_new, v=v_new, pos=jnp.full((B,), S, jnp.int32))
        return _logits(params, cfg, h[:, -1]), cache

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, xs):
            lp, _conv, _ssm = xs
            x = L.norm_apply(cfg.norm, lp["norm"], carry)
            y, conv_st, ssm_st = SSM.mamba_apply_with_state(lp["mamba"], cfg, x,
                                                            backend=backend)
            return carry + y, (conv_st, ssm_st)

        if cfg.family == "ssm":
            h, (conv_new, ssm_new) = layer_scan(
                body, h, (params["layers"], cache["conv"], cache["ssm"]))
            cache = dict(cache, conv=conv_new.astype(cache["conv"].dtype),
                         ssm=ssm_new, pos=jnp.full((B,), S, jnp.int32))
            return _logits(params, cfg, h[:, -1]), cache

        # hybrid: segments of mamba layers + shared attn blocks with their own KV
        conv_parts, ssm_parts = [], []
        ak, av = cache["ak"], cache["av"]
        attn_i = 0
        for (a, b, attn_after) in _hybrid_segments(cfg):
            h, (conv_st, ssm_st) = layer_scan(
                body, h, (_slice_layers(params["layers"], a, b),
                          cache["conv"][a:b], cache["ssm"][a:b]))
            conv_parts.append(conv_st)
            ssm_parts.append(ssm_st)
            if attn_after:
                lp = params["shared_attn"]
                x = L.norm_apply(cfg.norm, lp["attn_norm"], h)
                attn, kc, vc = ATT.prefill_attention(
                    lp["attn"], cfg, x, positions=positions, k_cache=ak[attn_i],
                    v_cache=av[attn_i], window=cfg.sliding_window, backend=backend)
                h = h + attn
                m = L.norm_apply(cfg.norm, lp["mlp_norm"], h)
                h = h + L.mlp_apply(lp["mlp"], m, cfg.activation)
                ak = ak.at[attn_i].set(kc)
                av = av.at[attn_i].set(vc)
                attn_i += 1
        cache = dict(cache,
                     conv=jnp.concatenate(conv_parts).astype(cache["conv"].dtype),
                     ssm=jnp.concatenate(ssm_parts), ak=ak, av=av,
                     pos=jnp.full((B,), S, jnp.int32))
        return _logits(params, cfg, h[:, -1]), cache

    if cfg.family == "audio":
        # encode once; precompute cross K/V; then prefill the decoder prompt
        enc_out = encode(params, cfg, batch["frames"], backend=backend)

        def cross_kv(lp):
            return ATT.encode_kv(lp["cross"], cfg, enc_out)
        _, (ck, cv) = layer_scan(lambda c, lp: (c, cross_kv(lp)), 0, params["dec_layers"])

        h = params["embed"]["emb"][tokens] + params["dec_pos"]["emb"][None, :S_text]
        dpos = jnp.broadcast_to(jnp.arange(S_text, dtype=jnp.int32)[None], (B, S_text))

        def body(carry, xs):
            lp, kc, vc, ckl, cvl = xs
            a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
            attn, kc, vc = ATT.prefill_attention(lp["attn"], cfg, a, positions=dpos,
                                                 k_cache=kc, v_cache=vc, backend=backend)
            h2 = carry + attn
            c = L.norm_apply(cfg.norm, lp["cross_norm"], h2)
            h2 = h2 + ATT.cross_attention(lp["cross"], cfg, c, enc_k=ckl, enc_v=cvl,
                                          backend=backend)
            m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
            return h2 + L.mlp_apply(lp["mlp"], m, cfg.activation), (kc, vc)

        h, (k_new, v_new) = layer_scan(
            body, h, (params["dec_layers"], cache["k"], cache["v"],
                      ck.astype(cache["ck"].dtype), cv.astype(cache["cv"].dtype)))
        cache = dict(cache, k=k_new, v=v_new, ck=ck.astype(cache["ck"].dtype),
                     cv=cv.astype(cache["cv"].dtype),
                     pos=jnp.full((B,), S_text, jnp.int32))
        return _logits(params, cfg, h[:, -1]), cache

    raise ValueError(cfg.family)


# ===========================================================================
# decode
# ===========================================================================
def decode_step(params: Params, cfg: ModelConfig, tokens, cache, *,
                backend: str = "auto"):
    """One decode step. tokens (B, 1) int32. Returns (logits (B, V), cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]                                   # length BEFORE this token
    kv_len = pos + 1
    h = params["embed"]["emb"][tokens]
    window = cfg.sliding_window

    if cfg.pos_emb == "mrope":
        n_vis = cfg.num_vision_tokens
        g = max(1, int(round(n_vis ** 0.5)))
        p = (pos - n_vis + g).astype(jnp.int32)          # text-stream position
        positions = jnp.stack([p, p, p], axis=-1)[:, None, :]   # (B, 1, 3)
    else:
        positions = pos[:, None].astype(jnp.int32)       # (B, 1)

    if cfg.family in ("dense", "moe", "vlm"):
        quant = "k_scale" in cache

        def block(lp, hin, kc, vc, ks=None, vs=None):
            a = L.norm_apply(cfg.norm, lp["attn_norm"], hin)
            res = ATT.decode_self_attention(
                lp["attn"], cfg, a, positions=positions, k_cache=kc, v_cache=vc,
                kv_len=kv_len, window=window, backend=backend,
                k_scale=ks, v_scale=vs)
            attn, kc, vc = res[0], res[1], res[2]
            h2 = hin + attn
            m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
            if cfg.family == "moe":
                y, _ = MOE.moe_apply(lp["moe"], cfg, m, dropless=True)
            else:
                y = L.mlp_apply(lp["mlp"], m, cfg.activation)
            if quant:
                return h2 + y, kc, vc, res[3], res[4]
            return h2 + y, kc, vc

        # Perf-iteration lever (REPRO_CACHE_MODE): with the cache as scan
        # xs/ys ("scan", baseline) XLA materializes a fresh (L,B,H,S,D) output
        # cache each step — a full copy of untouched rows. "carry" threads the
        # stacked cache through the scan carry and updates layer i in place
        # with dynamic_update_slice (XLA aliases carries in while loops), so
        # per-step cache traffic is the attention READ plus one row write.
        if os.environ.get("REPRO_CACHE_MODE", "scan") == "carry" and not quant:
            def body(carry, xs):
                hin, ck, cv = carry
                lp, i = xs
                kc = jax.lax.dynamic_index_in_dim(ck, i, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(cv, i, 0, keepdims=False)
                hout, kc, vc = block(lp, hin, kc, vc)
                ck = jax.lax.dynamic_update_index_in_dim(ck, kc, i, 0)
                cv = jax.lax.dynamic_update_index_in_dim(cv, vc, i, 0)
                return (hout, ck, cv), None
            (h, k_new, v_new), _ = layer_scan(
                body, (h, cache["k"], cache["v"]),
                (params["layers"], jnp.arange(cfg.num_layers)))
        elif quant:
            def body(carry, xs):
                lp, kc, vc, ks, vs = xs
                hout, kc, vc, ks, vs = block(lp, carry, kc, vc, ks, vs)
                return hout, (kc, vc, ks, vs)
            h, (k_new, v_new, ks_new, vs_new) = layer_scan(
                body, h, (params["layers"], cache["k"], cache["v"],
                          cache["k_scale"], cache["v_scale"]))
            cache = dict(cache, k=k_new, v=v_new, k_scale=ks_new,
                         v_scale=vs_new, pos=pos + 1)
            return _logits(params, cfg, h[:, -1]), cache
        else:
            def body(carry, xs):
                lp, kc, vc = xs
                hout, kc, vc = block(lp, carry, kc, vc)
                return hout, (kc, vc)
            h, (k_new, v_new) = layer_scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
        cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
        return _logits(params, cfg, h[:, -1]), cache

    if cfg.family in ("ssm", "hybrid"):
        def body(carry, xs):
            lp, conv_st, ssm_st = xs
            x = L.norm_apply(cfg.norm, lp["norm"], carry)
            y, conv_st, ssm_st = SSM.mamba_decode_step(lp["mamba"], cfg, x,
                                                       conv_st, ssm_st)
            return carry + y, (conv_st, ssm_st)

        if cfg.family == "ssm":
            h, (conv_new, ssm_new) = layer_scan(
                body, h, (params["layers"], cache["conv"], cache["ssm"]))
            cache = dict(cache, conv=conv_new.astype(cache["conv"].dtype),
                         ssm=ssm_new, pos=pos + 1)
            return _logits(params, cfg, h[:, -1]), cache

        conv_parts, ssm_parts = [], []
        ak, av = cache["ak"], cache["av"]
        attn_i = 0
        for (a, b, attn_after) in _hybrid_segments(cfg):
            h, (conv_st, ssm_st) = layer_scan(
                body, h, (_slice_layers(params["layers"], a, b),
                          cache["conv"][a:b], cache["ssm"][a:b]))
            conv_parts.append(conv_st)
            ssm_parts.append(ssm_st)
            if attn_after:
                lp = params["shared_attn"]
                x = L.norm_apply(cfg.norm, lp["attn_norm"], h)
                attn, kc, vc = ATT.decode_self_attention(
                    lp["attn"], cfg, x, positions=positions, k_cache=ak[attn_i],
                    v_cache=av[attn_i], kv_len=kv_len, window=cfg.sliding_window,
                    backend=backend)
                h = h + attn
                m = L.norm_apply(cfg.norm, lp["mlp_norm"], h)
                h = h + L.mlp_apply(lp["mlp"], m, cfg.activation)
                ak = ak.at[attn_i].set(kc)
                av = av.at[attn_i].set(vc)
                attn_i += 1
        cache = dict(cache,
                     conv=jnp.concatenate(conv_parts).astype(cache["conv"].dtype),
                     ssm=jnp.concatenate(ssm_parts), ak=ak, av=av, pos=pos + 1)
        return _logits(params, cfg, h[:, -1]), cache

    if cfg.family == "audio":
        h = h + params["dec_pos"]["emb"][pos][:, None, :]

        def body(carry, xs):
            lp, kc, vc, ckl, cvl = xs
            a = L.norm_apply(cfg.norm, lp["attn_norm"], carry)
            attn, kc, vc = ATT.decode_self_attention(
                lp["attn"], cfg, a, positions=positions, k_cache=kc, v_cache=vc,
                kv_len=kv_len, backend=backend)
            h2 = carry + attn
            c = L.norm_apply(cfg.norm, lp["cross_norm"], h2)
            h2 = h2 + ATT.cross_attention(lp["cross"], cfg, c, enc_k=ckl, enc_v=cvl,
                                          backend=backend)
            m = L.norm_apply(cfg.norm, lp["mlp_norm"], h2)
            return h2 + L.mlp_apply(lp["mlp"], m, cfg.activation), (kc, vc)

        h, (k_new, v_new) = layer_scan(
            body, h, (params["dec_layers"], cache["k"], cache["v"],
                      cache["ck"], cache["cv"]))
        cache = dict(cache, k=k_new, v=v_new, pos=pos + 1)
        return _logits(params, cfg, h[:, -1]), cache

    raise ValueError(cfg.family)
