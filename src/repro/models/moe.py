"""Mixture-of-Experts FFN with top-k routing and fixed-capacity dispatch.

TPU-friendly design: no dynamic shapes. Tokens are sorted by expert id
(argsort), ranked within their expert group, and scattered into an
(E, capacity) buffer; expert FFNs run as one batched einsum over the expert
dimension (expert-parallel shardable on the "model" mesh axis); results are
combined back weighted by router probabilities. Tokens overflowing an
expert's capacity are dropped (standard Switch/GShard semantics) — with
capacity_factor 1.25 and top-2 this is rare at the batch sizes we serve.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L


def moe_init(key, cfg: ModelConfig, dtype):
    E, d, ff = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale = d ** -0.5
    glu = cfg.activation in ("swiglu", "geglu")
    p = {
        "router": {"w": (jax.random.normal(kr, (d, E), jnp.float32) * scale).astype(dtype)},
        "up": (jax.random.normal(ku, (E, d, ff), jnp.float32) * scale).astype(dtype),
        "down": (jax.random.normal(kd, (E, ff, d), jnp.float32) * (ff ** -0.5)).astype(dtype),
    }
    if glu:
        p["gate"] = (jax.random.normal(kg, (E, d, ff), jnp.float32) * scale).astype(dtype)
    return p


def moe_apply(params, cfg: ModelConfig, x, *, dropless: bool = False):
    """x: (B, S, d) -> (y, aux_loss). Fixed-capacity top-k dispatch.

    dropless=True sets capacity = T (each expert can absorb every token):
    zero drops guaranteed. Used for decode, where the extra slots are dead
    FLOPs hidden under the memory roof (decode streams all expert weights
    from HBM anyway) — see DESIGN.md.
    """
    mcfg = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mcfg.num_experts, mcfg.num_experts_per_tok

    # Perf-iteration lever (REPRO_MOE_DISPATCH):
    #   global  — one argsort/gather/scatter over ALL tokens (baseline).
    #             Under SPMD the data-sharded token tensor must be all-gathered
    #             for the global sort: O(T*d) collective per layer.
    #   grouped — Switch/GShard-style per-group dispatch: tokens are split into
    #             groups aligned with the data shards, each group routes into a
    #             per-group capacity slice. The only cross-shard traffic is the
    #             dispatched (E, C, d) buffer (all-to-all-shaped), which is
    #             k/E-fraction of the baseline's all-gather.
    if (os.environ.get("REPRO_MOE_DISPATCH", "global") == "grouped"
            and not dropless and T >= 4096):
        return _moe_apply_grouped(params, cfg, x)
    if dropless:
        C = T
    else:
        C = max(1, min(T, int(mcfg.capacity_factor * T * k / E)))

    xt = x.reshape(T, d)
    logits = (xt @ params["router"]["w"]).astype(jnp.float32)       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- load-balance auxiliary loss (Switch-style) --------------------------
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * mcfg.router_aux_loss_coef

    # ---- fixed-capacity dispatch ---------------------------------------------
    flat_expert = expert_idx.reshape(-1)                            # (T*k,)
    flat_gate = gate_vals.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(T), k)                       # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)                   # group by expert
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]
    # rank within expert group
    same = jnp.cumsum(jax.nn.one_hot(sorted_expert, E, dtype=jnp.int32), axis=0)
    rank = jnp.take_along_axis(same, sorted_expert[:, None], axis=1)[:, 0] - 1
    keep = rank < C
    slot = sorted_expert * C + jnp.where(keep, rank, 0)             # (T*k,)

    # gather tokens into (E*C, d)
    buf = jnp.zeros((E * C, d), x.dtype)
    src = xt[sorted_token] * keep[:, None].astype(x.dtype)
    buf = buf.at[slot].add(src)                                     # each slot written once
    buf = buf.reshape(E, C, d)

    # ---- expert FFN (batched over E; shardable on model axis) ---------------
    if "gate" in params:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["gate"])) \
            * jnp.einsum("ecd,edf->ecf", buf, params["up"])
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", buf, params["up"]))
    out = jnp.einsum("ecf,efd->ecd", h, params["down"]).reshape(E * C, d)

    # ---- combine back ---------------------------------------------------------
    gathered = out[slot] * (sorted_gate * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[sorted_token].add(gathered)
    return y.reshape(B, S, d), aux


NUM_DISPATCH_GROUPS = 16     # aligned with the "data" mesh axis


def _moe_apply_grouped(params, cfg: ModelConfig, x):
    """Group-local dispatch: vmap the sort/capacity machinery over G groups so
    routing index math never crosses data shards; the expert einsum contracts
    the grouped buffer (G, E, Cg, d) against model-sharded expert weights."""
    mcfg = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mcfg.num_experts, mcfg.num_experts_per_tok
    G = min(NUM_DISPATCH_GROUPS, T)
    while T % G:
        G //= 2
    Tg = T // G
    Cg = max(1, min(Tg, int(mcfg.capacity_factor * Tg * k / E)))

    xt = x.reshape(G, Tg, d)
    logits = (xt @ params["router"]["w"]).astype(jnp.float32)       # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                 # (G, Tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    me = jnp.mean(probs.reshape(T, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[..., 0].reshape(T), E,
                                 dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) * mcfg.router_aux_loss_coef

    def dispatch_one(xg, eidx, gval):
        flat_e = eidx.reshape(-1)                                   # (Tg*k,)
        flat_g = gval.reshape(-1)
        flat_t = jnp.repeat(jnp.arange(Tg), k)
        order = jnp.argsort(flat_e, stable=True)
        se, stok, sg = flat_e[order], flat_t[order], flat_g[order]
        same = jnp.cumsum(jax.nn.one_hot(se, E, dtype=jnp.int32), axis=0)
        rank = jnp.take_along_axis(same, se[:, None], axis=1)[:, 0] - 1
        keep = rank < Cg
        slot = se * Cg + jnp.where(keep, rank, 0)
        buf = jnp.zeros((E * Cg, xg.shape[-1]), xg.dtype)
        buf = buf.at[slot].add(xg[stok] * keep[:, None].astype(xg.dtype))
        return buf.reshape(E, Cg, xg.shape[-1]), (slot, stok, sg, keep)

    buf, meta = jax.vmap(dispatch_one)(xt, expert_idx, gate_vals)   # (G,E,Cg,d)

    if "gate" in params:
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, params["gate"])) \
            * jnp.einsum("gecd,edf->gecf", buf, params["up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buf, params["up"]))
    out = jnp.einsum("gecf,efd->gecd", h, params["down"])           # (G,E,Cg,d)

    def combine_one(og, m):
        slot, stok, sg, keep = m
        gathered = og.reshape(E * Cg, d)[slot] * (sg * keep).astype(og.dtype)[:, None]
        return jnp.zeros((Tg, d), og.dtype).at[stok].add(gathered)

    y = jax.vmap(combine_one)(out, meta)                            # (G, Tg, d)
    return y.reshape(B, S, d), aux
