"""Reproducible-timing environment configuration + fingerprinting.

Kernel timings are only comparable when the numerical environment that
produced them is pinned: x64 mode changes every dtype default, the platform
pin changes which backend compiles, and XLA flags change the generated code.
This module does two things:

  * **configure** the environment for a timing run (x64 toggle, platform
    pin, host device count) — thin wrappers over ``jax.config`` in the style
    of the exemplar env-config helpers (SNIPPETS.md 1-3), callable only
    before JAX backends initialize where noted;
  * **fingerprint** the environment (library versions, backend, device kind,
    x64 state, and the XLA/repro env vars that alter codegen) so timing
    artifacts can refuse to be reused under a different environment. The
    kernel autotuner (``repro.kernels.autotune``) stores this fingerprint in
    its cache and rejects stale caches on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys
from typing import Dict, Optional

import jax

# Env vars that change generated code or measured time; captured verbatim
# (unset vars are omitted so an empty and an absent var fingerprint alike).
CAPTURED_ENV_VARS = (
    "XLA_FLAGS",
    "JAX_ENABLE_X64",
    "JAX_PLATFORMS",
    "JAX_DEFAULT_DTYPE_BITS",
    "LD_PRELOAD",
    "REPRO_KERNEL_BACKEND",
    "REPRO_CACHE_MODE",
    "TF_CPP_MIN_LOG_LEVEL",
)


# ------------------------------------------------------------- configuration
def enable_x64(use_x64: bool = True) -> None:
    """Toggle 64-bit default precision (changes every timed kernel's dtype)."""
    jax.config.update("jax_enable_x64", bool(use_x64))


def set_platform(name: str) -> None:
    """Pin the JAX platform ("cpu" | "gpu" | "tpu"). Only effective before
    the first backend initialization of the process."""
    jax.config.update("jax_platform_name", name)


def set_host_device_count(n: int) -> None:
    """Force n XLA host devices (prepended to XLA_FLAGS). Must run before
    JAX initializes its backends; later calls are silently ineffective for
    the current process but still land in the fingerprint."""
    n = int(n)
    if n < 1:
        raise ValueError(f"need >= 1 host devices, got {n}")
    flags = os.environ.get("XLA_FLAGS", "")
    flags = " ".join(f for f in flags.split()
                     if not f.startswith("--xla_force_host_platform_device_count"))
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n} {flags}".strip())


def configure_timing_env(*, x64: bool = False, platform_name: Optional[str] = None,
                         host_devices: Optional[int] = None) -> Dict[str, str]:
    """Apply a reproducible-timing configuration and return its fingerprint.

    The returned fingerprint reflects the environment AFTER configuration,
    so it is what a timing artifact produced under this call should record.
    """
    if host_devices is not None:
        set_host_device_count(host_devices)
    if platform_name is not None:
        set_platform(platform_name)
    enable_x64(x64)
    return env_fingerprint()


# -------------------------------------------------------------- fingerprint
def env_fingerprint() -> Dict[str, str]:
    """Stable description of everything that can change a kernel timing.

    Keys are sorted strings so the fingerprint JSON-serializes canonically;
    ``fingerprint_digest`` hashes exactly this dict.
    """
    try:
        import jaxlib
        jaxlib_version = getattr(jaxlib, "__version__", "unknown")
    except ImportError:                      # pragma: no cover - jaxlib ships with jax
        jaxlib_version = "missing"
    import numpy as np

    devices = jax.devices()
    fp = {
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": str(len(devices)),
        "jax": jax.__version__,
        "jaxlib": jaxlib_version,
        "machine": platform.machine(),
        "numpy": np.__version__,
        "python": ".".join(map(str, sys.version_info[:3])),
        "system": platform.system(),
        "x64": str(bool(jax.config.jax_enable_x64)),
    }
    for var in CAPTURED_ENV_VARS:
        val = os.environ.get(var)
        if val:
            fp[f"env:{var}"] = val
    return dict(sorted(fp.items()))


def fingerprint_digest(fp: Optional[Dict[str, str]] = None) -> str:
    """Short stable hash of a fingerprint (current environment's if None)."""
    if fp is None:
        fp = env_fingerprint()
    blob = json.dumps(fp, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode()).hexdigest()[:16]
