"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state. The dry-run entry point (dryrun.py) sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 BEFORE importing jax.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, moe_experts: int = 0):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    moe_experts > 0 factorizes the 16-way model axis into
    (expert = num_experts, tp = 16 // num_experts) so expert weights shard on
    their own axis (expert parallelism) and d_ff shards on the remainder —
    the §Perf fix for MoE whose expert count doesn't divide 16 (grok: 8x2).
    """
    if moe_experts:
        e = min(moe_experts, 16)
        while 16 % e:
            e //= 2
        tp = 16 // e
        if multi_pod:
            return jax.make_mesh((2, 16, e, tp), ("pod", "data", "expert", "tp"))
        return jax.make_mesh((16, e, tp), ("data", "expert", "tp"))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Small mesh for CPU tests (requires len(jax.devices()) >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))
