"""ShapeDtypeStruct stand-ins for every (arch x input-shape) combination.

No device allocation: params/optimizer/cache structures come from
jax.eval_shape over the real init functions, so the dry-run lowers the exact
production computation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.training import optimizer as OPT

SDS = jax.ShapeDtypeStruct


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Shape-driven config adjustments (documented in DESIGN.md):
    long_500k on attention archs runs the sliding-window variant."""
    if shape.name == "long_500k" and cfg.family in ("dense", "moe", "vlm"):
        cfg = dataclasses.replace(cfg, sliding_window=8192)
    return cfg


def batch_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> Dict[str, SDS]:
    B = shape.global_batch
    if shape.kind == "decode":
        toks = SDS((B, 1), jnp.int32)
        return {"tokens": toks}
    S = shape.seq_len
    out: Dict[str, SDS] = {}
    if cfg.family == "vlm":
        S_text = S - cfg.num_vision_tokens
        out["vision"] = SDS((B, cfg.num_vision_tokens, cfg.d_model), dtype)
        out["tokens"] = SDS((B, S_text), jnp.int32)
    elif cfg.family == "audio":
        out["frames"] = SDS((B, cfg.encoder_seq_len, cfg.d_model), dtype)
        out["tokens"] = SDS((B, S), jnp.int32)
    else:
        out["tokens"] = SDS((B, S), jnp.int32)
    return out


def params_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    max_pos = max(cfg.max_seq_len, shape.seq_len + 1) if cfg.family == "audio" else None
    fn = functools.partial(M.init_params, cfg, dtype=dtype, max_positions=max_pos)
    return jax.eval_shape(fn, jax.random.PRNGKey(0))


def opt_specs(params_tree):
    return jax.eval_shape(OPT.init_state, params_tree)


def cache_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16):
    import os
    kv_quant = (os.environ.get("REPRO_KV_QUANT") == "1"
                and cfg.family in ("dense", "moe", "vlm"))
    fn = functools.partial(M.init_cache, cfg, shape.global_batch, shape.seq_len,
                           dtype, enc_len=cfg.encoder_seq_len or None,
                           kv_quant=kv_quant)
    return jax.eval_shape(fn)


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16
                ) -> Tuple[Any, ...]:
    """Everything the step function for this shape takes, as abstract values.

    train:   (params, opt_state, batch)
    prefill: (params, batch, cache)
    decode:  (params, tokens, cache)
    """
    cfg = adapt_config(cfg, shape)
    params = params_specs(cfg, shape, dtype)
    if shape.kind == "train":
        return params, opt_specs(params), batch_specs(cfg, shape, dtype)
    cache = cache_specs(cfg, shape, dtype)
    if shape.kind == "prefill":
        return params, batch_specs(cfg, shape, dtype), cache
    return params, batch_specs(cfg, shape, dtype)["tokens"], cache
