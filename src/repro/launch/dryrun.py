import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax ---------------------------------------
import argparse
import dataclasses
import functools
import json
import re
import time
from contextlib import contextmanager
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (INPUT_SHAPES, get_config, get_shape, list_archs,
                           supports_shape)
from repro.configs.base import InputShape, ModelConfig
from repro.launch import input_specs as ISPEC
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.sharding import specs as SH
from repro.training import optimizer as OPT
from repro.training import train as TR

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")
_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-shape bytes of every collective op in (per-device) HLO."""
    out = {op: 0.0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    for m in _COLL_RE.finditer(hlo_text):
        shapes, op = m.group(1), m.group(2)
        if op.endswith("-done"):
            continue
        nbytes = 0.0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    out["counts"] = counts  # type: ignore[assignment]
    return out


@contextmanager
def unrolled():
    old = os.environ.get("REPRO_SCAN_UNROLL")
    os.environ["REPRO_SCAN_UNROLL"] = "1"
    try:
        yield
    finally:
        if old is None:
            os.environ.pop("REPRO_SCAN_UNROLL", None)
        else:
            os.environ["REPRO_SCAN_UNROLL"] = old


# --------------------------------------------------------------------- steps
def make_step(cfg: ModelConfig, shape: InputShape):
    if shape.kind == "train":
        opt = OPT.AdamWConfig()
        ts = TR.make_train_step(cfg, opt, backend="ref", remat=True)
        return ts
    if shape.kind == "prefill":
        def prefill_step(params, batch, cache):
            return M.prefill(params, cfg, batch, cache, backend="ref")
        return prefill_step

    def serve_step(params, tokens, cache):
        return M.decode_step(params, cfg, tokens, cache, backend="ref")
    return serve_step


def shardings_for(mesh, cfg: ModelConfig, shape: InputShape, abstract_args):
    seq_shard = (shape.kind == "decode"
                 and shape.global_batch % mesh.shape["data"] != 0)
    p_sh = SH.params_shardings(mesh, abstract_args[0])
    if shape.kind == "train":
        o_sh = jax.tree.map(
            lambda _: None, abstract_args[1],
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
        # optimizer state: mu/nu shard like params (+ ZeRO-1 under REPRO_ZERO=1)
        o_sh = {"mu": SH.opt_state_shardings(mesh, abstract_args[1]["mu"]),
                "nu": SH.opt_state_shardings(mesh, abstract_args[1]["nu"]),
                "step": jax.NamedSharding(mesh, jax.sharding.PartitionSpec())}
        b_sh = SH.batch_shardings(mesh, abstract_args[2])
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, None)
    elif shape.kind == "prefill":
        b_sh = SH.batch_shardings(mesh, abstract_args[1])
        c_sh = SH.cache_shardings(mesh, abstract_args[2], seq_shard=False)
        in_sh = (p_sh, b_sh, c_sh)
        out_sh = (None, c_sh)
    else:
        t_sh = SH.batch_shardings(mesh, abstract_args[1])
        c_sh = SH.cache_shardings(mesh, abstract_args[2], seq_shard=seq_shard)
        in_sh = (p_sh, t_sh, c_sh)
        out_sh = (None, c_sh)
    return in_sh, out_sh


# --------------------------------------------------------------------- compile
def lower_and_compile(cfg: ModelConfig, shape: InputShape, mesh,
                      donate: bool = True):
    cfg = ISPEC.adapt_config(cfg, shape)
    args = ISPEC.input_specs(cfg, shape)
    step = make_step(cfg, shape)
    in_sh, out_sh = shardings_for(mesh, cfg, shape, args)
    # donation: train aliases params+opt_state in->out; prefill/decode alias
    # the cache — this is what makes the per-device temp footprint realistic
    donate = (0, 1) if shape.kind == "train" else (2,)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate)
    with mesh:
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return compiled, {"lower_s": t1 - t0, "compile_s": t2 - t1}


def analyze(compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    ca = compiled.cost_analysis() or {}
    out["hlo_flops_raw"] = float(ca.get("flops", 0.0))
    out["hlo_bytes_raw"] = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                out[f] = int(v)
    hlo = compiled.as_text()
    out["collectives"] = parse_collective_bytes(hlo)
    return out


# --------------------------------------------- component (unrolled) accounting
def _component_cfgs(cfg: ModelConfig) -> Dict[str, ModelConfig]:
    """Tiny-depth variants whose UNROLLED compiles let us solve exact per-layer
    HLO costs (XLA counts while bodies once, so the scanned compile can't)."""
    r = dataclasses.replace
    if cfg.family == "hybrid":
        return {"m1": r(cfg, num_layers=1, hybrid_attn_every=0),
                "m2": r(cfg, num_layers=2, hybrid_attn_every=0),
                "m3": r(cfg, num_layers=3, hybrid_attn_every=0),
                "a1": r(cfg, num_layers=1, hybrid_attn_every=1)}
    if cfg.family == "audio":
        return {"e1d1": r(cfg, encoder_layers=1, num_layers=1),
                "e2d1": r(cfg, encoder_layers=2, num_layers=1),
                "e3d1": r(cfg, encoder_layers=3, num_layers=1),
                "e1d2": r(cfg, encoder_layers=1, num_layers=2),
                "e1d3": r(cfg, encoder_layers=1, num_layers=3)}
    return {"l1": r(cfg, num_layers=1), "l2": r(cfg, num_layers=2),
            "l3": r(cfg, num_layers=3)}


def _combine(cfg: ModelConfig, shape: InputShape,
             comp: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    """Extrapolate totals from component measurements."""
    L = cfg.num_layers

    def slope(a, b, c):
        """Robust per-layer increment from three depth points: GSPMD can make
        non-additive resharding choices per graph, so take the median of the
        three consistent difference estimators and clamp at 0."""
        cands = sorted([b - a, c - b, (c - a) / 2.0])
        return max(0.0, cands[1])

    def merge(fn):
        if cfg.family == "hybrid":
            n_attn = sum(1 for *_, a in M._hybrid_segments(cfg) if a)
            body = slope(fn("m1"), fn("m2"), fn("m3"))
            attn = max(0.0, fn("a1") - fn("m1"))
            return max(fn("m1") - body, 0.0) + L * body + n_attn * attn
        if cfg.family == "audio":
            enc_body = slope(fn("e1d1"), fn("e2d1"), fn("e3d1"))
            dec_body = slope(fn("e1d1"), fn("e1d2"), fn("e1d3"))
            E = cfg.encoder_layers
            base = max(fn("e1d1") - enc_body - dec_body, 0.0)
            # decode shapes never run the encoder (enc cost sits in prefill)
            if shape.kind == "decode":
                return max(fn("e1d1") - dec_body, 0.0) + L * dec_body
            return base + E * enc_body + L * dec_body
        body = slope(fn("l1"), fn("l2"), fn("l3"))
        return max(fn("l1") - body, 0.0) + L * body

    out = {"hlo_flops": merge(lambda k: comp[k]["hlo_flops_raw"]),
           "hlo_bytes": merge(lambda k: comp[k]["hlo_bytes_raw"])}
    for op in COLLECTIVE_OPS:
        out[f"coll_{op}"] = max(0.0, merge(lambda k: comp[k]["collectives"][op]))
    out["collective_bytes"] = sum(out[f"coll_{op}"] for op in COLLECTIVE_OPS)
    return out


def component_analysis(cfg: ModelConfig, shape: InputShape, mesh) -> Dict[str, float]:
    comps = {}
    with unrolled():
        for name, ccfg in _component_cfgs(cfg).items():
            compiled, _ = lower_and_compile(ccfg, shape, mesh)
            comps[name] = analyze(compiled)
    return _combine(cfg, shape, comps)


# --------------------------------------------------------------------- driver
def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            components: bool = True, out_dir: str = RESULTS_DIR,
            force: bool = False) -> Optional[Dict[str, Any]]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ok, reason = supports_shape(cfg, shape)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "SKIP", "reason": reason}
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"[dryrun] SKIP {arch} x {shape_name}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                           "status": "OK"}
    try:
        compiled, times = lower_and_compile(cfg, shape, mesh)
        rec.update(times)
        rec["full"] = analyze(compiled)
        del compiled
        if components and not multi_pod:
            rec["extrapolated"] = component_analysis(cfg, shape, mesh)
    except Exception as e:  # noqa: BLE001 — failures are the experiment result
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"[:2000]
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    flag = rec["status"]
    extra = ""
    if flag == "OK":
        mb = rec["full"].get("temp_size_in_bytes", 0) / 2**20
        extra = (f" compile={rec.get('compile_s', 0):.1f}s temp/dev={mb:.0f}MiB"
                 f" coll={rec['full']['collectives']}")
    print(f"[dryrun] {flag} {arch} x {shape_name} ({mesh_tag}){extra}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-components", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                run_one(arch, shape, multi_pod=mp,
                        components=not args.no_components, force=args.force)


if __name__ == "__main__":
    main()
