"""Serving entry point: hybrid-fleet router + real JAX engines.

``python -m repro.launch.serve --arch smollm-360m --requests 50``

Routes an Alpaca-like request stream across an (efficiency, performance) pool
pair with the paper's scheduler, executes every request on the JAX engine,
and prints the fleet energy/runtime report.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core.systems import paper_fleet, tpu_fleet
from repro.core.workload import sample_workload
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=20)
    ap.add_argument("--policy", default="threshold",
                    choices=("threshold", "cost_optimal", "capacity_aware"))
    ap.add_argument("--t-in", type=int, default=32)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--fleet", default="tpu", choices=("tpu", "paper"))
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = InferenceEngine(cfg, params, max_len=512)
    eff, perf = tpu_fleet() if args.fleet == "tpu" else paper_fleet()
    router = FleetRouter(cfg, {eff.name: eff, perf.name: perf},
                         {eff.name: engine, perf.name: engine},
                         policy=args.policy, t_in=args.t_in, lam=args.lam,
                         counts={eff.name: 4, perf.name: 1})
    rng = np.random.default_rng(args.seed)
    for q in sample_workload(args.requests, seed=args.seed):
        m = min(q.m, 400)
        prompt = rng.integers(0, cfg.vocab_size, size=m)
        res = router.submit(prompt, min(args.max_new_tokens, q.n))
        print(f"req{res.rid:4d} m={m:5d} n={min(args.max_new_tokens, q.n):4d} "
              f"-> {res.pool:16s} E={res.energy_j:8.2f}J R={res.runtime_s:6.3f}s "
              f"tokens={res.output[:8] if res.output is not None else None}")
    print("\nfleet report:")
    for pool, st in router.fleet_report().items():
        print(f"  {pool:16s} queries={st['queries']:4d} "
              f"energy={st['energy_j']:10.1f}J runtime={st['runtime_s']:8.2f}s")


if __name__ == "__main__":
    main()
