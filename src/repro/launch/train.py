"""Training entry point: ``python -m repro.launch.train --arch <id> [...]``.

CPU-sized by default (reduced config). Full configs + the production mesh are
exercised by dryrun.py; this driver does real optimization steps.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--checkpoint", default="")
    ap.add_argument("--data", choices=("arithmetic", "uniform"), default="arithmetic")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.name} family={cfg.family} params~"
          f"{cfg.param_count() / 1e6:.1f}M reduced={not args.full_config}")

    params = M.init_params(cfg, jax.random.PRNGKey(args.seed),
                           max_positions=max(args.seq_len + 1, 256))
    opt = OPT.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps)
    stream = (D.arithmetic_stream if args.data == "arithmetic" else
              D.uniform_stream)(cfg, args.batch_size, args.seq_len,
                                args.steps, seed=args.seed)
    t0 = time.time()
    params, state, hist = train_loop(cfg, params, stream, opt,
                                     remat=args.remat,
                                     log_every=max(args.steps // 20, 1))
    dt = time.time() - t0
    toks = args.steps * args.batch_size * args.seq_len
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({toks / dt:.0f} tok/s); loss {hist[0][1]:.3f} -> {hist[-1][1]:.3f}")
    if args.checkpoint:
        CKPT.save(args.checkpoint, params, state,
                  {"arch": cfg.name, "steps": args.steps, "final_loss": hist[-1][1]})
        print(f"[train] checkpoint -> {args.checkpoint}")


if __name__ == "__main__":
    main()
