"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention block
interleaved every 6 layers.  [arXiv:2411.15242]
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    pos_emb="rope",
    activation="gelu",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=128),
    hybrid_attn_every=6,
    sliding_window=8192,   # shared attn blocks use a sliding window -> long_500k viable
    source="arXiv:2411.15242",
    max_seq_len=1_048_576,
)
