"""Base configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``InputShape``. Configs are plain frozen dataclasses so they can be hashed and
used as jit static args.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    # capacity factor for fixed-shape expert dispatch (TPU-friendly, no dynamic shapes)
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N: SSM state size per head
    head_dim: int = 64            # P: channels per SSM head
    expand: int = 2               # d_inner = expand * d_model
    chunk_size: int = 128         # SSD chunk length
    conv_width: int = 4           # depthwise causal conv width


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # positional encoding: rope | mrope | learned | sinusoidal
    pos_emb: str = "rope"
    rope_theta: float = 10000.0
    # attention options
    qkv_bias: bool = False
    sliding_window: Optional[int] = None   # None = full attention
    attn_logit_softcap: Optional[float] = None
    # activation: swiglu | gelu | geglu
    activation: str = "swiglu"
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    tie_embeddings: bool = False
    # family-specific blocks
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2-style): one *shared* attention block applied every k layers
    hybrid_attn_every: int = 0    # 0 = no interleaved attention
    # encoder-decoder (whisper-style)
    encoder_layers: int = 0       # >0 => enc-dec
    encoder_seq_len: int = 0      # fixed encoder input length (audio frames)
    # multimodal stub frontend
    frontend: Optional[str] = None  # "audio" | "vision" | None
    num_vision_tokens: int = 0      # VLM: patch embeddings prepended to the prompt
    # citation for the config (paper/model card)
    source: str = ""
    max_seq_len: int = 131072

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return (self.ssm.expand * self.d_model) if self.ssm else 0

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm.head_dim if self.ssm else 0

    def param_count(self) -> int:
        """Approximate parameter count (used by the perf/energy model)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            attn = q + kv + o
            if self.family == "moe" and self.moe is not None:
                ffn = self.moe.num_experts * 3 * d * self.d_ff + d * self.moe.num_experts
            else:
                n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
                ffn = n_mat * d * self.d_ff
            per_layer = attn + ffn + 2 * d
        elif self.family == "ssm":
            di, N = self.d_inner, self.ssm.state_dim
            nh = self.ssm_heads
            per_layer = d * (2 * di + 2 * N + nh) + di * d + di * self.ssm.conv_width + 2 * d
        elif self.family == "hybrid":
            di, N = self.d_inner, self.ssm.state_dim
            nh = self.ssm_heads
            mamba = d * (2 * di + 2 * N + nh) + di * d + di * self.ssm.conv_width + 2 * d
            per_layer = mamba
        total = emb + L * per_layer
        if self.family == "hybrid" and self.hybrid_attn_every:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
            total += q + kv + o + n_mat * d * self.d_ff  # one SHARED block
        if self.encoder_layers:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            n_mat = 3 if self.activation in ("swiglu", "geglu") else 2
            enc_layer = q + kv + o + n_mat * d * self.d_ff + 2 * d
            cross = q + kv + o  # decoder cross-attn per layer already counted? add:
            total += self.encoder_layers * enc_layer + self.num_layers * cross
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        full_ffn = self.moe.num_experts * 3 * d * self.d_ff
        active_ffn = self.moe.num_experts_per_tok * 3 * d * self.d_ff
        return int(self.param_count() - L * (full_ffn - active_ffn))

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts, small vocab."""
        d = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        n_kv = max(1, min(self.num_kv_heads, n_heads))
        # keep the GQA ratio representative
        if self.num_kv_heads < self.num_heads:
            n_kv = max(1, n_heads // max(1, self.num_heads // self.num_kv_heads))
        moe = None
        if self.moe is not None:
            ne = min(4, self.moe.num_experts)
            nk = min(2, self.moe.num_experts_per_tok)
            # dropless capacity (C = T) so smoke tests are deterministic across
            # different batch compositions
            moe = dataclasses.replace(self.moe, num_experts=ne, num_experts_per_tok=nk,
                                      capacity_factor=float(ne) / nk)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, state_dim=min(32, self.ssm.state_dim),
                                      head_dim=32, chunk_size=32)
        return dataclasses.replace(
            self, num_layers=min(2, self.num_layers), d_model=d, num_heads=n_heads,
            num_kv_heads=n_kv, d_ff=min(512, self.d_ff), vocab_size=min(512, self.vocab_size),
            head_dim=64 if self.family != "ssm" else None,
            moe=moe, ssm=ssm,
            encoder_layers=min(2, self.encoder_layers) if self.encoder_layers else 0,
            encoder_seq_len=min(64, self.encoder_seq_len) if self.encoder_seq_len else 0,
            num_vision_tokens=min(16, self.num_vision_tokens) if self.num_vision_tokens else 0,
            hybrid_attn_every=min(2, self.hybrid_attn_every) if self.hybrid_attn_every else 0,
            max_seq_len=2048,
        )


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k":    InputShape("train_4k",    4_096,   256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  InputShape("decode_32k",  32_768,  128, "decode"),
    "long_500k":   InputShape("long_500k",   524_288, 1,   "decode"),
}
