"""whisper-base [audio]: enc-dec transformer, conv frontend stubbed.

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865.  [arXiv:2212.04356]
Whisper-base actually has 6 encoder + 6 decoder layers; 1500 audio frames
(30 s of mel features after the conv stride-2 frontend, which is STUBBED:
input_specs provides the (B, 1500, 512) frame embeddings directly).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    pos_emb="learned",
    qkv_bias=True,
    activation="gelu",
    norm="layernorm",
    encoder_layers=6,
    encoder_seq_len=1500,
    frontend="audio",
    source="arXiv:2212.04356",
    max_seq_len=448,
)
