"""llama2-7b — one of the paper's three benchmark models.  [arXiv:2307.09288]
32L d_model=4096 32H MHA d_ff=11008 vocab=32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    pos_emb="rope",
    activation="swiglu",
    source="arXiv:2307.09288 (paper Section 4.1.2)",
)
