"""falcon-7b — one of the paper's three benchmark models.  [Falcon series]
32L d_model=4544 71H (MQA kv=1) d_ff=18176 (4*d) vocab=65024, gelu.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-7b",
    family="dense",
    num_layers=32,
    d_model=4544,
    num_heads=71,
    num_kv_heads=1,
    d_ff=18176,
    vocab_size=65024,
    pos_emb="rope",
    activation="gelu",
    source="Falcon series (paper Section 4.1.1)",
)
