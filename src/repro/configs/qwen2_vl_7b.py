"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, M-RoPE, dynamic resolution.  [arXiv:2409.12191]

Vision encoder (ViT) is STUBBED per the carve-out: input_specs provides
precomputed patch embeddings of shape (B, num_vision_tokens, d_model) which the
language model consumes interleaved before text tokens, with M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    pos_emb="mrope",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    activation="swiglu",
    frontend="vision",
    num_vision_tokens=256,
    source="arXiv:2409.12191",
)
