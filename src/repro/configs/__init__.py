"""Architecture config registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig, MoEConfig, SSMConfig

# arch-id -> module name
_ARCH_MODULES = {
    "whisper-base":           "whisper_base",
    "phi3.5-moe-42b-a6.6b":   "phi3_5_moe_42b_a6_6b",
    "qwen2.5-3b":             "qwen2_5_3b",
    "deepseek-7b":            "deepseek_7b",
    "qwen2-vl-7b":            "qwen2_vl_7b",
    "mamba2-130m":            "mamba2_130m",
    "zamba2-1.2b":            "zamba2_1_2b",
    "grok-1-314b":            "grok_1_314b",
    "smollm-360m":            "smollm_360m",
    "phi3-medium-14b":        "phi3_medium_14b",
}

# the paper's own Section 4.1 benchmark models (selectable, not part of the
# assigned 10-arch dry-run matrix)
_PAPER_MODULES = {
    "llama2-7b":  "llama2_7b",
    "mistral-7b": "mistral_7b",
    "falcon-7b":  "falcon_7b",
}
_ARCH_MODULES.update(_PAPER_MODULES)


def list_archs() -> list[str]:
    """The 10 assigned architectures (dry-run / smoke matrix)."""
    return [a for a in _ARCH_MODULES if a not in _PAPER_MODULES]


def list_paper_archs() -> list[str]:
    return list(_PAPER_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    if name not in INPUT_SHAPES:
        raise KeyError(f"unknown input shape {name!r}; available: {sorted(INPUT_SHAPES)}")
    return INPUT_SHAPES[name]


def supports_shape(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is a supported combination; returns (ok, reason)."""
    if shape.name == "long_500k":
        if cfg.family == "audio":
            return False, ("enc-dec decoder context is bounded by design (448 positions in "
                           "whisper); 500k-token decode is architecturally meaningless — see "
                           "DESIGN.md shape-coverage notes")
        # sub-quadratic requirement: SSM/hybrid are natively fine; attention archs
        # run via the sliding-window variant (enabled automatically by the launcher).
        return True, "ssm/hybrid native or sliding-window attention variant"
    return True, ""


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "InputShape", "INPUT_SHAPES",
    "get_config", "get_shape", "list_archs", "supports_shape",
]
