"""mistral-7b — one of the paper's three benchmark models.  [arXiv:2310.06825]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, sliding window 4096.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    pos_emb="rope",
    activation="swiglu",
    sliding_window=4096,
    source="arXiv:2310.06825 (paper Section 4.1.3)",
)
