"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssd_scan

RNG = np.random.default_rng(42)


def _qkv(B, Hq, Hkv, Sq, Sk, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, Sq, D)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Hkv, Sk, D)), dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (1, 1, 1, 128, 64),     # MHA, exactly one block
    (2, 4, 2, 256, 64),     # GQA 2:1
    (1, 8, 1, 200, 128),    # MQA, ragged seq (padding path)
    (2, 6, 2, 384, 64),     # GQA 3:1
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, Hq, Hkv, S, D, dtype):
    q, k, v = _qkv(B, Hq, Hkv, S, S, D, dtype)
    want = ref.mha_attention(q, k, v, causal=True)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [1, 17, 64, 1000])
def test_flash_attention_sliding_window(window):
    q, k, v = _qkv(1, 4, 2, 300, 64, 64, jnp.float32)
    # note Sq=300 vs Sk=64? keep square for window semantics
    q, k, v = _qkv(1, 4, 2, 300, 300, 64, jnp.float32)
    want = ref.mha_attention(q, k, v, causal=True, window=window)
    got = flash_attention(q, k, v, causal=True, window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_softcap_and_noncausal():
    q, k, v = _qkv(2, 4, 4, 160, 160, 64, jnp.float32)
    for kwargs in ({"softcap": 30.0, "causal": True},
                   {"causal": False},
                   {"causal": False, "softcap": 10.0}):
        want = ref.mha_attention(q, k, v, **kwargs)
        got = flash_attention(q, k, v, interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_q_offset():
    """Chunked prefill: later q chunk with offset against full K."""
    q, k, v = _qkv(1, 2, 2, 64, 256, 64, jnp.float32)
    want = ref.mha_attention(q, k, v, causal=True, q_offset=192)
    got = flash_attention(q, k, v, causal=True, q_offset=192, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_chunked_ref_matches_dense_ref():
    q, k, v = _qkv(1, 4, 2, 1000, 1000, 64, jnp.float32)
    want = ref.mha_attention(q, k, v, causal=True, window=123)
    got = ref.mha_attention_chunked(q, k, v, causal=True, window=123, block_q=256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# --------------------------------------------------------------- decode attention
@pytest.mark.parametrize("B,Hq,Hkv,Smax,D", [
    (1, 4, 4, 128, 64),
    (2, 8, 2, 300, 64),
    (3, 4, 1, 257, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, Hq, Hkv, Smax, D, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Hq, 1, D)), dtype)
    kc = jnp.asarray(RNG.normal(size=(B, Hkv, Smax, D)), dtype)
    vc = jnp.asarray(RNG.normal(size=(B, Hkv, Smax, D)), dtype)
    kv_len = jnp.asarray(RNG.integers(1, Smax + 1, size=(B,)), jnp.int32)
    want = ref.decode_attention(q, kc, vc, kv_len=kv_len)
    got = decode_attention(q, kc, vc, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_decode_attention_window_and_softcap():
    B, Hq, Hkv, Smax, D = 2, 8, 2, 384, 64
    q = jnp.asarray(RNG.normal(size=(B, Hq, 1, D)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(B, Hkv, Smax, D)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(B, Hkv, Smax, D)), jnp.float32)
    kv_len = jnp.asarray([100, 384], jnp.int32)
    for kwargs in ({"window": 64}, {"softcap": 20.0}, {"window": 32, "softcap": 5.0}):
        want = ref.decode_attention(q, kc, vc, kv_len=kv_len, **kwargs)
        got = decode_attention(q, kc, vc, kv_len, interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# ------------------------------------------------------- paged decode attention
@pytest.mark.parametrize("B,Hq,Hkv,bs,nb,mb,D", [
    (1, 4, 4, 128, 8, 4, 64),     # MHA, kernel-sized blocks
    (2, 8, 2, 16, 24, 6, 64),     # GQA, small serving blocks
    (3, 4, 1, 32, 12, 5, 128),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention_matches_gathered_ref(B, Hq, Hkv, bs, nb, mb,
                                                     D, dtype):
    from repro.kernels.decode_attention import paged_decode_attention
    q = jnp.asarray(RNG.normal(size=(B, Hq, 1, D)), dtype)
    kp = jnp.asarray(RNG.normal(size=(nb, Hkv, bs, D)), dtype)
    vp = jnp.asarray(RNG.normal(size=(nb, Hkv, bs, D)), dtype)
    tables = jnp.asarray(RNG.integers(0, nb, size=(B, mb)), jnp.int32)
    kv_len = jnp.asarray(RNG.integers(1, mb * bs + 1, size=(B,)), jnp.int32)
    want = ref.paged_decode_attention(q, kp, vp, tables, kv_len=kv_len)
    got = paged_decode_attention(q, kp, vp, tables, kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    # the gathered contiguous view reduces to dense decode exactly
    k = ref.gather_paged_kv(kp, tables)
    v = ref.gather_paged_kv(vp, tables)
    dense = ref.decode_attention(q, k, v, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(dense, np.float32), atol=0)


def test_paged_decode_attention_window_and_softcap():
    from repro.kernels.decode_attention import paged_decode_attention
    B, Hq, Hkv, bs, nb, mb, D = 2, 8, 2, 32, 16, 8, 64
    q = jnp.asarray(RNG.normal(size=(B, Hq, 1, D)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(nb, Hkv, bs, D)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(nb, Hkv, bs, D)), jnp.float32)
    tables = jnp.asarray(RNG.integers(0, nb, size=(B, mb)), jnp.int32)
    kv_len = jnp.asarray([60, 256], jnp.int32)
    for kwargs in ({"window": 64}, {"softcap": 20.0},
                   {"window": 48, "softcap": 5.0}):
        want = ref.paged_decode_attention(q, kp, vp, tables, kv_len=kv_len,
                                          **kwargs)
        got = paged_decode_attention(q, kp, vp, tables, kv_len,
                                     interpret=True, **kwargs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 1, 64, 32, 16, 32),
    (2, 3, 200, 32, 64, 64),     # ragged (padding path)
    (1, 4, 256, 64, 128, 128),   # full-size state
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, H, S, P, N, chunk, dtype):
    x = jnp.asarray(RNG.normal(size=(B, H, S, P)), dtype)
    dt = jnp.asarray(RNG.uniform(0.001, 0.2, size=(B, H, S)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), dtype)
    y_want, fs_want = ref.ssd_scan(x, dt, A, Bm, Cm)
    y_got, fs_got = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y_got, np.float32),
                               np.asarray(y_want, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(fs_got), np.asarray(fs_want),
                               atol=tol, rtol=tol)


def test_ssd_chunked_jnp_matches_sequential():
    B, H, S, P, N = 2, 2, 330, 32, 16
    x = jnp.asarray(RNG.normal(size=(B, H, S, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.001, 0.2, size=(B, H, S)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 4.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    y1, f1 = ref.ssd_scan(x, dt, A, Bm, Cm)
    y2, f2 = ref.ssd_scan_chunked(x, dt, A, Bm, Cm, chunk=128)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=2e-5)


def test_ssd_decode_step_matches_scan_tail():
    """Running decode steps from the scan's final state continues the sequence."""
    B, H, S, P, N = 1, 2, 96, 32, 16
    x = jnp.asarray(RNG.normal(size=(B, H, S + 3, P)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, size=(B, H, S + 3)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S + 3, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S + 3, N)), jnp.float32)
    y_full, _ = ref.ssd_scan(x, dt, A, Bm, Cm)
    _, state = ref.ssd_scan(x[:, :, :S], dt[:, :, :S], A, Bm[:, :S], Cm[:, :S])
    for t in range(3):
        y_t, state = ref.ssd_decode_step(state, x[:, :, S + t], dt[:, :, S + t],
                                         A, Bm[:, S + t], Cm[:, S + t])
        np.testing.assert_allclose(np.asarray(y_t), np.asarray(y_full[:, :, S + t]),
                                   atol=2e-5)
