"""Carbon-aware scheduling extension (beyond-paper)."""
import math

from repro.configs import get_config
from repro.core import Query, ThresholdScheduler, paper_fleet
from repro.core.carbon import CarbonAwareScheduler, CarbonProfile, total_grams

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()


def test_intensity_daily_swing():
    cp = CarbonProfile()
    trough = cp.intensity(13 * 3600.0)
    peak = cp.intensity(1 * 3600.0)
    assert trough < cp.mean_g_per_kwh < peak
    assert abs(cp.intensity(0) - cp.intensity(24 * 3600.0)) < 1e-9


def test_deferral_reduces_carbon_not_energy():
    """Deferring batch queries to green windows cuts grams at equal joules."""
    # arrivals at the evening carbon peak
    qs = [Query(64, 512, arrival_s=20 * 3600.0 + i) for i in range(20)]
    cp = CarbonProfile()
    base = ThresholdScheduler(CFG, EFF, PERF, t_in=32).assign(qs)
    green = CarbonAwareScheduler(CFG, [EFF, PERF], cp,
                                 defer_out_threshold=256).assign(qs)
    assert total_grams(CFG, green, cp) < total_grams(CFG, base, cp)
    # deferral happened
    assert any(a.wait_s > 0 for a in green)


def test_interactive_queries_not_deferred():
    qs = [Query(16, 16, arrival_s=20 * 3600.0)]
    green = CarbonAwareScheduler(CFG, [EFF, PERF]).assign(qs)
    assert green[0].wait_s == 0.0


def test_deferral_bounded():
    sched = CarbonAwareScheduler(CFG, [EFF, PERF], max_defer_s=3600.0)
    a = sched.assign([Query(64, 512, arrival_s=20 * 3600.0)])[0]
    assert a.wait_s <= 3600.0
