"""Carbon-aware scheduling extension (beyond-paper)."""
import math

from repro.configs import get_config
from repro.core import Query, ThresholdScheduler, paper_fleet
from repro.core.carbon import CarbonAwareScheduler, CarbonProfile, total_grams

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()


def test_intensity_daily_swing():
    cp = CarbonProfile()
    trough = cp.intensity(13 * 3600.0)
    peak = cp.intensity(1 * 3600.0)
    assert trough < cp.mean_g_per_kwh < peak
    assert abs(cp.intensity(0) - cp.intensity(24 * 3600.0)) < 1e-9


def test_deferral_reduces_carbon_not_energy():
    """Deferring batch queries to green windows cuts grams at equal joules."""
    # arrivals at the evening carbon peak
    qs = [Query(64, 512, arrival_s=20 * 3600.0 + i) for i in range(20)]
    cp = CarbonProfile()
    base = ThresholdScheduler(CFG, EFF, PERF, t_in=32).assign(qs)
    green = CarbonAwareScheduler(CFG, [EFF, PERF], cp,
                                 defer_out_threshold=256).assign(qs)
    assert total_grams(CFG, green, cp) < total_grams(CFG, base, cp)
    # deferral happened
    assert any(a.wait_s > 0 for a in green)


def test_interactive_queries_not_deferred():
    qs = [Query(16, 16, arrival_s=20 * 3600.0)]
    green = CarbonAwareScheduler(CFG, [EFF, PERF]).assign(qs)
    assert green[0].wait_s == 0.0


def test_deferral_bounded():
    sched = CarbonAwareScheduler(CFG, [EFF, PERF], max_defer_s=3600.0)
    a = sched.assign([Query(64, 512, arrival_s=20 * 3600.0)])[0]
    assert a.wait_s <= 3600.0


# ------------------------------------------------------- satellite regression
def test_carbon_scheduler_dispatches_in_fleet_simulator():
    """Satellite regression: CarbonAwareScheduler used to crash with
    NotImplementedError inside core/fleet.py (no choose/dispatch). It must
    run under the online dispatch API like every other policy."""
    from repro.core import PoolSpec, simulate_fleet
    qs = [Query(16, 16, float(i)) for i in range(5)] + \
         [Query(64, 512, float(i)) for i in range(5)]
    res = simulate_fleet(
        CFG, qs,
        {"eff": PoolSpec(EFF, 2, 1), "perf": PoolSpec(PERF, 2, 1)},
        CarbonAwareScheduler(CFG, [EFF, PERF]))
    assert len(res.records) == len(qs)
    assert all(r.t_done >= r.t_arrival for r in res.records)


def test_carbon_dispatch_uses_snapshot_clock():
    """The route-now vs defer decision reads the fleet snapshot's clock: a
    deferrable query is priced at the next green window seen from *that*
    clock, an interactive one at the clock itself."""
    from repro.core import FleetState
    cp = CarbonProfile()
    sched = CarbonAwareScheduler(CFG, [EFF, PERF], cp,
                                 defer_out_threshold=256)
    peak = 1 * 3600.0                       # carbon peak (trough + 12h)
    batch_q = Query(64, 512, arrival_s=0.0)     # deferrable
    chat_q = Query(16, 16, arrival_s=0.0)       # interactive
    state = FleetState(time_s=peak)
    # deferrable: decision matches the greenest system at the green window
    t_green = sched._next_green_window(peak)
    assert cp.intensity(t_green) < cp.intensity(peak)
    want = min([EFF, PERF],
               key=lambda s: sched.model.grams(batch_q.m, batch_q.n, s, t_green))
    assert sched.dispatch(batch_q, state).pool == want.name
    # interactive: priced at the snapshot clock itself
    want_now = min([EFF, PERF],
                   key=lambda s: sched.model.grams(chat_q.m, chat_q.n, s, peak))
    assert sched.dispatch(chat_q, state).pool == want_now.name
    # without a snapshot the query's own arrival clock is used
    assert sched.dispatch(chat_q).pool == min(
        [EFF, PERF], key=lambda s: sched.model.grams(
            chat_q.m, chat_q.n, s, chat_q.arrival_s)).name


def test_carbon_scheduler_rejects_conflicting_profiles():
    """An explicit carbon= that disagrees with a carbon-bearing model= must
    raise, not silently lose (mirrors the cp=/model= and oracle=/model=
    conflict checks)."""
    import pytest
    from repro.core import CostModel
    with pytest.raises(ValueError):
        CarbonAwareScheduler(
            CFG, [EFF, PERF], CarbonProfile(trough_hour=2.0),
            model=CostModel(CFG, carbon=CarbonProfile()))


def test_carbon_scheduler_adopts_model_profile():
    """A CostModel passed in with its own CarbonProfile is authoritative:
    window planning and pricing must read the same curve."""
    from repro.core import CostModel
    shifted = CarbonProfile(trough_hour=2.0)
    sched = CarbonAwareScheduler(
        CFG, [EFF, PERF], model=CostModel(CFG, carbon=shifted))
    assert sched.carbon is shifted
    assert sched.model.carbon is shifted
    # green window from the shifted curve, not the 13:00 default
    t = sched._next_green_window(22 * 3600.0)
    assert shifted.intensity(t) <= shifted.mean_g_per_kwh * sched.defer_below
