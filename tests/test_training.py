"""Training substrate: optimizer math, loss descent, remat equivalence,
checkpoint roundtrip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import lm_loss, make_train_step, train_loop

KEY = jax.random.PRNGKey(3)


def test_lr_schedule_shape():
    cfg = OPT.AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(OPT.lr_at(cfg, 0)) == 0.0
    assert float(OPT.lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
    assert float(OPT.lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-2)
    assert float(OPT.lr_at(cfg, 55)) < 1e-3


def test_adamw_converges_quadratic():
    """AdamW drives a toy quadratic to its minimum."""
    params = {"w": jnp.asarray([5.0, -3.0])}
    cfg = OPT.AdamWConfig(lr=0.2, weight_decay=0.0, warmup_steps=0,
                          total_steps=200, min_lr_frac=1.0)
    state = OPT.init_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = OPT.apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_applied():
    params = {"w": jnp.ones((4,))}
    cfg = OPT.AdamWConfig(grad_clip=0.1)
    state = OPT.init_state(params)
    _, _, m = OPT.apply_updates(cfg, params, {"w": jnp.full((4,), 100.0)}, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_loss_decreases_on_learnable_task():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    opt = OPT.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    batches = D.arithmetic_stream(cfg, 8, 32, 40, seed=0)
    _, _, hist = train_loop(cfg, params, batches, opt, log_every=10,
                            log=lambda *_: None)
    assert hist[-1][1] < hist[0][1] * 0.8


def test_remat_matches_no_remat():
    cfg = get_config("qwen2.5-3b").reduced()
    params = M.init_params(cfg, KEY)
    batch = next(D.uniform_stream(cfg, 2, 16, 1, seed=1))
    l1, _ = lm_loss(params, cfg, batch, remat=False)
    l2, _ = lm_loss(params, cfg, batch, remat=True)
    assert float(jnp.abs(l1 - l2)) < 1e-5
    g1 = jax.grad(lambda p: lm_loss(p, cfg, batch, remat=False)[0])(params)
    g2 = jax.grad(lambda p: lm_loss(p, cfg, batch, remat=True)[0])(params)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-4


def test_loss_mask():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    batch = next(D.uniform_stream(cfg, 2, 16, 1, seed=2))
    full, _ = lm_loss(params, cfg, batch)
    masked, _ = lm_loss(params, cfg, dict(
        batch, loss_mask=jnp.zeros_like(batch["tokens"]).at[:, :8].set(1)))
    assert float(full) != pytest.approx(float(masked))


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("mamba2-130m").reduced()
    params = M.init_params(cfg, KEY)
    state = OPT.init_state(params)
    p = str(tmp_path / "ck.npz")
    CKPT.save(p, params, state, {"arch": cfg.name, "step": 0})
    p2, s2, meta = CKPT.restore(p, params, state)
    assert meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ssm_training_gradients_finite():
    """Regression: masked exp(seg) overflow in the chunked SSD backward made
    mamba2 grads NaN (where-grad picks the masked branch) — clamp before exp."""
    cfg = get_config("mamba2-130m").reduced()
    params = M.init_params(cfg, KEY)
    # large dt excursions are what triggered the overflow; run real steps
    opt = OPT.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
    step = jax.jit(make_train_step(cfg, opt))
    state = OPT.init_state(params)
    for batch in D.arithmetic_stream(cfg, 4, 64, 30, seed=3):
        params, state, m = step(params, state, batch)
        assert bool(jnp.isfinite(m["loss"])), "loss went non-finite"
        assert bool(jnp.isfinite(m["grad_norm"])), "grads went non-finite"
