"""Property tests for the paper's Eq. 2 partition problem and the scheduler
implementations.

Runs as hypothesis property tests when the optional dependency is installed
(see pyproject [test] extras); otherwise each property is exercised over
deterministic seeded cases spanning the same ranges, so the suite collects
and passes either way (previously a hard ``import hypothesis`` killed
collection of the whole tier-1 suite).
"""
import numpy as np
import pytest

try:  # optional dependency — guarded so collection never fails
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostOptimalScheduler, CostParams,
                        Query, RoundRobinScheduler, SingleSystemScheduler,
                        ThresholdScheduler, cost, energy, paper_fleet, runtime,
                        simulate, tpu_fleet)

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()


def _rand_queries(seed: int, max_size: int = 40) -> list[Query]:
    """Deterministic stand-in for the hypothesis queries strategy:
    1-40 queries, m in [1, 2048], n in [1, 512], arrival in [0, 100]."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(1, max_size + 1))
    return [Query(int(rng.integers(1, 2049)), int(rng.integers(1, 513)),
                  float(rng.uniform(0, 100))) for _ in range(k)]


def _rand_lam(seed: int) -> float:
    return float(np.random.default_rng(1000 + seed).uniform(0.0, 1.0))


if HAVE_HYPOTHESIS:
    queries_st = st.lists(
        st.builds(Query,
                  m=st.integers(min_value=1, max_value=2048),
                  n=st.integers(min_value=1, max_value=512),
                  arrival_s=st.floats(min_value=0, max_value=100)),
        min_size=1, max_size=40)


# ------------------------------------------------------------ property bodies
def check_partition_complete_and_disjoint(qs):
    """Eq. 3/4: every query assigned exactly once."""
    for sched in (ThresholdScheduler(CFG, EFF, PERF),
                  CostOptimalScheduler(CFG, [EFF, PERF]),
                  RoundRobinScheduler(CFG, [EFF, PERF])):
        assignments = sched.assign(qs)
        assert len(assignments) == len(qs)
        assert all(a.system in (EFF, PERF) for a in assignments)


def check_cost_optimal_dominates_for_its_lambda(qs, lam):
    """Per-query argmin is optimal for the uncapacitated separable objective:
    no other policy can have lower total cost at the same lambda."""
    cp = CostParams(lam=lam)
    opt = CostOptimalScheduler(CFG, [EFF, PERF], cp)
    base = ThresholdScheduler(CFG, EFF, PERF, cp=cp)

    def total(assigns):
        return sum(cp.lam * a.energy_j + (1 - cp.lam) * a.runtime_s
                   for a in assigns)
    assert total(opt.assign(qs)) <= total(base.assign(qs)) + 1e-6


def check_threshold_routing_rule(m, n):
    sched = ThresholdScheduler(CFG, EFF, PERF, t_in=32, t_out=64, axis="in")
    assert sched.choose(Query(m, n)) is (EFF if m <= 32 else PERF)
    sched_o = ThresholdScheduler(CFG, EFF, PERF, t_in=32, t_out=64, axis="out")
    assert sched_o.choose(Query(m, n)) is (EFF if n <= 64 else PERF)


def check_capacity_aware_waits_nonnegative_and_bounded(qs):
    sched = CapacityAwareScheduler(CFG, [EFF, PERF],
                                   counts={EFF.name: 2, PERF.name: 1})
    assigns = sched.assign(qs)
    assert all(a.wait_s >= 0 for a in assigns)
    # with infinite-capacity behaviour disabled, waits only arise from overlap
    total_service = sum(a.runtime_s for a in assigns)
    assert all(a.wait_s <= total_service for a in assigns)


def check_energy_runtime_positive_and_monotone_in_tokens(m, n):
    for s in (EFF, PERF, *tpu_fleet()):
        assert energy(CFG, m, n, s) > 0
        assert runtime(CFG, m, n, s) > 0
        assert energy(CFG, m + 64, n, s) >= energy(CFG, m, n, s)
        assert energy(CFG, m, n + 64, s) >= energy(CFG, m, n, s)
        assert runtime(CFG, m, n + 64, s) >= runtime(CFG, m, n, s)


def check_cost_is_convex_combination(m, n, lam):
    cp = CostParams(lam=lam)
    for s in (EFF, PERF):
        u = cost(CFG, m, n, s, cp)
        e, r = energy(CFG, m, n, s), runtime(CFG, m, n, s)
        assert min(e, r) - 1e-9 <= u <= max(e, r) + 1e-9


# --------------------------------------------------------- hypothesis drivers
if HAVE_HYPOTHESIS:
    @given(queries_st)
    @settings(max_examples=25, deadline=None)
    def test_partition_complete_and_disjoint(qs):
        check_partition_complete_and_disjoint(qs)

    @given(queries_st, st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=25, deadline=None)
    def test_cost_optimal_dominates_for_its_lambda(qs, lam):
        check_cost_optimal_dominates_for_its_lambda(qs, lam)

    @given(st.integers(min_value=1, max_value=2048),
           st.integers(min_value=1, max_value=2048))
    @settings(max_examples=50, deadline=None)
    def test_threshold_routing_rule(m, n):
        check_threshold_routing_rule(m, n)

    @given(queries_st)
    @settings(max_examples=15, deadline=None)
    def test_capacity_aware_waits_nonnegative_and_bounded(qs):
        check_capacity_aware_waits_nonnegative_and_bounded(qs)

    @given(st.integers(min_value=1, max_value=1024),
           st.integers(min_value=1, max_value=256))
    @settings(max_examples=40, deadline=None)
    def test_energy_runtime_positive_and_monotone_in_tokens(m, n):
        check_energy_runtime_positive_and_monotone_in_tokens(m, n)

    @given(st.integers(min_value=1, max_value=512),
           st.integers(min_value=1, max_value=512),
           st.floats(min_value=0.0, max_value=1.0))
    @settings(max_examples=40, deadline=None)
    def test_cost_is_convex_combination(m, n, lam):
        check_cost_is_convex_combination(m, n, lam)

# ------------------------------------------------- deterministic fallbacks
else:
    @pytest.mark.parametrize("seed", range(10))
    def test_partition_complete_and_disjoint(seed):
        check_partition_complete_and_disjoint(_rand_queries(seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_cost_optimal_dominates_for_its_lambda(seed):
        check_cost_optimal_dominates_for_its_lambda(_rand_queries(seed),
                                                    _rand_lam(seed))

    @pytest.mark.parametrize("m,n", [(1, 1), (31, 65), (32, 64), (33, 63),
                                     (2048, 1), (1, 2048), (100, 100),
                                     (512, 512)])
    def test_threshold_routing_rule(m, n):
        check_threshold_routing_rule(m, n)

    @pytest.mark.parametrize("seed", range(6))
    def test_capacity_aware_waits_nonnegative_and_bounded(seed):
        check_capacity_aware_waits_nonnegative_and_bounded(_rand_queries(seed))

    @pytest.mark.parametrize("m,n", [(1, 1), (7, 3), (64, 64), (1000, 250),
                                     (1024, 256), (500, 1)])
    def test_energy_runtime_positive_and_monotone_in_tokens(m, n):
        check_energy_runtime_positive_and_monotone_in_tokens(m, n)

    @pytest.mark.parametrize("m,n,lam", [(1, 1, 0.0), (32, 32, 1.0),
                                         (100, 50, 0.5), (512, 512, 0.25),
                                         (7, 400, 0.75)])
    def test_cost_is_convex_combination(m, n, lam):
        check_cost_is_convex_combination(m, n, lam)


def test_capacity_aware_dispatch_pure_wrt_reservation_heap():
    """Satellite regression: ``choose``/``dispatch`` must NOT mutate the
    reservation heap (previously ``choose`` reserved as a side effect, so
    snapshot-dispatch followed by a no-snapshot fallback double-booked).
    Reservation is an explicit ``observe``/``reserve`` step."""
    from repro.core import FleetState, PoolSnapshot
    sched = CapacityAwareScheduler(CFG, [EFF, PERF],
                                   counts={EFF.name: 2, PERF.name: 1})
    heaps = {k: list(p.free_at) for k, p in sched.pools.items()}
    q = Query(8, 8, 1.0)
    snap = FleetState(pools={
        "eff": PoolSnapshot(system=EFF, est_wait_s=3.0),
        "perf": PoolSnapshot(system=PERF, est_wait_s=0.0)})
    for _ in range(3):                       # repeated pricing, either path
        sched.dispatch(q, snap)
        sched.dispatch(q, None)
        sched.choose(q)
    assert {k: list(p.free_at) for k, p in sched.pools.items()} == heaps
    # observe commits exactly one booking on the committed system
    plan = sched.dispatch(q, None)
    sched.observe(q, plan)
    booked = {k: list(p.free_at) for k, p in sched.pools.items()}
    assert booked != heaps
    changed = [k for k in heaps if booked[k] != heaps[k]]
    assert changed == [plan.pool]
    # the offline path (assign/reserve) still books sequentially
    waits = [a.wait_s for a in
             CapacityAwareScheduler(CFG, [EFF, PERF],
                                    counts={EFF.name: 1, PERF.name: 1}
                                    ).assign([Query(64, 64, 0.0)] * 6)]
    assert any(w > 0 for w in waits)


def test_single_system_baseline_consistency():
    qs = [Query(10, 10), Query(1000, 200)]
    res = simulate(CFG, qs, SingleSystemScheduler(CFG, PERF))
    assert res.per_system_queries == {PERF.name: 2}
    assert res.total_energy_j == pytest.approx(
        sum(energy(CFG, q.m, q.n, PERF) for q in qs))
