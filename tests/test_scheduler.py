"""Property tests (hypothesis) for the paper's Eq. 2 partition problem and
the scheduler implementations."""
import hypothesis.strategies as st
import pytest
from hypothesis import given, settings

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostOptimalScheduler, CostParams,
                        Query, RoundRobinScheduler, SingleSystemScheduler,
                        ThresholdScheduler, cost, energy, paper_fleet, runtime,
                        simulate, tpu_fleet)

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()

queries_st = st.lists(
    st.builds(Query,
              m=st.integers(min_value=1, max_value=2048),
              n=st.integers(min_value=1, max_value=512),
              arrival_s=st.floats(min_value=0, max_value=100)),
    min_size=1, max_size=40)


@given(queries_st)
@settings(max_examples=25, deadline=None)
def test_partition_complete_and_disjoint(qs):
    """Eq. 3/4: every query assigned exactly once."""
    for sched in (ThresholdScheduler(CFG, EFF, PERF),
                  CostOptimalScheduler(CFG, [EFF, PERF]),
                  RoundRobinScheduler(CFG, [EFF, PERF])):
        assignments = sched.assign(qs)
        assert len(assignments) == len(qs)
        assert all(a.system in (EFF, PERF) for a in assignments)


@given(queries_st, st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=25, deadline=None)
def test_cost_optimal_dominates_for_its_lambda(qs, lam):
    """Per-query argmin is optimal for the uncapacitated separable objective:
    no other policy can have lower total cost at the same lambda."""
    cp = CostParams(lam=lam)
    opt = CostOptimalScheduler(CFG, [EFF, PERF], cp)
    base = ThresholdScheduler(CFG, EFF, PERF, cp=cp)

    def total(assigns):
        return sum(cp.lam * a.energy_j + (1 - cp.lam) * a.runtime_s
                   for a in assigns)
    assert total(opt.assign(qs)) <= total(base.assign(qs)) + 1e-6


@given(st.integers(min_value=1, max_value=2048),
       st.integers(min_value=1, max_value=2048))
@settings(max_examples=50, deadline=None)
def test_threshold_routing_rule(m, n):
    sched = ThresholdScheduler(CFG, EFF, PERF, t_in=32, t_out=64, axis="in")
    assert sched.choose(Query(m, n)) is (EFF if m <= 32 else PERF)
    sched_o = ThresholdScheduler(CFG, EFF, PERF, t_in=32, t_out=64, axis="out")
    assert sched_o.choose(Query(m, n)) is (EFF if n <= 64 else PERF)


@given(queries_st)
@settings(max_examples=15, deadline=None)
def test_capacity_aware_waits_nonnegative_and_bounded(qs):
    sched = CapacityAwareScheduler(CFG, [EFF, PERF],
                                   counts={EFF.name: 2, PERF.name: 1})
    assigns = sched.assign(qs)
    assert all(a.wait_s >= 0 for a in assigns)
    # with infinite-capacity behaviour disabled, waits only arise from overlap
    total_service = sum(a.runtime_s for a in assigns)
    assert all(a.wait_s <= total_service for a in assigns)


@given(st.integers(min_value=1, max_value=1024),
       st.integers(min_value=1, max_value=256))
@settings(max_examples=40, deadline=None)
def test_energy_runtime_positive_and_monotone_in_tokens(m, n):
    for s in (EFF, PERF, *tpu_fleet()):
        assert energy(CFG, m, n, s) > 0
        assert runtime(CFG, m, n, s) > 0
        assert energy(CFG, m + 64, n, s) >= energy(CFG, m, n, s)
        assert energy(CFG, m, n + 64, s) >= energy(CFG, m, n, s)
        assert runtime(CFG, m, n + 64, s) >= runtime(CFG, m, n, s)


@given(st.integers(min_value=1, max_value=512),
       st.integers(min_value=1, max_value=512),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=40, deadline=None)
def test_cost_is_convex_combination(m, n, lam):
    cp = CostParams(lam=lam)
    for s in (EFF, PERF):
        u = cost(CFG, m, n, s, cp)
        e, r = energy(CFG, m, n, s), runtime(CFG, m, n, s)
        assert min(e, r) - 1e-9 <= u <= max(e, r) + 1e-9


def test_single_system_baseline_consistency():
    qs = [Query(10, 10), Query(1000, 200)]
    res = simulate(CFG, qs, SingleSystemScheduler(CFG, PERF))
    assert res.per_system_queries == {PERF.name: 2}
    assert res.total_energy_j == pytest.approx(
        sum(energy(CFG, q.m, q.n, PERF) for q in qs))
