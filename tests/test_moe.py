"""MoE layer: dispatch correctness vs a dense loop oracle, dropless guarantee,
load-balance loss properties.

The aux-loss property test runs under hypothesis when installed; otherwise it
falls back to deterministic parametrized (seed, T) cases over the same ranges
(a hard ``import hypothesis`` previously killed tier-1 collection)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # optional dependency — guarded so collection never fails
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.configs import get_config
from repro.models import moe as MOE

KEY = jax.random.PRNGKey(11)


def dense_oracle(params, cfg, x):
    """Compute MoE output with a per-token python loop (no capacity)."""
    B, S, d = x.shape
    E, k = cfg.moe.num_experts, cfg.moe.num_experts_per_tok
    xt = np.asarray(x.reshape(-1, d), np.float64)
    logits = xt @ np.asarray(params["router"]["w"], np.float64)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    out = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[:k]
        w = probs[t, top] / probs[t, top].sum()
        for e, wi in zip(top, w):
            g = np.asarray(params["gate"][e], np.float64)
            u = np.asarray(params["up"][e], np.float64)
            dn = np.asarray(params["down"][e], np.float64)
            h = (xt[t] @ g)
            h = h / (1 + np.exp(-h)) * (xt[t] @ u)     # silu(gate) * up
            out[t] += wi * (h @ dn)
    return out.reshape(B, S, d)


def test_moe_matches_dense_oracle_dropless():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, cfg.d_model))
    y, aux = MOE.moe_apply(params, cfg, x, dropless=True)
    want = dense_oracle(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-3)


def test_dropless_capacity_never_drops():
    """With dropless=True, output is independent of batch composition."""
    cfg = get_config("grok-1-314b").reduced()
    params = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, cfg.d_model))
    y_full, _ = MOE.moe_apply(params, cfg, x, dropless=True)
    y_half, _ = MOE.moe_apply(params, cfg, x[:2], dropless=True)
    np.testing.assert_allclose(np.asarray(y_full[:2]), np.asarray(y_half),
                               atol=1e-5)


def test_capacity_drops_zero_not_garbage():
    """Tokens over capacity contribute zero output (never wrong values)."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=0.25))   # force drops
    params = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    y, _ = MOE.moe_apply(params, cfg, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens -> exactly zero rows are plausible; all-finite is the bar
    y_free, _ = MOE.moe_apply(params, cfg, x, dropless=True)
    # dropping can only remove contributions, not add
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_free)) * 1.5


def check_aux_loss_bounds(seed, T):
    """Switch aux loss: >= coef (perfect balance) and <= coef * E."""
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = MOE.moe_init(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 100), (1, T, cfg.d_model))
    _, aux = MOE.moe_apply(params, cfg, x)
    E = cfg.moe.num_experts
    coef = cfg.moe.router_aux_loss_coef
    assert 0.0 < float(aux) <= coef * E + 1e-6


if HAVE_HYPOTHESIS:
    @given(st.integers(min_value=1, max_value=6),
           st.integers(min_value=2, max_value=16))
    @settings(max_examples=10, deadline=None)
    def test_aux_loss_bounds(seed, T):
        check_aux_loss_bounds(seed, T)
else:
    @pytest.mark.parametrize("seed,T", [(1, 2), (2, 5), (3, 8), (4, 11),
                                        (5, 16), (6, 3)])
    def test_aux_loss_bounds(seed, T):
        check_aux_loss_bounds(seed, T)


def test_router_gradients_flow():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = MOE.moe_apply(p, cfg, x, dropless=True)
        return jnp.sum(y ** 2) + aux
    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]["w"]).max()) > 0
    assert float(jnp.abs(g["up"]).max()) > 0
