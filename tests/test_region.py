"""Region-lifted control plane: flattening, the global dispatcher's
route-vs-defer decisions, deferred admission through both fleet engines, and
idle-inclusive accounting across regions."""
import pytest

from repro.configs import get_config
from repro.core import (GlobalDispatcher, PoolSpec, PriceProfile, Query,
                        Region, SingleSystemScheduler, WorkloadSpec,
                        flatten_regions, sample_workload, simulate_fleet)
from repro.core.carbon import CarbonProfile
from repro.core.plan import DeferPlan, RunPlan
from repro.core.systems import get_profile

CFG = get_config("qwen2.5-3b")
EFF, PERF = get_profile("tpu-v5lite-eff"), get_profile("tpu-v5e-perf")


def _regions():
    # us-west troughs at solar midday; eu-north is cleaner on average and
    # troughs overnight — an 18:00 arrival sees both off-trough
    west = Region("us-west", {"eff": PoolSpec(EFF, instances=2, slots=4)},
                  carbon=CarbonProfile(mean_g_per_kwh=300.0,
                                       trough_hour=13.0))
    east = Region("eu-north", {"perf": PoolSpec(PERF, instances=2, slots=4)},
                  carbon=CarbonProfile(mean_g_per_kwh=120.0,
                                       trough_hour=2.0))
    return west, east


# ----------------------------------------------------------------- flattening
def test_flatten_regions_namespaces_pools_and_systems():
    west, east = _regions()
    flat = flatten_regions([west, east])
    assert set(flat) == {"us-west/eff", "eu-north/perf"}
    assert flat["us-west/eff"].system.name == "us-west/tpu-v5lite-eff"
    assert flat["eu-north/perf"].system.name == "eu-north/tpu-v5e-perf"
    # the embedded spec is otherwise untouched
    assert flat["us-west/eff"].instances == 2
    with pytest.raises(ValueError, match="duplicate region"):
        flatten_regions([west, west])


def test_simulate_fleet_takes_pools_xor_regions():
    west, east = _regions()
    qs = [Query(16, 16, 0.0)]
    sched = GlobalDispatcher(CFG, [west, east])
    with pytest.raises(ValueError, match="exactly one"):
        simulate_fleet(CFG, qs, flatten_regions([west, east]), sched,
                       regions=[west, east])
    with pytest.raises(ValueError, match="exactly one"):
        simulate_fleet(CFG, qs, scheduler=sched)
    with pytest.raises(TypeError, match="requires a scheduler"):
        simulate_fleet(CFG, qs, regions=[west, east])


# ------------------------------------------------------------ dispatch policy
def test_interactive_routes_now_batch_defers_to_green_window():
    west, east = _regions()
    sched = GlobalDispatcher(CFG, [west, east])
    t0 = 18 * 3600.0                      # both regions off their troughs
    chat = sched.dispatch(Query(64, 16, t0), None)
    assert isinstance(chat, RunPlan)
    batch = sched.dispatch(Query(256, 512, t0), None)
    assert isinstance(batch, DeferPlan)
    assert batch.until_s > t0
    # the deferred clock is inside the chosen region's green window
    reg = sched._region_of[batch.inner.pool]
    assert reg.carbon.intensity(batch.until_s) <= \
        reg.carbon.mean_g_per_kwh * sched.defer_below
    # terms carry the deferral as priced wait
    assert batch.terms.wait_s == pytest.approx(batch.until_s - t0)


def test_price_weight_flips_the_spatial_choice():
    west, east = _regions()
    west_pricey = Region(west.name, west.pools, carbon=west.carbon,
                         price=PriceProfile(mean_usd_per_kwh=1e6))
    neutral = GlobalDispatcher(CFG, [west, east])
    weighted = GlobalDispatcher(CFG, [west_pricey, east], price_weight=1.0)
    q = Query(64, 16, 2 * 3600.0)
    # carbon-only: the efficient hardware in us-west wins
    assert neutral.dispatch(q, None).pool.startswith("us-west/")
    # an absurd electricity price there flips the interactive choice
    assert weighted.dispatch(q, None).pool.startswith("eu-north/")


# ----------------------------------------------------- engines + accounting
def test_defer_plans_hold_admission_in_both_engines_identically():
    west, east = _regions()
    t0 = 18 * 3600.0
    qs = sorted([Query(256, 512, t0), Query(64, 16, t0 + 1.0),
                 Query(200, 400, t0 + 2.0)], key=lambda q: q.arrival_s)
    runs = {}
    for engine in ("event", "vectorized"):
        runs[engine] = simulate_fleet(
            CFG, qs, regions=[west, east],
            scheduler=GlobalDispatcher(CFG, [west, east]), engine=engine)
    se, sv = runs["event"].summary(), runs["vectorized"].summary()
    assert se == sv, {k: (se[k], sv[k]) for k in se if se[k] != sv[k]}
    te = [(x.rid, x.pool, x.t_arrival, x.t_start, x.t_done, x.energy_j)
          for x in runs["event"].records]
    tv = [(x.rid, x.pool, x.t_arrival, x.t_start, x.t_done, x.energy_j)
          for x in runs["vectorized"].records]
    assert te == tv
    recs = sorted(runs["event"].records, key=lambda x: x.rid)
    # batch tiers deferred (hours), interactive admitted on arrival
    assert recs[0].t_start - recs[0].t_arrival > 3600.0
    assert recs[2].t_start - recs[2].t_arrival > 3600.0
    assert recs[1].t_start == recs[1].t_arrival
    assert recs[0].wait_s > 3600.0        # deferral IS wait (idle-inclusive)


def test_fleet_accounting_stays_idle_inclusive_across_defer():
    """While a deferred batch waits, every region's pools keep burning their
    idle floor: fleet energy must cover the full horizon, not just busy
    time."""
    west, east = _regions()
    t0 = 18 * 3600.0
    qs = [Query(256, 512, t0)]
    r = simulate_fleet(CFG, qs, regions=[west, east],
                       scheduler=GlobalDispatcher(CFG, [west, east]))
    rec = r.records[0]
    assert rec.t_start > rec.t_arrival + 3600.0
    # the gap between fleet (idle-inclusive) and per-request energy is the
    # idle floor burned across the deferral window
    assert r.fleet_energy_j > r.total_energy_j
    assert r.horizon_s - t0 >= rec.t_done - rec.t_arrival


def test_regions_with_plain_scheduler_still_work():
    """The region grouping is orthogonal to the policy: a single-system
    scheduler over the flattened fleet runs fine."""
    west, east = _regions()
    flat_perf = flatten_regions([west, east])["eu-north/perf"].system
    qs = sample_workload(20, seed=1, spec=WorkloadSpec(rate_qps=2.0))
    r = simulate_fleet(CFG, qs, regions=[west, east],
                       scheduler=SingleSystemScheduler(CFG, flat_perf))
    assert all(rec.pool == "eu-north/perf" for rec in r.records)
