"""Tests for repro.analysis: the lint corpus (true/false positives per rule),
baseline round-trip, suppression handling, and regression pins for the
defects the analyzer surfaced in the serving/core code."""
import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import analyze_paths, analyze_source
from repro.analysis.baseline import (filter_findings, load_baseline,
                                     save_baseline)
from repro.analysis.findings import Finding, WARNING
from repro.configs import get_config
from repro.core.simulator import HeadlineResult
from repro.core.systems import TPU_V5E_PERF
from repro.models import model as M
from repro.serving.batching import (ContinuousBatcher,
                                    PagedContinuousBatcher, Request)
from repro.serving.engine import InferenceEngine

KEY = jax.random.PRNGKey(11)


def lint(snippet):
    return analyze_source(textwrap.dedent(snippet), path="snippet.py")


def rules_of(findings):
    return {f.rule for f in findings}


# =========================================================== units: positives
def test_units_flags_energy_plus_power():
    fs = lint("""
        def total(e_j, p_w):
            return e_j + p_w
    """)
    assert "unit-add" in rules_of(fs)


def test_units_flags_power_times_time_bound_to_power_name():
    fs = lint("""
        def draw(p_w, dt_s):
            total_w = p_w * dt_s
            return total_w
    """)
    assert "unit-assign" in rules_of(fs)


def test_units_flags_seconds_returned_from_energy_function():
    fs = lint("""
        def overhead_j(t_s, p_w):
            return t_s
    """)
    assert "unit-return" in rules_of(fs)


def test_units_flags_per_token_division_without_suffix():
    fs = lint("""
        def report(e_j, tokens):
            jpt = e_j / tokens
            return jpt
    """)
    assert "unit-derived-name" in rules_of(fs)


def test_units_flags_suffixless_quantity_field():
    fs = lint("""
        from dataclasses import dataclass

        @dataclass
        class Result:
            energy: float
            runtime: float
    """)
    assert "unit-field" in rules_of(fs)
    assert sum(f.rule == "unit-field" for f in fs) == 2


# =========================================================== units: negatives
def test_units_accepts_consistent_energy_accounting():
    fs = lint("""
        def account(t_prefill_s, t_decode_s, p_peak_w, p_idle_w):
            e_j = t_prefill_s * p_peak_w
            e_j += t_decode_s * p_idle_w
            return e_j
    """)
    assert fs == []


def test_units_accepts_normalized_objective():
    # the paper's Eq. 1: adding *normalized* energy and runtime is fine
    fs = lint("""
        def cost(e_j, r_s, e_norm, r_norm, lam):
            return lam * e_j / e_norm + (1.0 - lam) * r_s / r_norm
    """)
    assert fs == []


def test_units_accepts_per_token_names_and_counts():
    fs = lint("""
        def summarize(e_j, t_s, n_tokens):
            e_per_token = e_j / n_tokens
            tok_per_s = n_tokens / t_s
            return e_per_token, tok_per_s
    """)
    assert fs == []


def test_units_accepts_suffixed_fields_and_fractions():
    fs = lint("""
        from dataclasses import dataclass

        @dataclass
        class Result:
            energy_j: float
            runtime_s: float
            savings_frac: float
            n_queries: int
            name: str
    """)
    assert fs == []


# ==================================================== jax-hot-path: positives
def test_jax_flags_item_inside_jit():
    fs = lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.item()
    """)
    assert "jax-host-sync" in rules_of(fs)


def test_jax_flags_float_on_traced_in_batcher_loop():
    fs = lint("""
        import jax.numpy as jnp

        class MicroBatcher:
            def step(self):
                for i in range(4):
                    y = jnp.sum(self.cache[i])
                    self.totals.append(float(y))
    """)
    assert "jax-host-sync" in rules_of(fs)


def test_jax_flags_python_branch_on_traced_value():
    fs = lint("""
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
    """)
    assert "jax-traced-branch" in rules_of(fs)


def test_jax_flags_numpy_fallback_inside_jit():
    fs = lint("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x) * 2
    """)
    assert "jax-recompile" in rules_of(fs)


# ==================================================== jax-hot-path: negatives
def test_jax_accepts_branch_on_static_argname():
    fs = lint("""
        import functools
        import jax
        import jax.numpy as jnp

        @functools.partial(jax.jit, static_argnames=("causal",))
        def attend(q, causal):
            if causal:
                return jnp.tril(q)
            return q
    """)
    assert fs == []


def test_jax_accepts_shape_access_and_host_arrays():
    fs = lint("""
        import jax
        import jax.numpy as jnp
        import numpy as np

        @jax.jit
        def f(x):
            b = x.shape[0]
            return x * b

        def host_side(tokens):
            buf = np.asarray(tokens)
            return int(buf[0])
    """)
    assert fs == []


def test_jax_accepts_device_resident_tick():
    # the PR-3 contract: keep values on device through the tick
    fs = lint("""
        import jax.numpy as jnp

        class MicroBatcher:
            def step(self):
                logits, self.cache = self.engine.decode(self.last, self.cache)
                self.last = jnp.argmax(logits, axis=-1)
    """)
    assert fs == []


# ================================================= scheduler-purity: positives
def test_purity_flags_write_in_choose():
    fs = lint("""
        class GreedyScheduler:
            def choose(self, q):
                self.count += 1
                return "eff"
    """)
    assert "scheduler-purity" in rules_of(fs)


def test_purity_flags_mutating_call_in_dispatch():
    fs = lint("""
        class QueueScheduler:
            def dispatch(self, q):
                self.pending.append(q)
                return "perf"
    """)
    assert "scheduler-purity" in rules_of(fs)


def test_purity_flags_mutation_via_helper():
    fs = lint("""
        class SneakyScheduler:
            def choose(self, q):
                return self._pick(q)

            def _pick(self, q):
                self.memo[q.m] = "eff"
                return self.memo[q.m]
    """)
    assert "scheduler-purity" in rules_of(fs)


def test_purity_flags_write_in_dispatch_rid():
    fs = lint("""
        class TableScheduler:
            def dispatch_rid(self, rid, q, fleet):
                self.last_rid = rid
                return "perf"
    """)
    assert "scheduler-purity" in rules_of(fs)


def test_purity_flags_mutation_via_plan_helper():
    # dispatch -> plan-constructing helper -> mutation: the trace must
    # follow the helper chain
    fs = lint("""
        class PlanScheduler:
            def dispatch(self, q, fleet=None):
                s = self.choose(q)
                return self._as_plan(q, s)

            def choose(self, q):
                return self.systems[0]

            def _as_plan(self, q, s):
                return self._price(q, s)

            def _price(self, q, s):
                self.priced += 1
                return (s.name, self.priced)
    """)
    assert "scheduler-purity" in rules_of(fs)


# ================================================= scheduler-purity: negatives
def test_purity_accepts_observe_commit():
    fs = lint("""
        class FairScheduler:
            def choose(self, q):
                return "eff" if q.m < self.t_in else "perf"

            def observe(self, q, name):
                self.history.append((q, name))
    """)
    assert fs == []


def test_purity_accepts_observe_rid_commit():
    fs = lint("""
        class TableScheduler:
            def dispatch_rid(self, rid, q, fleet):
                return self._score(rid)

            def _score(self, rid):
                return self.table[rid]

            def observe_rid(self, rid, q, placed):
                self.free_at[placed] = self.table[rid]
    """)
    assert fs == []


def test_purity_accepts_local_state_in_choose():
    fs = lint("""
        class RankScheduler:
            def choose(self, q, snapshots):
                best = None
                for name, snap in snapshots.items():
                    if best is None or snap.free_blocks > best[1]:
                        best = (name, snap.free_blocks)
                return best[0]
    """)
    assert fs == []


def test_purity_ignores_non_scheduler_classes():
    fs = lint("""
        class Accumulator:
            def choose(self, q):
                self.count += 1
                return self.count
    """)
    assert fs == []


# ================================================== suppression and baseline
def test_inline_suppression_is_honored():
    noisy = """
        def total(e_j, p_w):
            return e_j + p_w
    """
    assert rules_of(lint(noisy)) == {"unit-add"}
    fs = lint("""
        def total(e_j, p_w):
            return e_j + p_w  # repro-lint: allow[unit-add]
    """)
    assert fs == []
    # comment-above form, and allow[*]
    fs = lint("""
        def total(e_j, p_w):
            # repro-lint: allow[*]
            return e_j + p_w
    """)
    assert fs == []


def test_suppression_of_other_rule_does_not_mask():
    fs = lint("""
        def total(e_j, p_w):
            return e_j + p_w  # repro-lint: allow[jax-host-sync]
    """)
    assert "unit-add" in rules_of(fs)


def test_baseline_round_trip_and_filtering(tmp_path):
    f1 = Finding(path="a.py", line=3, col=0, rule="unit-add",
                 severity=WARNING, message="mixes J and W")
    f2 = Finding(path="b.py", line=9, col=4, rule="jax-host-sync",
                 severity=WARNING, message="int() on a traced value")
    bl = tmp_path / "baseline.json"
    save_baseline(str(bl), [f1, f2])
    keys = load_baseline(str(bl))
    assert set(keys) == {f1.key(), f2.key()}

    # same finding on a different line still matches (location-insensitive)
    moved = Finding(path="a.py", line=30, col=2, rule="unit-add",
                    severity=WARNING, message="mixes J and W")
    fresh = Finding(path="a.py", line=5, col=0, rule="unit-add",
                    severity=WARNING, message="mixes W and s")
    res = filter_findings([moved, fresh], keys)
    assert res.new == [fresh]
    assert res.matched == [moved]
    assert [k for k in res.stale] == [f2.key()]

    # the committed baseline is empty and version-tagged
    committed = json.load(open("src/repro/analysis/baseline.json"))
    assert committed["findings"] == []
    assert load_baseline("src/repro/analysis/baseline.json") == []


def test_analyzer_clean_over_shipped_sources():
    """The merge gate: no unsuppressed findings in the serving/core trees
    (also pins the host-sync defects fixed in this change — reintroducing a
    per-lane ``int(jnp.argmax(...))`` in batching.py fails here)."""
    assert analyze_paths(["src/repro/serving", "src/repro/analysis"]) == []


# ======================================================= regression: defects
@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    return InferenceEngine(cfg, params, max_len=96)


class _TransferCounter:
    """Counts device->host conversions routed through np.asarray and records
    the element count of each transferred device array."""

    def __init__(self, monkeypatch):
        self.calls = []
        orig = np.asarray

        def counting(a, *args, **kwargs):
            if isinstance(a, jax.Array):
                self.calls.append(int(a.size))
            return orig(a, *args, **kwargs)

        monkeypatch.setattr(np, "asarray", counting)


def test_paged_prefill_completion_single_batched_sync(engine, monkeypatch):
    """Two lanes finishing prefill in one tick must cost ONE device->host
    transfer of exactly the two first tokens — not a per-lane blocking
    ``int()`` plus a full-width ``_last_tok`` round trip through the host."""
    b = PagedContinuousBatcher(engine, slots=4, num_blocks=32, block_size=8,
                               chunk=32, prefix_sharing=False)
    cfg = engine.cfg
    prompts = [np.arange(5) % cfg.vocab_size, np.arange(9) % cfg.vocab_size]
    for i, p in enumerate(prompts):
        b.submit(Request(i, p, max_new_tokens=4))
    b._admit()
    counter = _TransferCounter(monkeypatch)
    b._prefill_tick()                      # both prompts fit in one chunk
    assert counter.calls == [2]            # one sync, two tokens
    assert isinstance(b._last_tok, jax.Array)   # no host round trip
    for i in range(2):
        assert len(b.active[i].out_tokens) == 1


def test_dense_admission_single_batched_sync(engine, monkeypatch):
    """Admitting two requests in one ``_fill_slots`` pass must cost ONE
    device->host transfer, not one blocking ``int()`` per admission."""
    b = ContinuousBatcher(engine, slots=4)
    cfg = engine.cfg
    for i in range(2):
        b.submit(Request(i, np.arange(4 + i) % cfg.vocab_size,
                         max_new_tokens=4))
    counter = _TransferCounter(monkeypatch)
    b._fill_slots()
    assert counter.calls == [2]
    for i in range(2):
        assert len(b.active[i].out_tokens) == 1


def test_batched_sync_rewrite_preserves_tokens(engine):
    """The sync-batching rewrite must not change emitted tokens: paged
    batcher with several lanes completing prefill on the same tick still
    matches the solo greedy path."""
    cfg = engine.cfg
    prompts = [np.arange(4 + 3 * i) % cfg.vocab_size for i in range(3)]
    b = PagedContinuousBatcher(engine, slots=3, num_blocks=48, block_size=8,
                               chunk=32, prefix_sharing=False)
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    for req in reqs:
        b.submit(req)
    b.run()
    for req, prompt in zip(reqs, prompts):
        ref = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]},
                              max_new_tokens=5)
        assert req.out_tokens == list(np.asarray(ref.tokens)[0])


# ============================================= regression: removed aliases
def test_system_profile_power_aliases_removed():
    """The PR-6 one-release DeprecationWarning aliases are gone: the
    unit-suffixed fields are the only spelling."""
    with pytest.raises(AttributeError):
        TPU_V5E_PERF.power_peak
    with pytest.raises(AttributeError):
        TPU_V5E_PERF.power_idle
    assert TPU_V5E_PERF.power_peak_w == 170.0
    assert TPU_V5E_PERF.power_idle_w == 55.0


def test_headline_result_penalty_alias_removed():
    hd = HeadlineResult(hybrid=None, baselines={}, best_baseline="all_perf",
                        savings_vs_best_baseline=0.075,
                        savings_vs_all_perf=0.075,
                        runtime_penalty_frac_vs_all_perf=0.05)
    with pytest.raises(AttributeError):
        hd.runtime_penalty_vs_all_perf
    assert hd.runtime_penalty_frac_vs_all_perf == 0.05
