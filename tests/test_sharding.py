"""Sharding spec rules: divisibility fitting, param coverage, cache modes.
Runs on a 1x1 CPU mesh (rules are mesh-size-parametric; the 16x16 behaviour
is exercised by the dry-run sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.launch.mesh import make_debug_mesh
from repro.models import model as M
from repro.sharding import specs as SH


class FakeMesh:
    """Mesh stub with arbitrary axis sizes for rule testing (no devices)."""
    def __init__(self, **axes):
        self.shape = axes


def test_fit_drops_nondivisible():
    mesh = FakeMesh(pod=2, data=16, model=16)
    assert SH._fit(mesh, (32, 64), P("data", "model")) == P("data", "model")
    assert SH._fit(mesh, (30, 64), P("data", "model")) == P(None, "model")
    assert SH._fit(mesh, (32, 65), P("data", "model")) == P("data", None)
    assert SH._fit(mesh, (5,), P(("pod", "data"))) == P(None)


def test_fit_multi_axis_product():
    mesh = FakeMesh(pod=2, data=16)
    assert SH._fit(mesh, (64,), P(("pod", "data"))) == P(("pod", "data"))
    assert SH._fit(mesh, (16,), P(("pod", "data"))) == P(None)


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_cover_all_leaves(arch):
    """Every param leaf gets a spec whose ndim matches, and on a 16x16 mesh
    every sharded dim divides."""
    cfg = get_config(arch)
    import functools
    abstract = jax.eval_shape(
        functools.partial(M.init_params, cfg, dtype=jnp.bfloat16,
                          max_positions=cfg.max_seq_len if cfg.family == "audio" else None),
        jax.random.PRNGKey(0))
    mesh = FakeMesh(data=16, model=16)
    flat, _ = jax.tree_util.tree_flatten_with_path(abstract)
    n_sharded = 0
    for path, leaf in flat:
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        spec = SH.param_spec_from_path("/".join(keys), leaf.shape)
        fitted = SH._fit(mesh, leaf.shape, spec)
        assert len(tuple(fitted)) <= len(leaf.shape)
        for dim, ax in zip(leaf.shape, tuple(fitted)):
            if ax is not None:
                n_sharded += 1
                assert dim % 16 == 0, (keys, leaf.shape, fitted)
    # the model must actually be tensor-parallel: layer params are STACKED
    # (one leaf per weight type), so >=3 sharded leaf-dims means the core
    # matmul weights all shard
    assert n_sharded >= 3, arch


def test_cache_shardings_modes():
    cfg = get_config("qwen2.5-3b")      # kv=2: heads don't divide 16
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 128, 1024, jnp.bfloat16))
    mesh = FakeMesh(data=16, model=16)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    # emulate cache_shardings logic without NamedSharding (no real mesh here)
    for path, leaf in flat:
        name = str(getattr(path[-1], "key", ""))
        if name in ("k", "v"):
            assert leaf.shape[2] == 2   # kv heads
            # heads dim not divisible -> rule must pick seq-on-model
            assert leaf.shape[3] % 16 == 0


def test_cache_shardings_real_mesh():
    """On a real (1,1) mesh the NamedSharding tree builds for every family."""
    mesh = make_debug_mesh(1, 1)
    for arch in ("qwen2.5-3b", "mamba2-130m", "zamba2-1.2b", "whisper-base"):
        cfg = get_config(arch)
        cache = jax.eval_shape(
            lambda c=cfg: M.init_cache(c, 8, 64, jnp.float32,
                                       enc_len=c.encoder_seq_len or None))
        sh = SH.cache_shardings(mesh, cache)
        assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(cache)


def test_params_shardings_real_mesh():
    mesh = make_debug_mesh(1, 1)
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sh = SH.params_shardings(mesh, params)
    assert jax.tree_util.tree_structure(sh) == jax.tree_util.tree_structure(params)
