"""Serving integration: engine generation, continuous batching equivalence,
fleet routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.systems import paper_fleet, tpu_fleet
from repro.models import model as M
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    return InferenceEngine(cfg, params, max_len=96)


def test_generate_deterministic(engine):
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    a = engine.generate(batch, 6).tokens
    b = engine.generate(batch, 6).tokens
    np.testing.assert_array_equal(a, b)


def test_generate_batch_consistency(engine):
    """Each row of a batched generate equals its solo generate."""
    p1 = jnp.arange(8, dtype=jnp.int32)
    p2 = (jnp.arange(8, dtype=jnp.int32) * 3) % engine.cfg.vocab_size
    both = engine.generate({"tokens": jnp.stack([p1, p2])}, 5).tokens
    solo1 = engine.generate({"tokens": p1[None]}, 5).tokens
    solo2 = engine.generate({"tokens": p2[None]}, 5).tokens
    np.testing.assert_array_equal(both[0], solo1[0])
    np.testing.assert_array_equal(both[1], solo2[0])


def test_continuous_batching_matches_solo(engine):
    prompts = [np.arange(4 + i) % engine.cfg.vocab_size for i in range(5)]
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    cb = ContinuousBatcher(engine, slots=2)
    for r in reqs:
        cb.submit(r)
    cb.run()
    for r, p in zip(reqs, prompts):
        assert r.done
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 6)
        np.testing.assert_array_equal(np.asarray(r.out_tokens[:6]), solo.tokens[0])


def test_router_threshold_split(engine):
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"perf": engine, "eff": engine}, policy="threshold",
                         t_in=32)
    small = router.submit(np.arange(8), 4)
    large = router.submit(np.arange(64), 4)
    assert small.pool == "eff" and large.pool == "perf"
    assert small.energy_j > 0 and large.energy_j > 0
    rep = router.fleet_report()
    assert rep["eff"]["queries"] == 1 and rep["perf"]["queries"] == 1


def test_router_cost_optimal_prefers_cheaper_system(engine):
    eff, perf = tpu_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         policy="cost_optimal", lam=1.0)
    # tiny query: efficiency pool must win on energy
    assert router.route(4, 4) == "eff"


def test_router_capacity_aware_spills(engine):
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         policy="capacity_aware", lam=0.0,
                         counts={"m1-pro": 1, "swing-a100": 1})
    # lam=0 -> pure latency: a burst deep enough that the perf pool's queue
    # exceeds the eff pool's service time must spill to the eff pool
    pools = {router.route(8, 8, arrival_s=0.0) for _ in range(64)}
    assert len(pools) == 2
