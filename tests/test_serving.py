"""Serving integration: engine generation, continuous batching equivalence,
fleet routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.systems import paper_fleet, tpu_fleet
from repro.models import model as M
from repro.serving.batching import ContinuousBatcher, Request
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    return InferenceEngine(cfg, params, max_len=96)


def test_generate_deterministic(engine):
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    a = engine.generate(batch, 6).tokens
    b = engine.generate(batch, 6).tokens
    np.testing.assert_array_equal(a, b)


def test_generate_batch_consistency(engine):
    """Each row of a batched generate equals its solo generate."""
    p1 = jnp.arange(8, dtype=jnp.int32)
    p2 = (jnp.arange(8, dtype=jnp.int32) * 3) % engine.cfg.vocab_size
    both = engine.generate({"tokens": jnp.stack([p1, p2])}, 5).tokens
    solo1 = engine.generate({"tokens": p1[None]}, 5).tokens
    solo2 = engine.generate({"tokens": p2[None]}, 5).tokens
    np.testing.assert_array_equal(both[0], solo1[0])
    np.testing.assert_array_equal(both[1], solo2[0])


def test_continuous_batching_matches_solo(engine):
    prompts = [np.arange(4 + i) % engine.cfg.vocab_size for i in range(5)]
    reqs = [Request(i, p, max_new_tokens=6) for i, p in enumerate(prompts)]
    cb = ContinuousBatcher(engine, slots=2)
    for r in reqs:
        cb.submit(r)
    cb.run()
    for r, p in zip(reqs, prompts):
        assert r.done
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 6)
        np.testing.assert_array_equal(np.asarray(r.out_tokens[:6]), solo.tokens[0])


def test_continuous_batching_single_slot_matches_solo(engine):
    """Regression for the _splice_lane shape heuristic: with slots=1 the old
    ``v.shape[0] == lv.shape[0]`` test misclassified batch-leading cache
    tensors and corrupted the spliced lane."""
    prompts = [np.arange(5 + 2 * i) % engine.cfg.vocab_size for i in range(3)]
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    cb = ContinuousBatcher(engine, slots=1)
    for r in reqs:
        cb.submit(r)
    cb.run()
    for r, p in zip(reqs, prompts):
        assert r.done
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 5)
        np.testing.assert_array_equal(np.asarray(r.out_tokens[:5]), solo.tokens[0])


def test_splice_lane_batch_leading_tensor_at_single_slot():
    """Unit regression: a 2-D batch-leading cache entry spliced at slots=1
    must receive the lane's row, not a layer-axis write."""
    from repro.serving.batching import _splice_lane
    cache = {"pos": jnp.zeros((1,), jnp.int32),
             "k": jnp.zeros((3, 1, 2, 4, 5)),          # layer-leading
             "last_tok": jnp.zeros((1, 7), jnp.int32)}  # batch-leading 2-D
    lane = {"pos": jnp.array([9], jnp.int32),
            "k": jnp.ones((3, 1, 2, 4, 5)),
            "last_tok": jnp.full((1, 7), 5, jnp.int32)}
    import repro.serving.batching as B
    old = B._BATCH_LEADING_KEYS
    B._BATCH_LEADING_KEYS = old | {"last_tok"}
    try:
        out = _splice_lane(cache, lane, 0)
    finally:
        B._BATCH_LEADING_KEYS = old
    assert int(out["pos"][0]) == 9
    np.testing.assert_array_equal(np.asarray(out["k"]), np.ones((3, 1, 2, 4, 5)))
    np.testing.assert_array_equal(np.asarray(out["last_tok"][0]), np.full(7, 5))


def test_continuous_batching_kv_quant_lane_ops():
    """_splice_lane/_clear_lane must carry the int8 cache's scale tensors:
    batched generation over a kv_quant cache matches the solo quant engine."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    qeng = InferenceEngine(cfg, params, max_len=96, kv_quant=True)
    assert qeng.new_cache(2)["k"].dtype.name == "int8"
    prompts = [np.arange(5 + 2 * i) % cfg.vocab_size for i in range(3)]
    reqs = [Request(i, p, max_new_tokens=5) for i, p in enumerate(prompts)]
    cb = ContinuousBatcher(qeng, slots=2)
    for r in reqs:
        cb.submit(r)
    cb.run()
    for r, p in zip(reqs, prompts):
        assert r.done
        solo = qeng.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 5)
        np.testing.assert_array_equal(np.asarray(r.out_tokens[:5]),
                                      solo.tokens[0])


def test_continuous_batching_hybrid_family_lane_ops():
    """Hybrid cache family (ak/av shared-attention KV + conv/SSM state):
    splice/clear must handle every tensor, slots=1 included."""
    cfg = get_config("zamba2-1.2b").reduced()
    params = M.init_params(cfg, KEY)
    eng = InferenceEngine(cfg, params, max_len=96)
    cache = eng.new_cache(1)
    assert "ak" in cache and "ssm" in cache   # the families under test
    prompts = [np.arange(6 + 3 * i) % cfg.vocab_size for i in range(3)]
    for slots in (1, 2):
        reqs = [Request(i, p, max_new_tokens=4) for i, p in enumerate(prompts)]
        cb = ContinuousBatcher(eng, slots=slots)
        for r in reqs:
            cb.submit(r)
        cb.run()
        for r, p in zip(reqs, prompts):
            assert r.done
            solo = eng.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 4)
            np.testing.assert_array_equal(np.asarray(r.out_tokens[:4]),
                                          solo.tokens[0])


def test_batcher_eos_terminates_early(engine):
    """EOS-aware completion: find the token the model actually emits first,
    declare it EOS, and check the request retires before max_new_tokens."""
    prompt = np.arange(8) % engine.cfg.vocab_size
    free = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 8)
    eos = int(free.tokens[0][2])          # token emitted at step 2
    req = Request(0, prompt, max_new_tokens=8, eos_id=eos)
    cb = ContinuousBatcher(engine, slots=2)
    cb.submit(req)
    cb.run()
    assert req.done
    assert len(req.out_tokens) <= 3       # stopped at the eos emission
    assert req.out_tokens[-1] == eos


def test_engine_sampled_generation_default_key(engine):
    """temperature>0 with key=None must not crash (seeded default key) and
    must be reproducible."""
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None]}
    a = engine.generate(batch, 5, temperature=0.8).tokens
    b = engine.generate(batch, 5, temperature=0.8).tokens
    np.testing.assert_array_equal(a, b)
    c = engine.generate(batch, 5, temperature=0.8,
                        key=jax.random.PRNGKey(123)).tokens
    assert a.shape == c.shape


def test_router_threshold_split(engine):
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"perf": engine, "eff": engine}, policy="threshold",
                         t_in=32)
    small = router.submit(np.arange(8), 4)
    large = router.submit(np.arange(64), 4)
    assert small.pool == "eff" and large.pool == "perf"
    assert small.energy_j > 0 and large.energy_j > 0
    rep = router.fleet_report()
    assert rep["eff"]["queries"] == 1 and rep["perf"]["queries"] == 1


def test_router_cost_optimal_prefers_cheaper_system(engine):
    eff, perf = tpu_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         policy="cost_optimal", lam=1.0)
    # tiny query: efficiency pool must win on energy
    assert router.route(4, 4) == "eff"


def test_router_batcher_backend_executes_and_reports(engine):
    """Routed execution through per-pool ContinuousBatchers: submit queues,
    drain() runs the decode loops, outputs match the solo engine."""
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    router.attach_batchers(slots=2)
    prompts = [np.arange(6) % engine.cfg.vocab_size,
               np.arange(64) % engine.cfg.vocab_size]
    routed = [router.submit(p, 4) for p in prompts]
    assert routed[0].pool == "eff" and routed[1].pool == "perf"
    assert all(rr.request is not None and not rr.request.done for rr in routed)
    router.drain()
    for rr, p in zip(routed, prompts):
        assert rr.request.done
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 4)
        np.testing.assert_array_equal(np.asarray(rr.request.out_tokens[:4]),
                                      solo.tokens[0])


def test_router_paged_batcher_backend(engine):
    """attach_batchers(paged=True): routed execution through the paged
    runtime matches solo generation, and the fleet snapshot exposes block
    occupancy to schedulers."""
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    router.attach_batchers(slots=2, paged=True, num_blocks=48, block_size=8,
                           chunk=8)
    prompts = [np.arange(6) % engine.cfg.vocab_size,
               np.arange(64) % engine.cfg.vocab_size]
    routed = [router.submit(p, 4) for p in prompts]
    router.batchers["eff"].step()                    # admit the small request
    snap = router._fleet_state().pools["eff"]
    assert snap.total_blocks == 47 and snap.block_size == 8
    assert snap.free_blocks < snap.total_blocks      # admission took blocks
    router.drain()
    for rr, p in zip(routed, prompts):
        assert rr.request.done
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 4)
        np.testing.assert_array_equal(np.asarray(rr.request.out_tokens[:4]),
                                      solo.tokens[0])


def test_router_accounting_reconciles_eos_engine_path(engine):
    """Satellite: energy/runtime booked at expected_n must be corrected to
    the actually emitted token count when EOS retires a request early."""
    eff, perf = paper_fleet()
    prompt = np.arange(8) % engine.cfg.vocab_size
    free = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 8)
    eos = int(free.tokens[0][2])          # emitted at step 2 -> stops early
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    rr = router.submit(prompt, 8, eos_id=eos)
    st = router.fleet_report()[rr.pool]
    assert st["expected_tokens"] == len(prompt) + 8
    assert st["tokens"] < st["expected_tokens"]
    assert st["energy_j"] < st["expected_energy_j"]
    assert st["runtime_s"] < st["expected_runtime_s"]


def test_router_accounting_reconciles_eos_batcher_path(engine):
    eff, perf = paper_fleet()
    prompt = np.arange(8) % engine.cfg.vocab_size
    free = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 8)
    eos = int(free.tokens[0][2])
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    router.attach_batchers(slots=2)
    router.submit(prompt, 8, eos_id=eos)
    before = dict(router.fleet_report()["eff"])
    router.drain()
    after = router.fleet_report()["eff"]
    assert before["energy_j"] == before["expected_energy_j"]  # pre-drain
    assert after["energy_j"] < after["expected_energy_j"]     # reconciled
    assert after["tokens"] < after["expected_tokens"]


def test_router_est_wait_sees_active_residents(engine):
    """Satellite: est_wait must include the residual decode of active lanes,
    not only queued requests — a pool mid-request with an empty queue is not
    free."""
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine}, policy="threshold",
                         t_in=32)
    router.attach_batchers(slots=2)
    idle = router._fleet_state().pools["eff"].est_wait_s
    assert idle == 0.0
    router.submit(np.arange(6) % engine.cfg.vocab_size, 32)
    cb = router.batchers["eff"]
    cb.step()                              # admit + first decode step
    assert not cb.queue and any(r is not None for r in cb.active)
    busy = router._fleet_state().pools["eff"].est_wait_s
    assert busy > 0.0                      # residual decode counted
    router.drain()


def test_router_capacity_aware_spills(engine):
    eff, perf = paper_fleet()
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         policy="capacity_aware", lam=0.0,
                         counts={"m1-pro": 1, "swing-a100": 1})
    # lam=0 -> pure latency: a burst deep enough that the perf pool's queue
    # exceeds the eff pool's service time must spill to the eff pool
    pools = {router.route(8, 8, arrival_s=0.0) for _ in range(64)}
    assert len(pools) == 2
