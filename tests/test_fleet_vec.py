"""Vectorized fleet-sim core: bit-for-bit equivalence with the legacy
event engine across seeds, disciplines, power management, and paged-KV
admission; batched-pricing bitwise identity; golden arrival-sampler pins
(the arrival path is shared state between engines — a sampler drift would
silently re-baseline both sides of the equivalence gate); the sorted-
latency percentile cache; and engine-argument validation."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostOptimalScheduler,
                        FleetSimulator, PoolSpec, WorkloadSpec,
                        generate_arrivals, sample_workload, simulate_fleet)
from repro.core.fleet import FLEET_ENGINES, TargetUtilizationAutoscaler
from repro.core.fleet_vec import VectorizedFleetSimulator
from repro.core.pricing import AnalyticOracle, CostModel
from repro.core.systems import SystemProfile

CFG = get_config("qwen2.5-3b")


def _systems():
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=90e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=220.0,
                        power_idle_w=60.0, overhead_s=0.02, sat_ctx=4096.0)
    perf = SystemProfile(name="perf", kind="perf", chips=2, peak_flops=200e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=60.0, overhead_s=0.01, sat_ctx=None)
    return eff, perf


def _run_both(seed, disc, autoscale, kv, n=220):
    """One config through both engines; the scheduler family alternates
    with the seed so the table-backed CapacityAware fast path and the
    base CostOptimal path are both exercised."""
    eff, perf = _systems()
    qs = sample_workload(n, seed=seed, spec=WorkloadSpec(rate_qps=6.0),
                         arrival_process="mmpp" if seed % 2 else "diurnal")
    pools = {
        "eff": PoolSpec(eff, instances=3, slots=4,
                        kv_blocks=512 if kv else 0, block_size=16,
                        linger_s=20.0 if autoscale else math.inf),
        "perf": PoolSpec(perf, instances=2, slots=4,
                         kv_blocks=512 if kv else 0, block_size=16),
    }
    autos = ({"eff": TargetUtilizationAutoscaler(period_s=15.0,
                                                 min_instances=1)}
             if autoscale else None)
    out = []
    for engine in FLEET_ENGINES:
        sched = (CapacityAwareScheduler(CFG, [eff, perf],
                                        {"eff": 3, "perf": 2})
                 if seed % 2 else CostOptimalScheduler(CFG, [eff, perf]))
        out.append(simulate_fleet(CFG, qs, pools, sched,
                                  queue_discipline=disc, autoscaler=autos,
                                  engine=engine))
    return out


# ------------------------------------------------------- equivalence gate
@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("disc", ["fifo", "sjf"])
@pytest.mark.parametrize("autoscale,kv",
                         [(False, False), (True, False), (False, True),
                          (True, True)])
def test_engines_bit_identical(seed, disc, autoscale, kv):
    evt, vec = _run_both(seed, disc, autoscale, kv)
    assert evt.summary() == vec.summary()          # bit-for-bit, no tolerance
    for ra, rb in zip(evt.records, vec.records):
        assert (ra.rid, ra.pool, ra.t_arrival, ra.t_start, ra.t_decode,
                ra.t_done, ra.energy_j) == \
               (rb.rid, rb.pool, rb.t_arrival, rb.t_start, rb.t_decode,
                rb.t_done, rb.energy_j)
    for k in evt.per_pool:
        assert vars(evt.per_pool[k]) == vars(vec.per_pool[k])


def test_engine_classes_agree_with_dispatcher():
    """simulate_fleet(engine=...) must route to the same classes callers
    can construct directly."""
    eff, perf = _systems()
    qs = sample_workload(60, seed=5, spec=WorkloadSpec(rate_qps=4.0))
    pools = {"eff": PoolSpec(eff, 2, 2), "perf": PoolSpec(perf, 2, 2)}
    direct = VectorizedFleetSimulator(
        CFG, pools, CostOptimalScheduler(CFG, [eff, perf])).run(qs)
    routed = simulate_fleet(CFG, qs, pools,
                            CostOptimalScheduler(CFG, [eff, perf]),
                            engine="vectorized")
    assert direct.summary() == routed.summary()
    legacy = FleetSimulator(CFG, pools,
                            CostOptimalScheduler(CFG, [eff, perf])).run(qs)
    assert legacy.summary() == routed.summary()


def test_engine_argument_validated():
    eff, perf = _systems()
    qs = sample_workload(5, seed=0)
    pools = {"eff": PoolSpec(eff, 1, 1), "perf": PoolSpec(perf, 1, 1)}
    with pytest.raises(ValueError):
        simulate_fleet(CFG, qs, pools,
                       CostOptimalScheduler(CFG, [eff, perf]),
                       engine="turbo")


# --------------------------------------------------------- batched pricing
def test_batched_pricing_bitwise():
    """price/cost/runtime_batch must equal the scalar calls bit-for-bit:
    the vectorized engine's settlement arithmetic is transcribed, not
    approximated."""
    eff, perf = _systems()
    model = CostModel(CFG, AnalyticOracle())
    rng = np.random.default_rng(0)
    m = rng.integers(8, 2048, 64)
    n = rng.integers(1, 512, 64)
    for s in (eff, perf):
        cb = model.cost_batch(m, n, s)
        rb = model.runtime_batch(m, n, s)
        eb = model.energy_batch(m, n, s)
        for k in range(len(m)):
            assert cb[k] == model.cost(int(m[k]), int(n[k]), s)
            assert rb[k] == model.runtime(int(m[k]), int(n[k]), s)
            assert eb[k] == model.energy(int(m[k]), int(n[k]), s)
        for b in (1, 4):
            ph = model.price_batch(m, n, s, batch=b)
            for k in range(len(m)):
                p1 = model.phases(int(m[k]), int(n[k]), s, batch=b)
                assert ph.t_prefill[k] == p1.t_prefill
                assert ph.t_decode[k] == p1.t_decode
                assert ph.util_decode[k] == p1.util_decode


# ------------------------------------------------- golden arrival samplers
GOLDEN_HEADS = {
    ("diurnal", 0): [0.4720913903985484, 0.47759324111773044,
                     0.6310966298178072, 2.2632107198807887,
                     4.8588166471964325],
    ("diurnal", 1): [0.3837450473605064, 2.6492067718396726,
                     2.8023793765129246, 2.8106331103913345,
                     3.0228738131473483],
    ("mmpp", 0): [1.8586341910257107, 2.107428378126808,
                  2.2210521602320856, 2.3112512946211927,
                  2.7161732145432023],
    ("mmpp", 1): [1.5753148695915309, 2.304730832331034,
                  2.6064773853587155, 2.822255536769585,
                  2.9461258243397452],
}


@pytest.mark.parametrize("process,seed", sorted(GOLDEN_HEADS))
def test_arrival_sampler_golden(process, seed):
    """The vectorized arrival generators are pinned to exact float values:
    both engines consume the same stream, so a sampler change would keep
    the equivalence gate green while silently moving every benchmark."""
    a = generate_arrivals(200, 2.0, seed=seed, process=process)
    assert a[:5].tolist() == GOLDEN_HEADS[(process, seed)]


# ------------------------------------------------------- percentile cache
def test_latency_percentile_cache():
    eff, perf = _systems()
    qs = sample_workload(150, seed=2, spec=WorkloadSpec(rate_qps=5.0),
                         arrival_process="mmpp")
    pools = {"eff": PoolSpec(eff, 2, 2), "perf": PoolSpec(perf, 2, 4)}
    r = simulate_fleet(CFG, qs, pools,
                       CostOptimalScheduler(CFG, [eff, perf]))
    lat = np.array(sorted(rec.t_done - rec.t_arrival for rec in r.records))
    for p in (0.0, 10.0, 50.0, 90.0, 99.0, 100.0):
        assert r.latency_percentile(p) == float(np.percentile(lat, p))
    assert r.p50_latency_s == r.latency_percentile(50.0)
    assert r.p99_latency_s == r.latency_percentile(99.0)
