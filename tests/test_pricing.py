"""Unified pricing layer: oracle backends, CostModel, calibration fit.

Acceptance contract (ISSUE 2):
  * ``AnalyticOracle`` pricing is bit-for-bit the historical
    ``energy()``/``runtime()``/``cost()`` free functions;
  * ``TableOracle`` interpolation stays within a small relative error of the
    analytic model off-grid;
  * ``fit_calibration`` recovers ground-truth constants from noisy timings
    with rel-RMSE below the documented bound (0.08 at 3% noise);
  * the quantized LRU memo is exact at quant=1 and bounded-skew beyond.
"""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (AnalyticOracle, CalibratedOracle, Calibration,
                        CostModel, CostParams, KernelSample, Query,
                        TableOracle, cost, crossover_threshold, energy,
                        fit_calibration, normalized_cost_params, paper_fleet,
                        runtime, tpu_fleet)
from repro.core.pricing import _predict
from repro.core.scheduler import (CapacityAwareScheduler, CostOptimalScheduler,
                                  ThresholdScheduler)
from repro.core.systems import PROFILES

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()
GRID = [(1, 1), (8, 32), (32, 32), (100, 70), (513, 33), (777, 123),
        (2048, 512), (3, 900)]


# ----------------------------------------------------------- analytic oracle
def test_analytic_oracle_bit_for_bit():
    """The refactor's zero-regression guarantee: CostModel(AnalyticOracle)
    reproduces every historical free-function value EXACTLY."""
    model = CostModel(CFG, AnalyticOracle())
    cp = CostParams(lam=0.3, e_norm=7.0, r_norm=0.2)
    model_cp = CostModel(CFG, AnalyticOracle(), cp)
    for s in PROFILES.values():
        for m, n in GRID:
            assert model.energy(m, n, s) == energy(CFG, m, n, s)
            assert model.runtime(m, n, s) == runtime(CFG, m, n, s)
            assert model.cost(m, n, s) == cost(CFG, m, n, s)
            assert model_cp.cost(m, n, s) == cost(CFG, m, n, s, cp)
    for b in (2, 8):
        assert model.energy(64, 64, PERF, batch=b) == energy(CFG, 64, 64, PERF, b)


def test_cost_model_normalized_is_o1():
    model = CostModel.normalized(CFG, PERF, lam=0.5)
    assert model.energy(128, 128, PERF) / model.cp.e_norm == pytest.approx(1.0)
    assert model.runtime(128, 128, PERF) / model.cp.r_norm == pytest.approx(1.0)
    # at the representative size the cost is ~1 for ANY lambda
    for lam in (0.0, 0.25, 1.0):
        m = CostModel.normalized(CFG, PERF, lam=lam)
        assert m.cost(128, 128, PERF) == pytest.approx(1.0, rel=1e-9)


def test_wait_cost_matches_inline_wait():
    model = CostModel(CFG, AnalyticOracle(), CostParams(lam=0.4, r_norm=3.0))
    base = model.cost(64, 64, PERF)
    assert model.cost(64, 64, PERF, wait_s=5.0) == \
        pytest.approx(base + model.wait_cost(5.0), rel=1e-12)


# -------------------------------------------------------------- table oracle
def test_table_oracle_off_grid_accuracy():
    """Bilinear log-grid interpolation must track the analytic model within
    10% at off-grid points (the m1-pro's sat_ctx term is the worst case)."""
    analytic = CostModel(CFG)
    table = CostModel(CFG, TableOracle(CFG))
    for s in (EFF, PERF, *tpu_fleet()):
        for m, n in [(100, 70), (513, 33), (3, 900), (1500, 200)]:
            ra, rt = analytic.runtime(m, n, s), table.runtime(m, n, s)
            assert abs(ra - rt) / ra < 0.10, (s.name, m, n)
            ea, et = analytic.energy(m, n, s), table.energy(m, n, s)
            assert abs(ea - et) / ea < 0.10, (s.name, m, n)


def test_table_oracle_exact_on_grid():
    oracle = TableOracle(CFG)
    model, analytic = CostModel(CFG, oracle), CostModel(CFG)
    for m, n in [(32, 32), (256, 1024)]:    # grid points: log2-spaced
        assert model.runtime(m, n, PERF) == \
            pytest.approx(analytic.runtime(m, n, PERF), rel=1e-9)


def test_table_oracle_rejects_wrong_config():
    other = get_config("llama2-7b")
    oracle = TableOracle(CFG)
    with pytest.raises(ValueError):
        oracle.phases(other, 32, 32, PERF)


# --------------------------------------------------------- calibrated oracle
def _synthetic_samples(profile, ce, me, sat, oh, *, n=40, noise=0.03, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        base = float(10 ** rng.uniform(-3.0, 0.0))
        r = float(rng.uniform(-1.5, 1.5))
        f = base * ce * profile.instance_peak_flops / (10 ** max(0.0, -r))
        b = base * me * profile.instance_hbm_bw / (10 ** max(0.0, r))
        ctx = float(rng.integers(0, 4096))
        t = _predict([KernelSample("synthetic", f, b, ctx, 1.0)], profile,
                     ce, me, sat, oh)[0] * float(1 + rng.normal(0, noise))
        out.append(KernelSample("synthetic", f, b, ctx, max(t, 1e-9)))
    return out


def test_fit_calibration_recovers_ground_truth():
    """Documented bound (EXPERIMENTS.md §Calibration): synthetic recovery
    rel-RMSE < 0.08 at 3% noise, compute_eff within 25%."""
    truth = dict(ce=0.37, me=0.66, sat=1500.0, oh=0.002)
    samples = _synthetic_samples(PERF, truth["ce"], truth["me"],
                                 truth["sat"], truth["oh"])
    cal = fit_calibration(PERF, samples)
    assert cal.fit_rel_rmse < 0.08
    assert abs(cal.compute_eff - truth["ce"]) / truth["ce"] < 0.25
    assert abs(cal.overhead_s - truth["oh"]) / truth["oh"] < 0.5


def test_calibrated_oracle_prices_with_fitted_constants():
    cal = Calibration(profile=PERF.name, compute_eff=0.25, mem_eff=0.5,
                      sat_ctx=None, overhead_s=0.1, fit_rel_rmse=0.0,
                      n_samples=1)
    model = CostModel(CFG, CalibratedOracle([cal]))
    analytic = CostModel(CFG)
    # halved efficiencies -> strictly slower than the hand-tuned constants
    assert model.runtime(512, 128, PERF) > analytic.runtime(512, 128, PERF)
    # overhead term shows up verbatim
    assert model.phases(8, 8, PERF).t_overhead == 0.1
    # profiles without a calibration fall back to hand-tuned (non-strict)
    assert model.runtime(64, 64, EFF) == analytic.runtime(64, 64, EFF)
    with pytest.raises(KeyError):
        CalibratedOracle([cal], strict=True).phases(CFG, 8, 8, EFF)


def test_calibration_artifact_roundtrip(tmp_path):
    cal = Calibration(profile=EFF.name, compute_eff=0.11, mem_eff=0.22,
                      sat_ctx=333.0, overhead_s=0.044, fit_rel_rmse=0.01,
                      n_samples=9)
    path = str(tmp_path / "cal.json")
    CalibratedOracle([cal]).dump(path)
    loaded = CalibratedOracle.load(path)
    assert loaded.calibrations[EFF.name] == cal


def test_calibration_apply_rejects_wrong_profile():
    cal = Calibration(profile=EFF.name, compute_eff=0.1, mem_eff=0.2,
                      sat_ctx=None, overhead_s=0.0, fit_rel_rmse=0.0,
                      n_samples=1)
    with pytest.raises(ValueError):
        cal.apply(PERF)


# --------------------------------------------------------------------- memo
def test_memo_exact_at_quant_1():
    model = CostModel(CFG, quant=1)
    a = model.runtime(100, 70, PERF)
    b = model.runtime(100, 70, PERF)
    assert a == b == runtime(CFG, 100, 70, PERF)
    info = model.memo_info()
    assert info["hits"] == 1 and info["misses"] == 1


def test_memo_quantized_bounded_skew():
    exact, quant = CostModel(CFG), CostModel(CFG, quant=8)
    rng = np.random.default_rng(0)
    for _ in range(50):
        m = int(rng.integers(1, 2048))
        n = int(rng.integers(1, 512))
        e, q = exact.energy(m, n, PERF), quant.energy(m, n, PERF)
        assert abs(e - q) / e < 0.08, (m, n)
    # small token counts are never perturbed (dense region of the workload)
    assert quant.runtime(37, 41, PERF) == exact.runtime(37, 41, PERF)


def test_memo_lru_eviction_bounds_size():
    model = CostModel(CFG, memo_size=16)
    for m in range(1, 40):
        model.runtime(m, 1, PERF)
    assert model.memo_info()["size"] <= 16
    model.clear_memo()
    assert model.memo_info() == {"size": 0, "hits": 0, "misses": 0, "quant": 1}


def test_memo_rejects_bad_quant():
    with pytest.raises(ValueError):
        CostModel(CFG, quant=0)


def test_memo_invalidated_when_oracle_mutates():
    """Adding a calibration (or table) after first use must not leave stale
    memoized phases behind."""
    oracle = CalibratedOracle()
    model = CostModel(CFG, oracle)
    before = model.runtime(512, 128, PERF)       # memoized, hand-tuned
    oracle.add(Calibration(profile=PERF.name, compute_eff=0.25, mem_eff=0.5,
                           sat_ctx=None, overhead_s=0.1, fit_rel_rmse=0.0,
                           n_samples=1))
    after = model.runtime(512, 128, PERF)
    assert after > before                         # halved efficiencies bite


def test_default_model_distinguishes_samename_config_variants():
    """cfg.reduced() keeps cfg.name; the shims must price the variant that
    was actually passed, not a name-collided cache entry."""
    full = get_config("llama2-7b")
    reduced = full.reduced()
    e_full = energy(full, 64, 32, PERF)
    e_reduced = energy(reduced, 64, 32, PERF)
    assert e_reduced < e_full                     # tiny model, tiny joules
    # and a replace()-built profile variant must not collide in the memo
    from dataclasses import replace
    slow = replace(PERF, compute_eff=PERF.compute_eff / 10,
                   mem_eff=PERF.mem_eff / 10)
    model = CostModel(CFG)
    r_fast = model.runtime(512, 128, PERF)
    r_slow = model.runtime(512, 128, slow)
    assert r_slow > r_fast


def test_scheduler_rejects_conflicting_cp_and_model():
    model = CostModel(CFG, cp=CostParams(lam=1.0))
    with pytest.raises(ValueError):
        CostOptimalScheduler(CFG, [EFF, PERF], CostParams(lam=0.5),
                             model=model)
    # agreeing or default cp is fine
    CostOptimalScheduler(CFG, [EFF, PERF], model=model)
    CostOptimalScheduler(CFG, [EFF, PERF], CostParams(lam=1.0), model=model)


# -------------------------------------------- schedulers price via the model
def test_schedulers_accept_pluggable_oracle():
    """Every policy runs unchanged on a table-backed CostModel."""
    table = CostModel(CFG, TableOracle(CFG))
    qs = [Query(10, 10, 0.0), Query(800, 200, 1.0), Query(30, 5, 2.0)]
    for sched in (ThresholdScheduler(CFG, EFF, PERF, t_in=32, model=table),
                  CostOptimalScheduler(CFG, [EFF, PERF], model=table),
                  CapacityAwareScheduler(CFG, [EFF, PERF],
                                         {EFF.name: 1, PERF.name: 1},
                                         model=table)):
        assigns = sched.assign(qs)
        assert len(assigns) == len(qs)
        assert all(a.energy_j > 0 and a.runtime_s > 0 for a in assigns)


def test_cost_optimal_identical_under_analytic_model():
    """Explicit-model and legacy construction route every query the same."""
    qs = [Query(int(m), int(n)) for m, n in
          np.random.default_rng(1).integers(1, 1024, size=(30, 2))]
    legacy = CostOptimalScheduler(CFG, [EFF, PERF])
    modeled = CostOptimalScheduler(CFG, [EFF, PERF],
                                   model=CostModel(CFG, AnalyticOracle()))
    for q in qs:
        assert legacy.choose(q).name == modeled.choose(q).name


# ------------------------------------------------------- CostParams edge cases
def test_lambda_zero_is_pure_latency_ranking():
    """lam=0 must rank systems exactly by runtime, ignoring energy."""
    cp = CostParams(lam=0.0)
    for m, n in [(8, 8), (64, 512), (2048, 64)]:
        by_cost = sorted(PROFILES.values(),
                         key=lambda s: cost(CFG, m, n, s, cp))
        by_runtime = sorted(PROFILES.values(),
                            key=lambda s: runtime(CFG, m, n, s))
        assert [s.name for s in by_cost] == [s.name for s in by_runtime]
        # and the cost VALUE is the runtime itself at unit normalizers
        s0 = by_cost[0]
        assert cost(CFG, m, n, s0, cp) == pytest.approx(
            runtime(CFG, m, n, s0), rel=1e-12)


def test_normalized_cost_params_o1_scaling():
    """Shim parity: normalized params make E and R O(1) on the reference."""
    for lam in (0.0, 0.5, 1.0):
        cp = normalized_cost_params(CFG, PERF, lam)
        e = energy(CFG, 128, 128, PERF) / cp.e_norm
        r = runtime(CFG, 128, 128, PERF) / cp.r_norm
        assert e == pytest.approx(1.0, rel=1e-9)
        assert r == pytest.approx(1.0, rel=1e-9)
        assert cost(CFG, 128, 128, PERF, cp) == pytest.approx(1.0, rel=1e-9)


def test_crossover_threshold_out_axis():
    """axis='out' (previously untested): the calibrated fleet crosses over
    within a power-of-two bucket of the paper's T_out=32, and below the
    crossover the efficiency device genuinely wins J/token."""
    t_out = crossover_threshold(CFG, EFF, PERF, axis="out")
    assert 16 <= t_out <= 64
    from repro.core import energy_per_token_out
    assert energy_per_token_out(CFG, max(1, t_out // 2), EFF) < \
        energy_per_token_out(CFG, max(1, t_out // 2), PERF)
    assert energy_per_token_out(CFG, t_out, PERF) < \
        energy_per_token_out(CFG, t_out, EFF)
    # bounded-search contract: hi is returned when no crossover in range
    assert crossover_threshold(CFG, EFF, PERF, axis="out", lo=1, hi=2) == 2
