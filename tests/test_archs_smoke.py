"""Per-architecture smoke tests: REDUCED variants (<=2 layers, d_model<=512,
<=4 experts) run one forward + one train step on CPU; output shapes asserted,
no NaNs; prefill+decode must match the full forward teacher-forcing logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import model as M
from repro.training import optimizer as OPT
from repro.training.train import make_train_step

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, extra=0):
    tok = jax.random.randint(KEY, (B, S + extra), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (B, cfg.encoder_seq_len, cfg.d_model))
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(KEY, (B, cfg.num_vision_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = M.init_params(cfg, KEY, max_positions=256)
    batch = make_batch(cfg)
    logits, aux = M.forward_train(params, cfg, batch)
    S_total = 16 + (cfg.num_vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY, max_positions=256)
    opt = OPT.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    step = jax.jit(make_train_step(cfg, opt))
    state = OPT.init_state(params)
    p2, s2, metrics = step(params, state, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(bool(jnp.any(a != b))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY, max_positions=256)
    B, S, extra = 2, 12, 3
    batch = make_batch(cfg, B, S, extra)
    tok = batch["tokens"]
    full_logits, _ = M.forward_train(params, cfg, dict(batch, tokens=tok))
    n_vis = cfg.num_vision_tokens if cfg.family == "vlm" else 0

    cache = M.init_cache(cfg, B, 64)
    lg, cache = M.prefill(params, cfg, dict(batch, tokens=tok[:, :S]), cache)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full_logits[:, n_vis + S - 1]),
                               atol=2e-4)
    for t in range(extra):
        lg, cache = M.decode_step(params, cfg, tok[:, S + t:S + t + 1], cache)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, n_vis + S + t]),
                                   atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "zamba2-1.2b", "mamba2-130m"])
def test_sliding_window_variant_runs(arch):
    """long_500k carve-out: the sliding-window variant must be functional."""
    import dataclasses
    cfg = dataclasses.replace(get_config(arch).reduced(), sliding_window=8)
    params = M.init_params(cfg, KEY)
    logits, _ = M.forward_train(params, cfg, make_batch(cfg, 1, 32))
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned hyperparams."""
    spec = {
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, H, kv, ff, V), arch
    assert get_config("phi3.5-moe-42b-a6.6b").moe.num_experts == 16
    assert get_config("grok-1-314b").moe.num_experts == 8
    assert get_config("mamba2-130m").ssm.state_dim == 128
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
