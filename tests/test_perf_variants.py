"""The perf-iteration levers (EXPERIMENTS.md §Perf) must preserve semantics
exactly: baseline and optimized implementations are interchangeable."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models import moe as MOE
from repro.training.train import lm_loss

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def env():
    saved = {k: os.environ.get(k) for k in
             ("REPRO_LOSS_IMPL", "REPRO_CACHE_MODE", "REPRO_MOE_DISPATCH")}
    yield os.environ
    for k, v in saved.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v


def test_loss_impls_equal(env):
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 24), 0, cfg.vocab_size)}
    env["REPRO_LOSS_IMPL"] = "softmax"
    l1, _ = lm_loss(params, cfg, batch)
    env["REPRO_LOSS_IMPL"] = "logsumexp"
    l2, _ = lm_loss(params, cfg, batch)
    assert abs(float(l1 - l2)) < 1e-5
    # gradients too
    env["REPRO_LOSS_IMPL"] = "softmax"
    g1 = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    env["REPRO_LOSS_IMPL"] = "logsumexp"
    g2 = jax.grad(lambda p: lm_loss(p, cfg, batch)[0])(params)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
    assert err < 1e-5


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "phi3.5-moe-42b-a6.6b"])
def test_cache_modes_equal(env, arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    tok = jax.random.randint(KEY, (2, 20), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 2, 64)
    _, cache = M.prefill(params, cfg, {"tokens": tok[:, :16]}, cache)
    env["REPRO_CACHE_MODE"] = "scan"
    lg_s, cache_s = M.decode_step(params, cfg, tok[:, 16:17], dict(cache))
    env["REPRO_CACHE_MODE"] = "carry"
    lg_c, cache_c = M.decode_step(params, cfg, tok[:, 16:17], dict(cache))
    np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_c), atol=1e-5)
    np.testing.assert_allclose(np.asarray(cache_s["k"]), np.asarray(cache_c["k"]),
                               atol=1e-6)


def test_moe_dispatch_modes_equal_at_dropless_capacity(env):
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, capacity_factor=float(cfg.moe.num_experts) / cfg.moe.num_experts_per_tok))
    p = MOE.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 4096, cfg.d_model)) * 0.1
    env["REPRO_MOE_DISPATCH"] = "global"
    yg, auxg = MOE.moe_apply(p, cfg, x)
    env["REPRO_MOE_DISPATCH"] = "grouped"
    yl, auxl = MOE.moe_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(yg), np.asarray(yl), atol=1e-5)
    assert abs(float(auxg - auxl)) < 1e-6
