"""Kernel autotuner: cache round-trip, env pinning, dispatch wiring, parity.

Acceptance contract (ISSUE 8):
  * the cache round-trips (write -> load -> resolve) and is deterministic at
    a fixed seed/env;
  * a cache recorded under a different environment fingerprint refuses to
    load (``StaleCacheError``);
  * with NO cache installed, ops dispatch is bit-for-bit the historical
    defaults — including the quantized paged-decode read path, which must
    reproduce the old inline gather-dequantize composition exactly;
  * the tuned winner is never slower than the default on the measured grid
    (the default is a candidate in every space);
  * tuned parameters actually reach the kernels, and explicit kwargs beat
    them;
  * the fused int8 read path matches the gather oracle within kernel
    tolerance; ``TableOracle.from_autotune`` prices tuned timings within the
    fit's own error.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pricing import (CalibratedOracle, KernelSample, TableOracle,
                                _predict, fit_calibration)
from repro.core.systems import SystemProfile
from repro.kernels import autotune as AT
from repro.kernels import decode_attention as DA
from repro.kernels import flash_attention as FA
from repro.kernels import ops, ref
from repro.kernels import ssm_scan as SS
from repro.launch import envcfg

HOST = SystemProfile(name="host-cpu", kind="eff", chips=1,
                     peak_flops=2.0e11, hbm_bw=5.0e10, ici_bw=0.0,
                     power_peak_w=65.0, power_idle_w=10.0, overhead_s=1e-3)


@pytest.fixture(autouse=True)
def _no_installed_cache():
    """Every test starts and ends with no process-wide cache installed."""
    AT.install(None)
    yield
    AT.install(None)


def det_timer(kernel, shape, *, params, backend, iters, seed):
    """Deterministic fake timer: time depends only on (kernel, shape,
    params), with a fixed non-default winner per kernel."""
    fast = {"flash_attention": {"block_q": 256},
            "ssm_scan": {"chunk": 64},
            "decode_attention": {"block_kv": 256},
            "paged_decode_quant": {"impl": "fused"}}
    base = 1e-3 * (1 + sum(shape.values()) / 1024)
    t = base * (0.5 if params == fast.get(kernel) else 1.0 + 0.01 * (
        sum(ord(c) for c in json.dumps(params, sort_keys=True)) % 7))
    return KernelSample(kernel, 1e9, 1e6,
                        float(shape.get("c", shape.get("s", 0))), t, 0.01)


def make_cache(backend="ref"):
    shapes = {"flash_attention": [{"s": 1024}], "ssm_scan": [{"s": 512}],
              "paged_decode_quant": [{"b": 8, "c": 1024}]}
    if backend != "ref":
        shapes["decode_attention"] = [{"b": 2, "c": 2048}]
    return AT.autotune(shapes, profile="host-cpu", backend=backend,
                       timer=det_timer)


# ------------------------------------------------------------- param spaces
def test_spaces_contain_defaults_first():
    for (kernel, backend), default in AT.DEFAULT_PARAMS.items():
        space = AT.param_space(kernel, backend)
        if not space:
            assert default == {}, (kernel, backend)
            continue
        assert space[0] == default, (kernel, backend)
        assert all(space.count(c) == 1 for c in space)


def test_shape_bucket_pow2():
    assert AT.shape_bucket("flash_attention", s=1024) == "s1024"
    assert AT.shape_bucket("flash_attention", s=1000) == "s1024"
    assert AT.shape_bucket("flash_attention", s=1025) == "s2048"
    assert AT.shape_bucket("paged_decode_quant", b=6, c=1500) == "b8c2048"
    assert AT.shape_bucket("ssm_scan", s=512) == "s512"
    with pytest.raises(KeyError):
        AT.shape_bucket("nope", s=1)


# ------------------------------------------------------------------- cache
def test_cache_roundtrip(tmp_path):
    cache = make_cache()
    assert len(cache.entries) == 3
    path = cache.dump(AT.cache_path("host-cpu", "ref", str(tmp_path)))
    loaded = AT.AutotuneCache.load(path)
    assert loaded.to_json() == cache.to_json()
    for e in cache.entries.values():
        assert loaded.resolve(e.kernel, e.backend, e.bucket) == e.params
    assert loaded.resolve("flash_attention", "ref", "s4096") is None
    assert loaded.resolve("flash_attention", "pallas", "s1024") is None


def test_cache_deterministic_at_fixed_seed_env():
    a, b = make_cache(), make_cache()
    assert a.to_json() == b.to_json()


def test_stale_env_refused(tmp_path):
    cache = make_cache()
    path = cache.dump(str(tmp_path / "c.json"))
    with open(path) as f:
        data = json.load(f)
    data["env"]["jax"] = "0.0.0-stale"
    data["env_digest"] = envcfg.fingerprint_digest(data["env"])
    stale = str(tmp_path / "stale.json")
    with open(stale, "w") as f:
        json.dump(data, f)
    with pytest.raises(AT.StaleCacheError):
        AT.AutotuneCache.load(stale)
    # escape hatch for offline inspection
    assert AT.AutotuneCache.load(stale, require_env=False).entries
    # a tampered digest is corruption, not staleness
    data["env_digest"] = "0" * 16
    with open(stale, "w") as f:
        json.dump(data, f)
    with pytest.raises(ValueError, match="corrupt"):
        AT.AutotuneCache.load(stale, require_env=False)


def test_cache_version_pinned(tmp_path):
    cache = make_cache()
    data = cache.to_json()
    data["version"] = AT.CACHE_VERSION + 1
    path = str(tmp_path / "v.json")
    with open(path, "w") as f:
        json.dump(data, f)
    with pytest.raises(ValueError, match="version"):
        AT.AutotuneCache.load(path)


def test_env_fingerprint_tracks_captured_vars(monkeypatch):
    base = envcfg.fingerprint_digest()
    monkeypatch.setenv("REPRO_CACHE_MODE", "weird-test-value")
    assert envcfg.fingerprint_digest() != base


# ------------------------------------------------- fallback parity (no cache)
def _attn_inputs(seed=0, B=2, Hq=4, Hkv=2, S=256, Dh=64):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Hq, S, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
    return q, k, v


def _quant_inputs(seed=0, B=2, Hq=4, Hkv=2, Dh=64, bs=16, mb=8):
    rng = np.random.default_rng(seed)
    ctx = bs * mb
    nb = 1 + B * mb
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, Dh)), jnp.float32)
    kp = jnp.asarray(rng.integers(-127, 128, (nb, Hkv, bs, Dh)), jnp.int8)
    vp = jnp.asarray(rng.integers(-127, 128, (nb, Hkv, bs, Dh)), jnp.int8)
    ks = jnp.asarray(rng.uniform(.005, .02, (nb, Hkv, bs, 1)), jnp.float32)
    vs = jnp.asarray(rng.uniform(.005, .02, (nb, Hkv, bs, 1)), jnp.float32)
    tables = jnp.asarray(np.arange(1, 1 + B * mb).reshape(B, mb), jnp.int32)
    kv_len = jnp.asarray([ctx - (37 * i) % 101 for i in range(B)], jnp.int32)
    return q, kp, vp, ks, vs, tables, kv_len


def test_untuned_dispatch_bit_for_bit_historical():
    """No cache installed: every ops entry point must equal the direct
    kernel call with its historical hard-coded parameters."""
    q, k, v = _attn_inputs(S=2048)       # > ref default block_q, so chunking runs
    got = ops.flash_attention(q, k, v, backend="ref")
    want = ref.mha_attention_chunked(q, k, v, causal=True, block_q=1024)
    assert (got == want).all()

    got = ops.flash_attention(q[:, :, :256], k, v, backend="pallas_interpret")
    want = FA.flash_attention(q[:, :, :256], k, v, causal=True,
                              block_q=128, block_k=128, interpret=True)
    assert (got == want).all()

    qd = q[:, :, :1]
    kv_len = jnp.asarray([2048, 1500], jnp.int32)
    got = ops.decode_attention(qd, k, v, kv_len, backend="pallas_interpret")
    want = DA.decode_attention(qd, k, v, kv_len, block_k=128, interpret=True)
    assert (got == want).all()

    rng = np.random.default_rng(3)
    B, H, S, P, N = 2, 4, 384, 64, 64
    x = jnp.asarray(rng.normal(size=(B, H, S, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(.001, .2, (B, H, S)), jnp.float32)
    A = jnp.asarray(-rng.uniform(.5, 4., (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
    y, fin = ops.ssd_scan(x, dt, A, Bm, Cm, backend="ref")
    yw, finw = ref.ssd_scan_chunked(x, dt, A, Bm, Cm, chunk=128)
    assert (y == yw).all() and (fin == finw).all()


def test_untuned_quant_path_is_old_inline_composition():
    """ops.paged_decode_attention_quant with no cache = the exact gather +
    dequantize + dense-decode composition models.attention used to inline."""
    q, kp, vp, ks, vs, tables, kv_len = _quant_inputs()
    for backend in ("ref", "pallas_interpret"):
        got = ops.paged_decode_attention_quant(
            q, kp, vp, ks, vs, tables, kv_len, softcap=30.0, backend=backend)
        k_read = ref.dequantize_kv(ref.gather_paged_kv(kp, tables),
                                   ref.gather_paged_kv(ks, tables), q.dtype)
        v_read = ref.dequantize_kv(ref.gather_paged_kv(vp, tables),
                                   ref.gather_paged_kv(vs, tables), q.dtype)
        want = ops.decode_attention(q, k_read, v_read, kv_len, softcap=30.0,
                                    backend=backend)
        assert (got == want).all(), backend


# --------------------------------------------------------- tuned dispatch
def test_tuned_params_reach_kernels_and_kwargs_override(monkeypatch):
    cache = make_cache(backend="pallas_interpret")
    AT.install(cache)
    seen = {}
    orig_fa, orig_da, orig_ss = (FA.flash_attention, DA.decode_attention,
                                 SS.ssd_scan)

    def spy_fa(q, k, v, **kw):
        seen["flash"] = kw
        return orig_fa(q, k, v, **kw)

    monkeypatch.setattr(ops._fa, "flash_attention", spy_fa)
    q, k, v = _attn_inputs(S=1024)
    ops.flash_attention(q, k, v, backend="pallas_interpret")
    assert seen["flash"]["block_q"] == 256          # det_timer's winner
    ops.flash_attention(q, k, v, backend="pallas_interpret", block_q=64)
    assert seen["flash"]["block_q"] == 64           # explicit kwarg wins
    # different bucket (s2048): no entry -> kernel defaults, nothing passed
    q2, k2, v2 = _attn_inputs(S=2048)
    ops.flash_attention(q2, k2, v2, backend="pallas_interpret")
    assert "block_q" not in seen["flash"]

    def spy_da(q, kc, vc, kv_len, **kw):
        seen["decode"] = kw
        return orig_da(q, kc, vc, kv_len, **kw)

    monkeypatch.setattr(ops._da, "decode_attention", spy_da)
    qd = q[:, :, :1]
    kv_len = jnp.full((2,), 1024, jnp.int32)
    kc = jnp.zeros((2, 2, 2048, 64), jnp.float32)
    ops.decode_attention(qd, kc, kc, kv_len, backend="pallas_interpret")
    assert seen["decode"]["block_k"] == 256

    # ssm: tuned chunk resolves, explicit chunk overrides
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 2, 512, 16)), jnp.float32)
    dt = jnp.asarray(rng.uniform(.001, .2, (1, 2, 512)), jnp.float32)
    A = jnp.asarray(-rng.uniform(.5, 4., (2,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(1, 512, 8)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(1, 512, 8)), jnp.float32)

    def spy_ss(x, dt, A, Bm, Cm, **kw):
        seen["ssm"] = kw
        return orig_ss(x, dt, A, Bm, Cm, **kw)

    monkeypatch.setattr(ops._ss, "ssd_scan", spy_ss)
    ops.ssd_scan(x, dt, A, Bm, Cm, backend="pallas_interpret")
    assert seen["ssm"]["chunk"] == 64
    ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32, backend="pallas_interpret")
    assert seen["ssm"]["chunk"] == 32


def test_tuned_quant_impl_switches_kernel():
    cache = make_cache()                             # fused wins under det_timer
    q, kp, vp, ks, vs, tables, kv_len = _quant_inputs(B=8, mb=64)  # b8c1024
    gather = ops.paged_decode_attention_quant(q, kp, vp, ks, vs, tables,
                                              kv_len, backend="ref")
    AT.install(cache)
    tuned = ops.paged_decode_attention_quant(q, kp, vp, ks, vs, tables,
                                             kv_len, backend="ref")
    want = ref.paged_decode_attention_quant_fused(q, kp, vp, ks, vs, tables,
                                                  kv_len=kv_len)
    assert (tuned == want).all()
    # numerically interchangeable, not bit-equal (no q.dtype rounding)
    np.testing.assert_allclose(np.asarray(tuned), np.asarray(gather),
                               atol=2e-5)
    with pytest.raises(ValueError, match="impl"):
        ops.paged_decode_attention_quant(q, kp, vp, ks, vs, tables, kv_len,
                                         impl="nope", backend="ref")


def test_autotune_never_slower_on_measured_grid():
    """Real (tiny) grid search on the interpreter backend: the recorded
    winner time can never exceed the recorded default time, because the
    default is a candidate in every space."""
    shapes = {"ssm_scan": [{"s": 64}], "flash_attention": [{"s": 64}]}
    cache = AT.autotune(shapes, profile="host-cpu",
                        backend="pallas_interpret", iters=2)
    assert len(cache.entries) == 2
    for e in cache.entries.values():
        assert e.t_s <= e.t_default_s
        assert e.speedup >= 1.0
    assert cache.geomean_speedup() >= 1.0


# ------------------------------------------------------- int8 fused kernels
TOL = 3e-5


@pytest.mark.parametrize("softcap,window", [(None, None), (30.0, None),
                                            (None, 48), (20.0, 48)])
def test_int8_fused_matches_gather_oracle(softcap, window):
    q, kp, vp, ks, vs, tables, kv_len = _quant_inputs(seed=7)
    want = ref.paged_decode_attention_quant(q, kp, vp, ks, vs, tables,
                                            kv_len=kv_len, softcap=softcap,
                                            window=window)
    folded = ref.paged_decode_attention_quant_fused(
        q, kp, vp, ks, vs, tables, kv_len=kv_len, softcap=softcap,
        window=window)
    kernel = DA.paged_decode_attention_int8(
        q, kp, vp, ks, vs, tables, kv_len, softcap=softcap, window=window,
        interpret=True)
    np.testing.assert_allclose(np.asarray(folded), np.asarray(want), atol=TOL)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(want), atol=TOL)


def test_flash_block_validation():
    q, k, v = _attn_inputs(S=64)
    with pytest.raises(ValueError, match="power of two"):
        FA.flash_attention(q, k, v, block_q=96)


# ----------------------------------------------------------- oracle refresh
def test_table_oracle_from_autotune():
    cfg = get_config("deepseek-7b")
    # synthetic tuned samples from known roofline constants -> the refit
    # must price them back (and .calibration must expose the fit)
    rng = np.random.default_rng(0)
    truth_ce, truth_me, oh = 0.3, 0.5, 2e-4
    samples = []
    for i in range(12):
        # straddle the roofline knee so both efficiencies bind on some
        # samples (same harness as benchmarks/calibrate.py --synthetic)
        base = float(10.0 ** rng.uniform(-3.0, 0.0))
        r = float(rng.uniform(-1.5, 1.5))
        f = base * truth_ce * HOST.instance_peak_flops / (10.0 ** max(0.0, -r))
        b = base * truth_me * HOST.instance_hbm_bw / (10.0 ** max(0.0, r))
        t = oh + max(f / (HOST.instance_peak_flops * truth_ce),
                     b / (HOST.instance_hbm_bw * truth_me))
        samples.append(KernelSample("flash_attention", f, b, 0.0, t))
    oracle = TableOracle.from_autotune(cfg, HOST, samples, fit_sat_ctx=False)
    cal = oracle.calibration
    # grid-search precision floor is ~5% — same bound as the calibrate.py
    # synthetic recovery gate (0.08), not an exact-recovery claim
    assert cal is not None and cal.fit_rel_rmse < 0.08
    pred = _predict(samples, HOST, cal.compute_eff, cal.mem_eff, cal.sat_ctx,
                    cal.overhead_s)
    t = np.array([s.t_s for s in samples])
    assert np.all(np.abs(pred - t) / t < 0.10)
    # the grid was built eagerly and prices like its calibrated base
    base = CalibratedOracle([cal])
    for m, n in [(128, 64), (1024, 256), (777, 123)]:
        got = oracle.phases(cfg, m, n, HOST)
        want = base.phases(cfg, m, n, HOST)
        assert got.t_prefill == pytest.approx(want.t_prefill, rel=0.05)
        assert got.t_decode == pytest.approx(want.t_decode, rel=0.05)
    # an AutotuneCache is accepted directly (duck-typed tuned_samples())
    cache = make_cache()
    oracle2 = TableOracle.from_autotune(cfg, HOST, cache)
    assert oracle2.calibration.n_samples == len(cache.entries)


def test_fit_calibration_downweights_noisy_samples():
    """A wildly wrong sample flagged as noisy steers the fit less than the
    same sample claiming to be clean."""
    rng = np.random.default_rng(1)
    truth_ce, truth_me = 0.3, 0.5
    samples = []
    for i in range(10):
        f = 10.0 ** rng.uniform(9, 11)
        b = 10.0 ** rng.uniform(6, 8)
        t = max(f / (HOST.instance_peak_flops * truth_ce),
                b / (HOST.instance_hbm_bw * truth_me))
        samples.append(KernelSample("k", f, b, 0.0, t))
    bad = KernelSample("k", samples[0].flops, samples[0].bytes, 0.0,
                       samples[0].t_s * 3.0)

    def err(cal):
        pred = _predict(samples, HOST, cal.compute_eff, cal.mem_eff,
                        cal.sat_ctx, cal.overhead_s)
        t = np.array([s.t_s for s in samples])
        return float(np.sqrt(np.mean(((pred - t) / t) ** 2)))

    import dataclasses
    noisy = dataclasses.replace(bad, noise_frac=5.0)
    cal_clean_flag = fit_calibration(HOST, samples + [bad],
                                     fit_sat_ctx=False)
    cal_noisy_flag = fit_calibration(HOST, samples + [noisy],
                                     fit_sat_ctx=False)
    assert err(cal_noisy_flag) <= err(cal_clean_flag)
