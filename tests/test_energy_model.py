"""Paper-validation tests: the calibrated energy model must reproduce the
paper's Section 5/6 findings structurally and its Section 6 numbers."""
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.core import (alpaca_like, crossover_threshold, energy,
                        energy_per_token_in, energy_per_token_out, headline,
                        optimal_threshold, paper_fleet, threshold_sweep,
                        throughput, tpu_fleet)

CFG = get_config("deepseek-7b")     # llama-arch 7B == paper's model class
EFF, PERF = paper_fleet()


def test_fig1c_crossover_exists():
    """Efficiency device wins small inputs, performance device wins large."""
    assert energy_per_token_in(CFG, 8, EFF) < energy_per_token_in(CFG, 8, PERF)
    assert energy_per_token_in(CFG, 2048, PERF) < energy_per_token_in(CFG, 2048, EFF)


def test_fig2c_crossover_exists_output_axis():
    assert energy_per_token_out(CFG, 8, EFF) < energy_per_token_out(CFG, 8, PERF)
    assert energy_per_token_out(CFG, 512, PERF) < energy_per_token_out(CFG, 512, EFF)


def test_crossover_near_paper_threshold():
    """Paper Section 6.3: T_in = T_out = 32. Calibrated model: 32 +/- one
    power-of-two bucket."""
    t_in = crossover_threshold(CFG, EFF, PERF, axis="in")
    t_out = crossover_threshold(CFG, EFF, PERF, axis="out")
    assert 16 <= t_in <= 64, t_in
    assert 16 <= t_out <= 64, t_out


def test_eq9_sweep_optimum_is_32_both_axes():
    """The paper's Eq. 9/10 methodology yields T* = 32 on our calibration."""
    qs = alpaca_like(2000, seed=0)
    for axis in ("in", "out"):
        sweep = threshold_sweep(CFG, qs, EFF, PERF, axis=axis)
        assert optimal_threshold(sweep).threshold == 32, axis


def test_headline_savings_positive():
    """Hybrid at T=32 must beat every workload-unaware baseline (paper: 7.5%)."""
    qs = alpaca_like(2000, seed=1)
    hd = headline(CFG, qs, EFF, PERF, t_in=32, axis="in")
    assert hd.savings_vs_all_perf > 0.0
    assert hd.hybrid.total_energy_j < min(
        b.total_energy_j for b in hd.baselines.values()) * 1.001


def test_runtime_energy_tradeoff():
    """Paper Fig 4b: the energy savings cost runtime."""
    qs = alpaca_like(1000, seed=2)
    hd = headline(CFG, qs, EFF, PERF, t_in=32, axis="in")
    assert hd.runtime_penalty_frac_vs_all_perf > 0.0


def test_fig1b_throughput_roofline_shape():
    """Prefill token rate rises with input size then saturates at the compute
    roof (paper Fig 1b's roofline shape)."""
    from repro.core import query_phases

    def rate(m):
        ph = query_phases(CFG, m, 0, PERF)
        return m / (ph.t_prefill + ph.t_overhead)
    rates = [rate(m) for m in (8, 64, 512, 4096, 16384, 65536)]
    assert rates[1] > rates[0] and rates[2] > rates[1]
    # saturation: relative gain collapses at the roof
    assert rates[5] / rates[4] < 1.5 < rates[1] / rates[0]


def test_output_tokens_cost_more_than_input():
    """Section 5.5: adding output tokens costs more runtime than adding the
    same number of input tokens."""
    from repro.core import runtime
    r_in = runtime(CFG, 256, 32, PERF) - runtime(CFG, 32, 32, PERF)
    r_out = runtime(CFG, 32, 256, PERF) - runtime(CFG, 32, 32, PERF)
    assert r_out > r_in


def test_tpu_fleet_also_exhibits_crossover():
    """The TPU adaptation preserves the paper's phenomenon."""
    eff, perf = tpu_fleet()
    t = crossover_threshold(CFG, eff, perf, axis="in", hi=8192)
    assert 1 < t < 8192


@pytest.mark.parametrize("arch", list_archs())
def test_energy_model_covers_all_archs(arch):
    """Scheduler applicability (DESIGN §Arch-applicability): E/R computable
    and positive for every assigned architecture."""
    cfg = get_config(arch)
    e = energy(cfg, 64, 32, PERF)
    assert np.isfinite(e) and e > 0


def test_moe_decode_more_memory_bound_than_dense():
    """Active-FLOPs vs full-weight-streaming: MoE's decode crossover region
    is wider (lower utilization on the perf system)."""
    from repro.core.perf_model import query_phases
    moe = get_config("phi3.5-moe-42b-a6.6b")
    dense = get_config("deepseek-7b")
    u_moe = query_phases(moe, 32, 64, PERF).util_decode
    u_dense = query_phases(dense, 32, 64, PERF).util_decode
    assert u_moe < u_dense
