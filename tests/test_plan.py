"""Plan-IR conformance: every scheduler's dispatch/dispatch_rid must return
well-formed, finitely priced plans; the IR must JSON round-trip; the legacy
SystemProfile/tuple encodings must still coerce (one release, warning)."""
import json
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostOptimalScheduler,
                        DisaggregatedScheduler, FleetState, GlobalDispatcher,
                        PoolSnapshot, PoolSpec, Query, Region,
                        RoundRobinScheduler, SingleSystemScheduler,
                        ThresholdScheduler, WorkloadSpec, sample_workload)
from repro.core.carbon import CarbonAwareScheduler
from repro.core.plan import (DeferPlan, PlanTerms, RunPlan, SplitPlan,
                             as_plan, plan_from_json, plan_to_json)
from repro.core.settlement import resolve_plan
from repro.core.systems import SystemProfile, paper_fleet

CFG = get_config("qwen2.5-3b")
EFF, PERF = paper_fleet()
LINKED_EFF = SystemProfile(
    name="eff", kind="eff", chips=1, peak_flops=90e12, hbm_bw=0.8e12,
    ici_bw=50e9, power_peak_w=220.0, power_idle_w=8.0, overhead_s=0.02,
    sat_ctx=2048.0, link_bw_gbps=100.0)
LINKED_PERF = SystemProfile(
    name="perf", kind="perf", chips=2, peak_flops=200e12, hbm_bw=1.25e12,
    ici_bw=100e9, power_peak_w=350.0, power_idle_w=60.0, overhead_s=0.01,
    sat_ctx=None, link_bw_gbps=100.0)


def _all_schedulers():
    counts = {EFF.name: 2, PERF.name: 2}
    west = Region("w", {"eff": PoolSpec(EFF, instances=2, slots=2)})
    east = Region("e", {"perf": PoolSpec(PERF, instances=2, slots=2)})
    return [
        ("threshold", ThresholdScheduler(CFG, EFF, PERF, t_in=32)),
        ("cost_optimal", CostOptimalScheduler(CFG, [EFF, PERF])),
        ("capacity_aware", CapacityAwareScheduler(CFG, [EFF, PERF], counts)),
        ("disaggregated",
         DisaggregatedScheduler(CFG, [LINKED_EFF, LINKED_PERF])),
        ("single", SingleSystemScheduler(CFG, PERF)),
        ("round_robin", RoundRobinScheduler(CFG, [EFF, PERF])),
        ("carbon", CarbonAwareScheduler(CFG, [EFF, PERF])),
        ("carbon_defer", CarbonAwareScheduler(CFG, [EFF, PERF], defer=True)),
        ("global", GlobalDispatcher(CFG, [west, east])),
    ]


def _idle_fleet(sched):
    return FleetState(pools={s.name: PoolSnapshot(system=s, block_size=16)
                             for s in sched.systems})


def _check_well_formed(plan, sched, q):
    inner = plan.inner if isinstance(plan, DeferPlan) else plan
    assert isinstance(inner, (RunPlan, SplitPlan))
    names = {s.name for s in sched.systems}
    if isinstance(inner, SplitPlan):
        assert inner.pool_prefill in names and inner.pool_decode in names
        assert inner.pool_prefill != inner.pool_decode
        assert inner.mig_bytes > 0
    else:
        assert inner.pool in names
    t = plan.terms
    assert isinstance(t, PlanTerms), f"unpriced plan from {type(sched)}"
    assert math.isfinite(t.energy_j) and t.energy_j > 0
    assert math.isfinite(t.runtime_s) and t.runtime_s > 0
    assert math.isfinite(t.wait_s) and t.wait_s >= 0
    assert math.isfinite(t.cost)
    if isinstance(plan, DeferPlan):
        assert math.isfinite(plan.until_s)
    # resolve_plan must accept it silently (no warning, no coercion change)
    assert resolve_plan(plan, q, names) == plan


@pytest.mark.parametrize("name,sched", _all_schedulers())
def test_dispatch_returns_priced_plan(name, sched):
    """Every policy, both snapshot and snapshotless paths, across query
    shapes (interactive, prompt-heavy, batch-tier, zero-decode)."""
    fleet = _idle_fleet(sched)
    for q in (Query(16, 16, 0.0), Query(250, 50, 3600.0),
              Query(64, 512, 7200.0), Query(64, 0, 10.0)):
        for state in (fleet, None):
            _check_well_formed(sched.dispatch(q, state), sched, q)


@pytest.mark.parametrize("name,sched", _all_schedulers())
def test_dispatch_rid_matches_dispatch(name, sched):
    """Table-backed fast paths must price identically to scalar dispatch."""
    if not hasattr(sched, "prepare_batch"):
        pytest.skip("no batch tables")
    qs = sample_workload(40, seed=5, spec=WorkloadSpec(mu_in=5.0, mu_out=3.5))
    sched.prepare_batch(np.array([q.m for q in qs]),
                        np.array([q.n for q in qs]))
    fleet = _idle_fleet(sched)
    for rid, q in enumerate(qs):
        assert sched.dispatch_rid(rid, q, fleet) == sched.dispatch(q, fleet)


# --------------------------------------------------------------- IR mechanics
def test_json_round_trip_every_plan_kind():
    terms = PlanTerms(energy_j=1.5, runtime_s=0.25, wait_s=2.0, cost=0.75)
    plans = [
        RunPlan("eff"),
        RunPlan("perf", terms=terms),
        SplitPlan("perf", "eff", mig_bytes=4096.0, terms=terms),
        DeferPlan(1800.0, RunPlan("eff", terms=terms)),
        DeferPlan(900.0, SplitPlan("perf", "eff", mig_bytes=16.0)),
    ]
    for plan in plans:
        wire = json.dumps(plan_to_json(plan))       # truly serializable
        assert plan_from_json(json.loads(wire)) == plan


def test_defer_plans_do_not_nest():
    with pytest.raises(TypeError):
        DeferPlan(10.0, DeferPlan(5.0, RunPlan("eff")))
    with pytest.raises(ValueError):
        plan_from_json({"kind": "warp", "pool": "eff"})


def test_as_plan_coerces_legacy_encodings_with_warning():
    with pytest.warns(DeprecationWarning):
        assert as_plan(EFF) == RunPlan(EFF.name)
    with pytest.warns(DeprecationWarning):
        assert as_plan((PERF, EFF)) == SplitPlan(PERF.name, EFF.name)
    with pytest.raises(TypeError):
        as_plan("eff")                  # a bare string is NOT a profile
    # plans pass through silently and unchanged
    p = DeferPlan(3.0, RunPlan("eff"))
    assert as_plan(p) is p


def test_resolve_plan_validates_and_degrades():
    known = {"eff", "perf"}
    with pytest.raises(KeyError, match="unknown system"):
        resolve_plan(RunPlan("gone"), Query(8, 8), known)
    # zero-decode split degrades to a RunPlan on the prefill pool and only
    # that name is validated (historical engine semantics)
    got = resolve_plan(SplitPlan("perf", "gone"), Query(8, 0), known)
    assert got == RunPlan("perf")
    got = resolve_plan(DeferPlan(9.0, SplitPlan("perf", "eff")),
                       Query(8, 0), known)
    assert got == DeferPlan(9.0, RunPlan("perf"))
