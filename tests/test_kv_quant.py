"""int8 KV-cache quantization: correctness vs the f32 cache path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import attention as ATT
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def test_quantize_roundtrip_error_bounded():
    x = jax.random.normal(KEY, (2, 4, 32, 64)) * 3.0
    q, s = ATT.quantize_kv(x)
    back = ATT.dequantize_kv(q, s)
    rel = float(jnp.abs(back - x).max() / jnp.abs(x).max())
    assert q.dtype == jnp.int8
    assert rel < 1.0 / 64       # per-row symmetric int8: <=(1/127)*rowmax


def test_quantize_scale_shape_and_zero_rows():
    x = jnp.zeros((1, 2, 8, 16))
    q, s = ATT.quantize_kv(x)
    assert s.shape == (1, 2, 8, 1)
    assert bool(jnp.all(q == 0)) and bool(jnp.all(jnp.isfinite(s)))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "phi3.5-moe-42b-a6.6b"])
def test_int8_cache_matches_f32_cache(arch):
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, KEY)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 20), 0, cfg.vocab_size)
    c32 = M.init_cache(cfg, 2, 64)
    c8 = M.init_cache(cfg, 2, 64, kv_quant=True)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8
    l32, c32 = M.prefill(params, cfg, {"tokens": tok[:, :16]}, c32)
    l8, c8 = M.prefill(params, cfg, {"tokens": tok[:, :16]}, c8)
    # prefill logits identical (attention runs on fresh K/V, not the cache)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l32), atol=1e-5)
    for t in range(3):
        l32, c32 = M.decode_step(params, cfg, tok[:, 16 + t:17 + t], c32)
        l8, c8 = M.decode_step(params, cfg, tok[:, 16 + t:17 + t], c8)
        rel = float(jnp.abs(l8 - l32).max() / jnp.abs(l32).max())
        assert rel < 0.05, rel


def test_int8_cache_greedy_tokens_usually_match():
    """Greedy decode should pick the same tokens with the quantized cache."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, cfg.vocab_size)
    outs = {}
    for quant in (False, True):
        cache = M.init_cache(cfg, 1, 64, kv_quant=quant)
        lg, cache = M.prefill(params, cfg, {"tokens": tok}, cache)
        toks = []
        t = jnp.argmax(lg, -1).astype(jnp.int32)
        for _ in range(6):
            toks.append(int(t[0]))
            lg, cache = M.decode_step(params, cfg, t[:, None], cache)
            t = jnp.argmax(lg, -1).astype(jnp.int32)
        outs[quant] = toks
    agree = sum(a == b for a, b in zip(outs[False], outs[True]))
    assert agree >= 5, outs
