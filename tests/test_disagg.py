"""Disaggregated prefill/decode: priced KV migration, pair dispatch, dual
fleet-engine equivalence, and the serving handoff seam
(``migrate_kv_blocks`` / ``adopt_lane``)."""
import math

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (DisaggregatedScheduler, PoolSpec, Query, WorkloadSpec,
                        sample_workload, simulate_fleet)
from repro.core.plan import RunPlan, SplitPlan
from repro.core.pricing import CostModel, kv_bytes_per_token
from repro.core.scheduler import (FleetState, PoolSnapshot, Scheduler,
                                  kv_blocks_needed)
from repro.core.systems import SystemProfile

CFG = get_config("qwen2.5-3b")


def _systems(link=100.0):
    """The disagg probe pair (benchmarks/disagg_sweep.py): near-dark idle on
    the eff pool, fast high-idle prefill on the perf pool."""
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=90e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=220.0,
                        power_idle_w=8.0, overhead_s=0.02, sat_ctx=2048.0,
                        link_bw_gbps=link)
    perf = SystemProfile(name="perf", kind="perf", chips=2, peak_flops=200e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=60.0, overhead_s=0.01, sat_ctx=None,
                         link_bw_gbps=link)
    return eff, perf


def _idle_fleet(*systems):
    return FleetState(pools={s.name: PoolSnapshot(system=s, block_size=16)
                             for s in systems})


# ---------------------------------------------------------------- pricing
def test_migration_terms_pricing():
    eff, perf = _systems(link=100.0)
    model = CostModel(CFG)
    m, bs = 250, 16
    nbytes, t_s, e_j = model.migration_terms(m, perf, eff, block_size=bs)
    blocks = kv_blocks_needed(m, bs)
    assert nbytes == blocks * bs * kv_bytes_per_token(CFG)
    # link transfer + gather at the source + scatter at the destination
    expect = (nbytes / (100.0 * 0.125e9)
              + nbytes / (perf.instance_hbm_bw * perf.mem_eff)
              + nbytes / (eff.instance_hbm_bw * eff.mem_eff))
    assert t_s == pytest.approx(expect)
    assert e_j == pytest.approx(t_s * (perf.power(0.0) + eff.power(0.0)))
    # token-granular when the serving side reports no block size
    nb0, _, _ = model.migration_terms(m, perf, eff, block_size=0)
    assert nb0 == m * kv_bytes_per_token(CFG)


def test_migration_seconds_inf_without_link():
    eff, perf = _systems(link=0.0)
    model = CostModel(CFG)
    assert math.isinf(model.migration_seconds(1e6, eff, perf))


# --------------------------------------------------------------- scheduler
def test_dispatch_returns_split_plan_for_prompt_heavy_query():
    eff, perf = _systems()
    sched = DisaggregatedScheduler(CFG, [eff, perf])
    got = sched.dispatch(Query(250, 50, 0.0), _idle_fleet(eff, perf))
    assert isinstance(got, SplitPlan)
    assert (got.pool_prefill, got.pool_decode) == (perf.name, eff.name)
    assert got.mig_bytes > 0 and got.terms is not None
    # workload-only fallback (no queue state) never splits
    assert isinstance(sched.dispatch(Query(250, 50, 0.0), None), RunPlan)


def test_dispatch_never_pairs_without_decode_or_link():
    eff, perf = _systems()
    sched = DisaggregatedScheduler(CFG, [eff, perf])
    fleet = _idle_fleet(eff, perf)
    assert isinstance(sched.dispatch(Query(250, 0, 0.0), fleet), RunPlan)
    eff0, perf0 = _systems(link=0.0)
    sched0 = DisaggregatedScheduler(CFG, [eff0, perf0])
    got = sched0.dispatch(Query(250, 50, 0.0), _idle_fleet(eff0, perf0))
    assert isinstance(got, RunPlan)         # zero link: no NaN, no split


def test_dispatch_rid_matches_scalar_dispatch():
    eff, perf = _systems()
    sched = DisaggregatedScheduler(CFG, [eff, perf])
    qs = sample_workload(60, seed=3,
                         spec=WorkloadSpec(mu_in=5.0, mu_out=3.5))
    m = np.array([q.m for q in qs])
    n = np.array([q.n for q in qs])
    sched.prepare_batch(m, n)
    fleet = _idle_fleet(eff, perf)
    for rid, q in enumerate(qs):
        assert sched.dispatch_rid(rid, q, fleet) == sched.dispatch(q, fleet)


# ------------------------------------------------------- fleet-sim equivalence
def _disagg_pools(eff, perf):
    return {"eff": PoolSpec(eff, instances=4, slots=4, kv_blocks=4096),
            "perf": PoolSpec(perf, instances=4, slots=4, kv_blocks=4096)}


@pytest.mark.parametrize("seed,disc", [(0, "fifo"), (1, "sjf")])
def test_fleet_engines_bit_identical_under_splits(seed, disc):
    eff, perf = _systems()
    qs = sample_workload(160, seed=seed,
                         spec=WorkloadSpec(mu_in=5.5, sigma_in=0.7,
                                           mu_out=4.0, sigma_out=0.8,
                                           rate_qps=20.0),
                         arrival_process="diurnal")
    runs = {}
    for engine in ("event", "vectorized"):
        runs[engine] = simulate_fleet(
            CFG, qs, _disagg_pools(eff, perf),
            DisaggregatedScheduler(CFG, [eff, perf]),
            queue_discipline=disc, engine=engine)
    se, sv = runs["event"].summary(), runs["vectorized"].summary()
    assert se == sv, {k: (se[k], sv[k]) for k in se if se[k] != sv[k]}
    te = [(x.rid, x.pool, x.pool_decode, x.t_arrival, x.t_start, x.t_decode,
           x.t_done, x.energy_j, x.mig_bytes) for x in runs["event"].records]
    tv = [(x.rid, x.pool, x.pool_decode, x.t_arrival, x.t_start, x.t_decode,
           x.t_done, x.energy_j, x.mig_bytes)
          for x in runs["vectorized"].records]
    assert te == tv
    assert any(x[2] for x in te), "probe config stopped splitting"


def test_no_link_means_no_splits_and_no_migration():
    eff, perf = _systems(link=0.0)
    qs = sample_workload(60, seed=0,
                         spec=WorkloadSpec(mu_in=5.5, mu_out=4.0,
                                           rate_qps=20.0))
    r = simulate_fleet(CFG, qs, _disagg_pools(eff, perf),
                       DisaggregatedScheduler(CFG, [eff, perf]))
    assert r.mig_bytes == 0.0
    assert all(rec.pool_decode == "" and rec.mig_bytes == 0.0
               for rec in r.records)


class _AlwaysPair(Scheduler):
    """Degenerate LEGACY policy: returns a raw (a, b) profile tuple for EVERY
    query — exercises the one-release deprecation shim (``as_plan``) AND the
    engines' n<=0 degradation to single-pool prefill with no handoff."""

    def choose(self, q):
        return self.systems[0]

    def dispatch(self, q, fleet=None):
        return (self.systems[1], self.systems[0])


def test_zero_decode_query_degrades_tuple_to_single_pool():
    eff, perf = _systems()
    qs = [Query(64, 0, 0.0), Query(32, 0, 0.1)]
    for engine in ("event", "vectorized"):
        r = simulate_fleet(CFG, qs, _disagg_pools(eff, perf),
                           _AlwaysPair(CFG, [eff, perf]), engine=engine)
        assert all(rec.pool == "perf" and rec.pool_decode == ""
                   and rec.mig_bytes == 0.0 for rec in r.records)


# ------------------------------------------------------------- percentiles
def test_ttft_tpot_percentiles_and_summary_keys():
    eff, perf = _systems()
    qs = sample_workload(80, seed=2,
                         spec=WorkloadSpec(mu_in=5.5, mu_out=4.0,
                                           rate_qps=20.0))
    r = simulate_fleet(CFG, qs, _disagg_pools(eff, perf),
                       DisaggregatedScheduler(CFG, [eff, perf]))
    recs = r.records
    ttft = np.array([x.t_decode - x.t_arrival for x in recs])
    tpot = np.array([(x.t_done - x.t_decode) / max(1, q.n)
                     for x, q in zip(recs, qs)])
    assert r.ttft_percentile(100.0) == pytest.approx(ttft.max())
    assert r.ttft_percentile(0.0) == pytest.approx(ttft.min())
    assert r.ttft_percentile(99.0) == pytest.approx(
        float(np.percentile(ttft, 99.0)))
    assert r.tpot_percentile(50.0) == pytest.approx(
        float(np.percentile(tpot, 50.0)))
    assert r.p99_ttft_s == r.ttft_percentile(99.0)
    s = r.summary()
    assert s["p99_ttft_s"] == r.p99_ttft_s
    assert s["mig_bytes"] == r.mig_bytes == pytest.approx(
        sum(x.mig_bytes for x in recs))
    assert r.mig_bytes > 0.0             # the probe config splits


# ------------------------------------------------------------ serving handoff
@pytest.fixture(scope="module")
def engine():
    from repro.models import model as M
    from repro.serving.engine import InferenceEngine
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    return InferenceEngine(cfg, params, max_len=96)


def test_migrate_kv_blocks_copies_not_steals(engine):
    import jax.numpy as jnp
    from repro.serving.batching import migrate_kv_blocks
    cfg = engine.cfg
    src = engine.new_paged_cache(2, 8, 4)
    dst = engine.new_paged_cache(2, 8, 4)
    src = dict(src, kp=src["kp"].at[:, 1:3].set(1.5),
               vp=src["vp"].at[:, 1:3].set(-2.5))
    dst2, moved = migrate_kv_blocks(src, [1, 2], dst, [3, 4])
    np.testing.assert_array_equal(np.asarray(dst2["kp"][:, 3:5]),
                                  np.asarray(src["kp"][:, 1:3]))
    np.testing.assert_array_equal(np.asarray(dst2["vp"][:, 3:5]),
                                  np.asarray(src["vp"][:, 1:3]))
    assert float(jnp.sum(jnp.abs(dst2["kp"][:, :3]))) == 0.0  # others untouched
    # source unchanged (copy, not steal)
    assert float(src["kp"][0, 1, 0, 0, 0]) == 1.5
    per_block = 2 * cfg.num_layers * cfg.num_kv_heads * 4 * \
        cfg.resolved_head_dim * 4
    assert moved == 2 * per_block
    same, zero = migrate_kv_blocks(src, [], dst, [])
    assert zero == 0 and same is dst
    with pytest.raises(ValueError):
        migrate_kv_blocks(src, [1, 2], dst, [3])
    with pytest.raises(ValueError):          # block-size mismatch
        migrate_kv_blocks(src, [1], engine.new_paged_cache(2, 8, 8), [1])


def _disagg_router(engine, *, dst_blocks=48):
    from repro.core.pricing import CostParams
    from repro.serving.router import FleetRouter
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=5e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=120.0,
                        power_idle_w=8.0, overhead_s=0.02, sat_ctx=2048.0,
                        link_bw_gbps=400.0)
    perf = SystemProfile(name="perf", kind="perf", chips=4, peak_flops=400e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=100.0, overhead_s=0.0005,
                         link_bw_gbps=400.0)
    # price with the UNREDUCED config: the reduced test model's decode is too
    # small for any split plan to beat migration
    pricing = CostModel(get_config("smollm-360m"), None, CostParams(lam=1.0))
    router = FleetRouter(engine.cfg, {"eff": eff, "perf": perf},
                         {"eff": engine, "perf": engine},
                         policy="disaggregated", model=pricing)
    router.attach_batchers(slots=2, paged=True, num_blocks=48, block_size=8,
                           chunk=8)
    return router


def test_disagg_router_token_parity_across_handoff(engine):
    import jax.numpy as jnp
    router = _disagg_router(engine)
    prompts = [np.arange(40 + 7 * i) % engine.cfg.vocab_size for i in range(3)]
    routed = [router.submit(p, 6) for p in prompts]
    assert router._handoffs, "expected split plans from the pricing probe"
    assert all(rr.request.hold for rr in routed)
    router.drain()
    assert not router._handoffs
    for rr, p in zip(routed, prompts):
        assert rr.request.done and not rr.request.hold
        solo = engine.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 6)
        np.testing.assert_array_equal(np.asarray(rr.request.out_tokens[:6]),
                                      solo.tokens[0])
    # query counted at its prefill pool; decode tokens booked at the decode
    # pool; every block returned on both ends
    rep = router.fleet_report()
    assert rep["perf"]["queries"] == 3 and rep["eff"]["queries"] == 0
    assert rep["eff"]["tokens"] == 18 and rep["eff"]["energy_j"] > 0
    for cb in router.batchers.values():
        assert all(r is None for r in cb.active) and not cb.queue
        evictable = sum(1 for b in cb.prefix._map.values()
                        if cb.allocator.refcount[b] == 1)
        assert cb.allocator.free_blocks + evictable == cb.allocator.total_blocks


def test_adopt_lane_prefix_shared_blocks_survive_handoff(engine):
    """Copy-not-steal: a handed-off lane's prompt blocks may be shared via
    the PrefixBlockCache — migration must leave them serving the source
    pool."""
    import jax.numpy as jnp
    from repro.serving.batching import PagedContinuousBatcher, Request
    src = PagedContinuousBatcher(engine, slots=2, num_blocks=32, block_size=8,
                                 chunk=8)
    dst = PagedContinuousBatcher(engine, slots=2, num_blocks=32, block_size=8,
                                 chunk=8)
    prompt = np.arange(24) % engine.cfg.vocab_size
    held = Request(1, prompt, 5, hold=True)
    twin = Request(2, prompt.copy(), 5)          # same prefix, decodes at src
    src.submit(held)
    src.submit(twin)
    for _ in range(10):                          # prefill both; held waits
        src.step()
        if held.out_tokens and src._lane[0] is not None \
                and src._lane[0].prefilled >= len(prompt):
            break
    assert held.out_tokens and not held.done
    src_i = src.active.index(held)
    shared_before = src.prefix.hits
    moved = dst.adopt_lane(held, src, src_i)
    assert moved and moved > 0
    src.release_lane(src_i)
    src.run()                                    # twin finishes on src
    dst.run()                                    # held finishes on dst
    assert held.done and twin.done
    solo = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 5)
    np.testing.assert_array_equal(np.asarray(held.out_tokens[:5]),
                                  solo.tokens[0])
    np.testing.assert_array_equal(np.asarray(twin.out_tokens[:5]),
                                  solo.tokens[0])
    assert src.prefix.hits >= shared_before      # prefix entries survived
    for cb in (src, dst):
        evictable = sum(1 for b in cb.prefix._map.values()
                        if cb.allocator.refcount[b] == 1)
        assert cb.allocator.free_blocks + evictable == cb.allocator.total_blocks


def test_adopt_lane_block_starved_target_retries(engine):
    """A migration racing admission on a block-starved target must wait (no
    partial copy) and succeed once the target frees blocks."""
    import jax.numpy as jnp
    from repro.serving.batching import PagedContinuousBatcher, Request
    src = PagedContinuousBatcher(engine, slots=1, num_blocks=32, block_size=8,
                                 chunk=8, prefix_sharing=False)
    dst = PagedContinuousBatcher(engine, slots=1, num_blocks=8, block_size=8,
                                 chunk=8, prefix_sharing=False)
    hog = Request(9, np.arange(40) % engine.cfg.vocab_size, 3)
    dst.submit(hog)
    dst.step()                                   # hog takes 6 of 7 blocks
    held = Request(1, np.arange(16) % engine.cfg.vocab_size, 4, hold=True)
    src.submit(held)
    while not held.out_tokens:
        src.step()
    src_i = src.active.index(held)
    assert dst.adopt_lane(held, src, src_i) is None   # starved: no partial copy
    assert src.active[src_i] is held and held.hold    # source lane untouched
    dst.run()                                         # hog retires, frees blocks
    assert hog.done
    moved = dst.adopt_lane(held, src, src_i)
    assert moved and moved > 0
    src.release_lane(src_i)
    dst.run()
    assert held.done
    solo = engine.generate(
        {"tokens": jnp.asarray(held.tokens, jnp.int32)[None]}, 4)
    np.testing.assert_array_equal(np.asarray(held.out_tokens[:4]),
                                  solo.tokens[0])
