"""Discrete-event fleet simulator: determinism, the zero-load reduction to
static accounting, queueing/batching dynamics, arrival processes, and the
dispatch-API contract shared by all schedulers."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostOptimalScheduler,
                        FleetSimulator, FleetState, PoolSpec, Query,
                        RoundRobinScheduler, SingleSystemScheduler,
                        ThresholdScheduler, WorkloadSpec, diurnal_arrivals,
                        energy, generate_arrivals, mmpp_arrivals, paper_fleet,
                        poisson_arrivals, runtime, sample_workload, simulate,
                        simulate_fleet, threshold_sweep, trace_arrivals)
from repro.core.pricing import normalized_cost_params

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()


# ---------------------------------------------------------- arrival processes
@pytest.mark.parametrize("process", ["poisson", "diurnal", "mmpp"])
def test_arrivals_sorted_positive_deterministic(process):
    a1 = generate_arrivals(200, 2.0, seed=3, process=process)
    a2 = generate_arrivals(200, 2.0, seed=3, process=process)
    np.testing.assert_array_equal(a1, a2)          # deterministic under seed
    assert len(a1) == 200
    assert np.all(a1 > 0)
    assert np.all(np.diff(a1) >= 0)                # nondecreasing
    a3 = generate_arrivals(200, 2.0, seed=4, process=process)
    assert not np.array_equal(a1, a3)              # seed actually matters


@pytest.mark.parametrize("process", ["poisson", "diurnal", "mmpp"])
def test_arrivals_mean_rate(process):
    a = generate_arrivals(5000, 4.0, seed=0, process=process)
    rate = len(a) / a[-1]
    assert 0.7 * 4.0 <= rate <= 1.4 * 4.0          # long-run mean ~ rate_qps


def test_mmpp_is_burstier_than_poisson():
    """MMPP inter-arrival coefficient of variation must exceed Poisson's ~1."""
    gaps_p = np.diff(poisson_arrivals(5000, 2.0, seed=1))
    gaps_m = np.diff(mmpp_arrivals(5000, 2.0, seed=1))
    cv = lambda g: np.std(g) / np.mean(g)
    assert cv(gaps_m) > cv(gaps_p) * 1.2


def test_diurnal_amplitude_validation():
    with pytest.raises(ValueError):
        diurnal_arrivals(10, 1.0, amplitude=1.5)


def test_trace_replay():
    a = trace_arrivals([3.0, 1.0, 2.0])
    np.testing.assert_array_equal(a, [1.0, 2.0, 3.0])
    with pytest.raises(ValueError):
        trace_arrivals([-1.0, 2.0])
    with pytest.raises(ValueError):
        generate_arrivals(5, 1.0, process="trace", trace=[1.0, 2.0])
    with pytest.raises(ValueError):
        generate_arrivals(5, 1.0, process="nope")


def test_sample_workload_arrival_process_plumbs_through():
    qs = sample_workload(50, seed=0, spec=WorkloadSpec(rate_qps=2.0),
                        arrival_process="mmpp")
    assert len(qs) == 50
    assert all(q.arrival_s >= 0 for q in qs)


# --------------------------------------------------------------- fleet sim
def _pools(n_eff=2, n_perf=2, slots_eff=1, slots_perf=1):
    return {"eff": PoolSpec(EFF, n_eff, slots_eff),
            "perf": PoolSpec(PERF, n_perf, slots_perf)}


def test_zero_load_reduces_to_static_simulate():
    """Infinite capacity + negligible rate: event-driven totals == static
    per-query accounting (the acceptance bar: relative error < 1e-6)."""
    qs = sample_workload(40, seed=3, spec=WorkloadSpec(rate_qps=1e-3))
    sched = ThresholdScheduler(CFG, EFF, PERF, t_in=32)
    static = simulate(CFG, qs, sched)
    res = simulate_fleet(CFG, qs, _pools(len(qs), len(qs)), sched)
    rel = abs(res.total_energy_j - static.total_energy_j) / static.total_energy_j
    assert rel < 1e-6
    # per-request service time equals the static runtime too
    assert sum(r.service_s for r in res.records) == pytest.approx(
        static.total_runtime_s, rel=1e-6)
    assert res.mean_wait_s == 0.0


def test_fleet_sim_deterministic():
    qs = sample_workload(120, seed=7, spec=WorkloadSpec(rate_qps=3.0),
                        arrival_process="mmpp")
    r1 = simulate_fleet(CFG, qs, _pools(2, 1, 2, 4),
                        ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    r2 = simulate_fleet(CFG, qs, _pools(2, 1, 2, 4),
                        ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    assert r1.total_energy_j == r2.total_energy_j
    assert r1.p99_latency_s == r2.p99_latency_s
    assert [r.t_done for r in r1.records] == [r.t_done for r in r2.records]


def test_every_request_completes_and_invariants_hold():
    qs = sample_workload(100, seed=5, spec=WorkloadSpec(rate_qps=5.0),
                        arrival_process="mmpp")
    res = simulate_fleet(CFG, qs, _pools(2, 1, 2, 2),
                        CostOptimalScheduler(CFG, [EFF, PERF]))
    assert len(res.records) == len(qs)
    for r in res.records:
        assert r.t_done > r.t_start >= r.t_arrival
        assert r.wait_s >= 0 and r.energy_j > 0
    for p in res.per_pool.values():
        assert 0.0 <= p.utilization <= 1.0
    assert res.fleet_energy_j >= res.total_energy_j


def test_finite_capacity_creates_queueing():
    """A tight fleet under load must show nonzero waits; an ample fleet with
    the same workload must not."""
    qs = sample_workload(60, seed=2, spec=WorkloadSpec(rate_qps=8.0))
    sched = SingleSystemScheduler(CFG, PERF)
    tight = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 1)}, sched)
    ample = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 60, 1)}, sched)
    assert tight.mean_wait_s > 0
    assert ample.mean_wait_s == 0
    assert tight.p99_latency_s > ample.p99_latency_s


def test_batching_shares_decode_and_raises_throughput():
    """More slots per instance = decode weight-streaming amortized across
    co-resident requests: same instance count must finish sooner."""
    qs = sample_workload(60, seed=9, spec=WorkloadSpec(rate_qps=6.0))
    sched = SingleSystemScheduler(CFG, PERF)
    solo = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 1)}, sched)
    batched = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 8)}, sched)
    assert batched.horizon_s < solo.horizon_s
    assert batched.p99_latency_s < solo.p99_latency_s


def test_sjf_priority_queue_beats_fifo_on_median_wait():
    spec = WorkloadSpec(rate_qps=6.0)
    qs = sample_workload(80, seed=11, spec=spec)
    sched = SingleSystemScheduler(CFG, PERF)
    pools = {"perf": PoolSpec(PERF, 1, 1)}
    fifo = simulate_fleet(CFG, qs, pools, sched, queue_discipline="fifo")
    sjf = simulate_fleet(CFG, qs, pools, sched, queue_discipline="sjf")
    assert sjf.latency_percentile(50) <= fifo.latency_percentile(50)
    with pytest.raises(ValueError):
        FleetSimulator(CFG, pools, sched, queue_discipline="lifo")


def test_dispatch_api_uniform_across_policies():
    """Every scheduler must dispatch through the same online API."""
    cp = normalized_cost_params(CFG, PERF, lam=0.5)
    schedulers = [
        ThresholdScheduler(CFG, EFF, PERF, t_in=32),
        CostOptimalScheduler(CFG, [EFF, PERF]),
        CapacityAwareScheduler(CFG, [EFF, PERF],
                               {EFF.name: 2, PERF.name: 1}, cp),
        RoundRobinScheduler(CFG, [EFF, PERF]),
        SingleSystemScheduler(CFG, PERF),
    ]
    qs = sample_workload(30, seed=1, spec=WorkloadSpec(rate_qps=4.0))
    for sched in schedulers:
        res = simulate_fleet(CFG, qs, _pools(2, 2), sched)
        assert len(res.records) == len(qs)
        assert all(r.pool in ("eff", "perf") for r in res.records)


def test_capacity_aware_beats_threshold_under_burst():
    """Acceptance: under bursty MMPP arrivals the queue-aware policy wins
    p99 latency at equal-or-lower fleet energy (idle-inclusive)."""
    qs = sample_workload(150, seed=7, spec=WorkloadSpec(rate_qps=3.0),
                        arrival_process="mmpp")
    pools = {"eff": PoolSpec(EFF, 4, 2), "perf": PoolSpec(PERF, 2, 4)}
    cp = normalized_cost_params(CFG, PERF, lam=0.9)
    thr = simulate_fleet(CFG, qs, pools,
                         ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    cap = simulate_fleet(CFG, qs, pools,
                         CapacityAwareScheduler(CFG, [EFF, PERF],
                                                {EFF.name: 4, PERF.name: 2}, cp))
    assert cap.p99_latency_s < thr.p99_latency_s
    assert cap.fleet_energy_j <= thr.fleet_energy_j


def test_capacity_aware_dispatch_reads_fleet_state():
    """dispatch() must react to observed queue pressure: with the eff pool
    backed up, a query that would statically go eff spills to perf."""
    from repro.core import PoolSnapshot
    cp = normalized_cost_params(CFG, PERF, lam=0.0)   # pure latency
    sched = CapacityAwareScheduler(CFG, [EFF, PERF],
                                   {EFF.name: 1, PERF.name: 1}, cp)
    q = Query(8, 8)
    idle_choice = sched.dispatch(q, FleetState(pools={
        "eff": PoolSnapshot(system=EFF, est_wait_s=0.0),
        "perf": PoolSnapshot(system=PERF, est_wait_s=0.0)}))
    # small query, no queues: the faster system wins under pure latency
    fast = min((EFF, PERF), key=lambda s: runtime(CFG, q.m, q.n, s))
    assert idle_choice.pool == fast.name
    # back up only the fast pool: the query must spill to the other one
    one_sided = FleetState(pools={
        fast.name: PoolSnapshot(system=fast, est_wait_s=1e4, queue_len=50),
        (PERF if fast is EFF else EFF).name: PoolSnapshot(
            system=PERF if fast is EFF else EFF, est_wait_s=0.0)})
    spilled = sched.dispatch(q, one_sided)
    assert spilled.pool != fast.name


# ----------------------------------------------------------- KV block capacity
def test_block_capacity_bounds_occupancy():
    """KV memory smaller than slots x max_len: concurrent residents are
    bounded by blocks (not the slot count) and the queue still drains."""
    qs = [Query(64, 64, i * 0.01) for i in range(20)]
    sched = SingleSystemScheduler(CFG, PERF)
    # each query needs ceil(128/16) = 8 blocks; 16 per instance -> 2 residents
    res = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 8, kv_blocks=16,
                                                    block_size=16)}, sched)
    assert len(res.records) == 20
    assert all(r.t_done > r.t_start for r in res.records)
    assert res.per_pool["perf"].peak_residents <= 2
    # same fleet without the block cap saturates the slots instead
    res2 = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 8)},
                          SingleSystemScheduler(CFG, PERF))
    assert res2.per_pool["perf"].peak_residents > 2


def test_block_capacity_zero_load_matches_static():
    """Ample blocks + ample instances: the block-capacity path must not
    perturb the zero-load reduction to static accounting."""
    qs = sample_workload(40, seed=3, spec=WorkloadSpec(rate_qps=1e-3))
    sched = ThresholdScheduler(CFG, EFF, PERF, t_in=32)
    static = simulate(CFG, qs, sched)
    pools = {"eff": PoolSpec(EFF, len(qs), 1, kv_blocks=4096, block_size=16),
             "perf": PoolSpec(PERF, len(qs), 1, kv_blocks=4096, block_size=16)}
    res = simulate_fleet(CFG, qs, pools, sched)
    rel = abs(res.total_energy_j - static.total_energy_j) / static.total_energy_j
    assert rel < 1e-9
    assert res.mean_wait_s == 0.0


def test_block_capacity_oversized_query_raises():
    sched = SingleSystemScheduler(CFG, PERF)
    with pytest.raises(ValueError):
        simulate_fleet(CFG, [Query(400, 400)],
                       {"perf": PoolSpec(PERF, 1, 1, kv_blocks=4,
                                         block_size=16)}, sched)


def test_snapshot_reports_block_state_and_dispatch_prices_it():
    """The simulator's PoolSnapshot must expose block occupancy, and the
    capacity-aware policy must spill away from a memory-starved pool even
    when its slots are free."""
    from dataclasses import replace
    from repro.core import PoolSnapshot
    fast = replace(PERF, name="twin-fast")
    slow = replace(PERF, name="twin-slow", overhead_s=PERF.overhead_s * 1.5)
    cp = normalized_cost_params(CFG, fast, lam=0.0)     # pure latency
    sched = CapacityAwareScheduler(CFG, [fast, slow],
                                   {fast.name: 1, slow.name: 1}, cp)
    q = Query(32, 32)
    assert runtime(CFG, q.m, q.n, fast) < runtime(CFG, q.m, q.n, slow)
    # fast pool: free slots, zero free blocks -> must spill to the other
    starved = FleetState(pools={
        fast.name: PoolSnapshot(system=fast, slots_per_instance=8,
                                free_blocks=0, total_blocks=32,
                                block_size=16),
        slow.name: PoolSnapshot(system=slow, free_blocks=32, total_blocks=32,
                                block_size=16)})
    assert sched.dispatch(q, starved).pool == slow.name
    # with blocks available the fast pool wins again
    roomy = FleetState(pools={
        fast.name: PoolSnapshot(system=fast, free_blocks=32, total_blocks=32,
                                block_size=16),
        slow.name: PoolSnapshot(system=slow, free_blocks=32, total_blocks=32,
                                block_size=16)})
    assert sched.dispatch(q, roomy).pool == fast.name
    # and the simulator populates the fields end to end, in PER-INSTANCE
    # admission terms: a request lands on one instance, so 2 instances with
    # 64 blocks each report 64 free, not 128 — otherwise a query too big for
    # any single instance would price as admissible
    sim = FleetSimulator(CFG, {"perf": PoolSpec(PERF, 2, 2, kv_blocks=64,
                                                block_size=16)},
                         SingleSystemScheduler(CFG, PERF))
    snap = sim._fleet_state(0.0).pools["perf"]
    assert snap.total_blocks == 64 and snap.free_blocks == 64
    assert snap.block_size == 16
    assert snap.blocks_needed(48, 16) == 4
    assert snap.mem_wait_s(16 * 65, 0, 1.0) > 0.0   # 65 blocks > one instance


# ------------------------------------------------------- satellite regressions
def test_threshold_sweep_out_axis_default_caps_at_512():
    """The docstring's 512-token M1 output cap must actually bound the
    default threshold list on axis='out' (the dead-`hi` fix)."""
    qs = [Query(16, 700), Query(32, 40)]
    sweep = threshold_sweep(CFG, qs, EFF, PERF, axis="out")
    assert max(p.threshold for p in sweep) == 512
    sweep_in = threshold_sweep(CFG, qs, EFF, PERF, axis="in")
    assert max(p.threshold for p in sweep_in) == 2048
