"""Paged-KV serving runtime: block-table cache correctness, dense/paged
parity, chunked prefill, prefix sharing, and memory-aware admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.batching import (BlockAllocator, ContinuousBatcher,
                                    PagedContinuousBatcher, PrefixBlockCache,
                                    Request)
from repro.serving.engine import InferenceEngine

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, KEY)
    return InferenceEngine(cfg, params, max_len=96)


@pytest.fixture(scope="module")
def moe_engine():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    params = M.init_params(cfg, KEY)
    return InferenceEngine(cfg, params, max_len=96)


def _requests(cfg, n=5, budget=6):
    prompts = [np.arange(4 + 3 * i) % cfg.vocab_size for i in range(n)]
    return [Request(i, p, max_new_tokens=budget) for i, p in enumerate(prompts)]


# ------------------------------------------------------------------ unit level
def test_init_paged_cache_shapes_and_guards():
    cfg = get_config("smollm-360m").reduced()
    c = M.init_paged_cache(cfg, lanes=3, num_blocks=10, block_size=8,
                           max_blocks_per_lane=4)
    hd = cfg.resolved_head_dim
    assert c["kp"].shape == (cfg.num_layers, 10, cfg.num_kv_heads, 8, hd)
    assert c["vp"].shape == c["kp"].shape
    assert c["block_tables"].shape == (3, 4)
    assert int(c["block_tables"].max()) == M.NULL_BLOCK
    assert c["pos"].shape == (3,)
    cq = M.init_paged_cache(cfg, 2, 6, 8, kv_quant=True)
    assert cq["kp"].dtype == jnp.int8 and cq["kp_scale"].shape[-1] == 1
    with pytest.raises(ValueError):
        M.init_paged_cache(get_config("mamba2-130m").reduced(), 2, 6, 8)
    with pytest.raises(ValueError):
        M.init_paged_cache(cfg, 2, 1, 8)      # null block needs company


def test_block_allocator_refcounts():
    a = BlockAllocator(6)                      # 5 usable, block 0 reserved
    assert a.total_blocks == 5 and a.free_blocks == 5
    got = a.alloc(3)
    assert got is not None and M.NULL_BLOCK not in got
    assert a.free_blocks == 2 and a.used_blocks == 3
    assert a.alloc(3) is None                  # doesn't fit -> no side effects
    assert a.free_blocks == 2
    a.incref(got[:1])                          # shared block: 2 refs
    a.decref(got)                              # request retires
    assert a.free_blocks == 4                  # shared one still held
    a.decref(got[:1])
    assert a.free_blocks == 5
    with pytest.raises(ValueError):
        a.decref(got[:1])                      # double free


def test_prefix_cache_match_register_evict():
    a = BlockAllocator(10)
    pc = PrefixBlockCache(a)
    prompt = np.arange(20)
    blocks = a.alloc(3)
    # register the first two full 8-token blocks as written
    pc.register(prompt, 8, blocks, 0, 2)
    assert a.refcount[blocks[0]] == 2          # owner + cache pin
    hit = pc.match(prompt, 8)
    assert hit == blocks[:2]                   # longest chain, capped at (m-1)//bs
    a.decref(hit)
    # different prompt: no hit
    assert pc.match(np.arange(20) + 1, 8) == []
    # release the owner; eviction can now reclaim the pinned blocks
    a.decref(blocks)
    free_before = a.free_blocks
    pc.evict(a.free_blocks + 2)
    assert a.free_blocks == free_before + 2


def test_prefix_cache_evicts_deepest_first():
    """Eviction must drop the deepest chain entries first: releasing a
    shallow key would orphan its descendants (match stops at the first miss)
    while they stay pinned."""
    a = BlockAllocator(5)                      # 4 usable
    pc = PrefixBlockCache(a)
    prompt = np.arange(24)
    blocks = a.alloc(3)
    pc.register(prompt, 8, blocks, 0, 3)
    a.decref(blocks)                           # only cache pins remain
    pc.evict(a.free_blocks + 1)                # reclaim one block
    hit = pc.match(prompt, 8)                  # cap: (24-1)//8 = 2 blocks
    assert hit == blocks[:2]                   # shallow chain still usable
    a.decref(hit)


# -------------------------------------------------------------- parity (dense)
def _run_pair(engine, reqs_dense, reqs_paged, slots=2, **paged_kw):
    dense = ContinuousBatcher(engine, slots=slots)
    for r in reqs_dense:
        dense.submit(r)
    dense.run()
    paged = PagedContinuousBatcher(engine, slots=slots, **paged_kw)
    for r in reqs_paged:
        paged.submit(r)
    paged.run()
    return paged


def test_paged_matches_dense_budget_capped(engine):
    a = _requests(engine.cfg)
    b = _requests(engine.cfg)
    paged = _run_pair(engine, a, b, num_blocks=48, block_size=8, chunk=8)
    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out_tokens == rb.out_tokens
    assert paged.allocator.free_blocks == paged.total_blocks - \
        paged._evictable()                     # only prefix pins outstanding


def test_paged_matches_dense_eos(engine):
    """EOS-aware retirement: same early stop on both runtimes, and the paged
    side releases the retired request's blocks."""
    prompt = np.arange(8) % engine.cfg.vocab_size
    free = engine.generate({"tokens": jnp.asarray(prompt, jnp.int32)[None]}, 8)
    eos = int(free.tokens[0][2])
    a = [Request(0, prompt, 8, eos_id=eos)]
    b = [Request(0, prompt, 8, eos_id=eos)]
    paged = _run_pair(engine, a, b, num_blocks=32, block_size=8, chunk=8)
    assert a[0].out_tokens == b[0].out_tokens
    assert len(b[0].out_tokens) <= 3
    st = paged.stats()
    assert st["free_blocks"] + paged._evictable() == st["total_blocks"]


def test_paged_matches_dense_moe_family(moe_engine):
    a = _requests(moe_engine.cfg, n=4, budget=5)
    b = _requests(moe_engine.cfg, n=4, budget=5)
    _run_pair(moe_engine, a, b, num_blocks=48, block_size=8, chunk=8)
    for ra, rb in zip(a, b):
        assert ra.done and rb.done
        assert ra.out_tokens == rb.out_tokens


def test_paged_single_slot(engine):
    """slots=1: the whole loop is sequential admission; parity must hold."""
    a = _requests(engine.cfg, n=3)
    b = _requests(engine.cfg, n=3)
    _run_pair(engine, a, b, slots=1, num_blocks=32, block_size=8, chunk=16)
    for ra, rb in zip(a, b):
        assert ra.out_tokens == rb.out_tokens


def test_paged_kv_quant_runtime(engine):
    """int8 paged pools: same machinery, quantized blocks + scale pools.
    Greedy tokens should usually agree with the f32 paged run."""
    qeng = InferenceEngine(engine.cfg, engine.params, max_len=96,
                           kv_quant=True)
    reqs32 = _requests(engine.cfg, n=3, budget=6)
    reqs8 = _requests(engine.cfg, n=3, budget=6)
    p32 = PagedContinuousBatcher(engine, slots=2, num_blocks=32, block_size=8,
                                 chunk=8)
    p8 = PagedContinuousBatcher(qeng, slots=2, num_blocks=32, block_size=8,
                                chunk=8)
    assert p8.cache["kp"].dtype == jnp.int8
    for r in reqs32:
        p32.submit(r)
    for r in reqs8:
        p8.submit(r)
    p32.run()
    p8.run()
    agree = sum(a == b for ra, rb in zip(reqs32, reqs8)
                for a, b in zip(ra.out_tokens, rb.out_tokens))
    total = sum(len(r.out_tokens) for r in reqs32)
    assert all(r.done for r in reqs8)
    assert agree >= total - 2, (agree, total)


# ----------------------------------------------------------- chunked prefill
def test_chunked_prefill_decode_advances_during_long_prompt(engine):
    """A long prompt prefilling chunk-by-chunk must not stall resident decode
    lanes: the short request keeps emitting tokens while the long one is
    still mid-prefill."""
    long_req = Request(0, np.arange(80) % engine.cfg.vocab_size, 4)
    short = Request(1, np.arange(5) % engine.cfg.vocab_size, 12)
    cb = PagedContinuousBatcher(engine, slots=2, num_blocks=64, block_size=8,
                                chunk=8)
    cb.submit(long_req)
    cb.submit(short)
    interleaved = []
    ticks = 0
    while cb.busy and ticks < 60:
        cb.step()
        ticks += 1
        lane0 = cb._lane[0]
        if lane0 is not None and lane0.prefilled < len(long_req.tokens):
            interleaved.append(len(short.out_tokens))
    assert long_req.done and short.done
    # decode progressed across ticks where the long prompt was mid-prefill
    assert interleaved and interleaved[-1] > interleaved[0]
    # and the outputs still match the solo engine
    solo = engine.generate(
        {"tokens": jnp.asarray(long_req.tokens, jnp.int32)[None]}, 4)
    np.testing.assert_array_equal(np.asarray(long_req.out_tokens[:4]),
                                  solo.tokens[0])


# ------------------------------------------------------------ prefix sharing
def test_prefix_sharing_reuses_blocks(engine):
    """n requests sharing a 24-token prefix: later arrivals map the donor's
    full blocks instead of allocating fresh ones, and outputs are unchanged."""
    cfg = engine.cfg
    pre = np.arange(24) % cfg.vocab_size
    reqs = [Request(i, np.concatenate([pre, np.array([i + 1, i + 2])])
                    % cfg.vocab_size, 5) for i in range(4)]
    cb = PagedContinuousBatcher(engine, slots=2, num_blocks=48, block_size=8,
                                chunk=8)
    for r in reqs:
        cb.submit(r)
    cb.run()
    st = cb.stats()
    no_share = sum(-(-(len(r.tokens) + r.max_new_tokens) // 8) for r in reqs)
    assert st["prefix_hits"] > 0
    assert st["fresh_allocs"] < no_share       # allocated < sum of contexts
    for r in reqs:
        solo = engine.generate({"tokens": jnp.asarray(r.tokens, jnp.int32)[None]}, 5)
        np.testing.assert_array_equal(np.asarray(r.out_tokens[:5]),
                                      solo.tokens[0])


def test_prefix_sharing_disabled_allocates_full(engine):
    cfg = engine.cfg
    pre = np.arange(24) % cfg.vocab_size
    reqs = [Request(i, np.concatenate([pre, np.array([i + 1])])
                    % cfg.vocab_size, 4) for i in range(3)]
    cb = PagedContinuousBatcher(engine, slots=1, num_blocks=48, block_size=8,
                                chunk=8, prefix_sharing=False)
    for r in reqs:
        cb.submit(r)
    cb.run()
    st = cb.stats()
    assert st["prefix_hits"] == 0
    assert st["fresh_allocs"] == sum(
        -(-(len(r.tokens) + r.max_new_tokens) // 8) for r in reqs)


# ------------------------------------------------------- memory-aware admission
def test_memory_bound_admission_caps_concurrency(engine):
    """KV memory smaller than slots x max_len: concurrency is bounded by
    blocks, not slots, and the queue still drains as blocks free up."""
    reqs = [Request(i, np.arange(16) % engine.cfg.vocab_size, 8)
            for i in range(6)]
    # each request needs ceil(24/8)=3 blocks; 7 usable blocks, 4 slots
    cb = PagedContinuousBatcher(engine, slots=4, num_blocks=8, block_size=8,
                                chunk=16, prefix_sharing=False)
    peak = 0
    for r in reqs:
        cb.submit(r)
    ticks = 0
    while cb.busy and ticks < 400:
        cb.step()
        peak = max(peak, sum(1 for r in cb.active if r is not None))
        ticks += 1
    assert all(r.done for r in reqs)
    assert peak <= 2                            # 3 blocks each, 7 usable
    assert cb.allocator.peak_used <= cb.total_blocks


def test_paged_submit_rejects_impossible_request(engine):
    cb = PagedContinuousBatcher(engine, slots=1, num_blocks=4, block_size=8)
    with pytest.raises(ValueError):
        cb.submit(Request(0, np.arange(40), 8))  # 6 blocks > 3 usable
