"""End-to-end behaviour tests for the paper's system: a hybrid fleet serving
a workload, with the paper's scheduler measurably beating the workload-unaware
baseline, on top of real JAX inference."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (CostOptimalScheduler, Query, SingleSystemScheduler,
                        ThresholdScheduler, alpaca_like, headline, paper_fleet,
                        simulate)
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.serving.router import FleetRouter


def test_end_to_end_hybrid_beats_unaware():
    """The paper's claim, end to end: threshold scheduler at T*=32 consumes
    less energy on an Alpaca-like workload than any single-pool policy."""
    cfg = get_config("deepseek-7b")
    eff, perf = paper_fleet()
    qs = alpaca_like(3000, seed=5)
    hd = headline(cfg, qs, eff, perf, t_in=32, axis="in")
    assert hd.savings_vs_all_perf > 0.02      # >2% floor; calibrated ~18%
    assert hd.hybrid.total_energy_j < hd.baselines["all_eff"].total_energy_j
    # both pools actually used
    assert len(hd.hybrid.per_system_queries) == 2


def test_cost_optimal_beats_threshold_on_joint_workload():
    """Beyond-paper: exact per-query argmin beats the threshold heuristic."""
    cfg = get_config("deepseek-7b")
    eff, perf = paper_fleet()
    qs = alpaca_like(2000, seed=6)
    th = simulate(cfg, qs, ThresholdScheduler(cfg, eff, perf, t_in=32, t_out=32,
                                              axis="both"))
    co = simulate(cfg, qs, CostOptimalScheduler(cfg, [eff, perf]))
    assert co.total_energy_j <= th.total_energy_j


def test_served_tokens_flow_through_router():
    """Requests routed AND executed produce real tokens from the JAX engine."""
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_len=64)
    eff, perf = paper_fleet()
    router = FleetRouter(cfg, {"eff": eff, "perf": perf},
                         {"eff": eng, "perf": eng}, policy="threshold", t_in=16)
    outs = [router.submit(np.arange(4), 5), router.submit(np.arange(40), 5)]
    assert outs[0].pool == "eff" and outs[1].pool == "perf"
    for o in outs:
        assert o.output is not None and o.output.shape == (5,)
        assert (o.output >= 0).all() and (o.output < cfg.vocab_size).all()
    rep = router.fleet_report()
    assert rep["eff"]["energy_j"] > 0 and rep["perf"]["energy_j"] > 0


def test_scheduler_respects_lambda_extremes():
    """lambda=0 -> pure speed: everything goes to the performance system;
    lambda=1 -> small queries go to the efficiency system."""
    from repro.core import CostParams
    cfg = get_config("deepseek-7b")
    eff, perf = paper_fleet()
    fast = CostOptimalScheduler(cfg, [eff, perf], CostParams(lam=0.0))
    assert fast.choose(Query(4, 4)) is perf
    green = CostOptimalScheduler(cfg, [eff, perf], CostParams(lam=1.0))
    assert green.choose(Query(4, 4)) is eff
