import os
import sys

# tests see ONE cpu device (the dry-run sets its own XLA_FLAGS internally and
# runs as a separate process — never import repro.launch.dryrun from tests
# before jax is initialized elsewhere)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
