"""Energy-proportional fleets: power-state tables, the instance sleep/wake
machine, SLO-aware autoscaling, and the energy-accounting fixes that ride
along (idle-inclusive J/token, flat summaries, same-tick refill, float-dust
consistency at large simulated time)."""
import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, FleetSimulator, FleetState,
                        PoolSnapshot, PoolSpec, PowerState, PowerStateTable,
                        Query, QueueDepthAutoscaler, SingleSystemScheduler,
                        TargetUtilizationAutoscaler, ThresholdScheduler,
                        WorkloadSpec, default_power_states, paper_fleet,
                        sample_workload, simulate_fleet)
from repro.core.pricing import normalized_cost_params
from repro.core.fleet import SLEEP, _Resident

CFG = get_config("deepseek-7b")
EFF, PERF = paper_fleet()
SLO_S = 30.0


def _diurnal(n=120, seed=5, rate=1.0):
    """Compressed day/night cycle: n queries span multiple troughs."""
    return sample_workload(n, seed=seed, spec=WorkloadSpec(rate_qps=rate),
                           arrival_process="diurnal", period_s=240.0,
                           amplitude=0.9)


# ------------------------------------------------------------ power-state table
def test_default_power_states_consistent_with_profile():
    for prof in (EFF, PERF):
        t = default_power_states(prof)
        assert t.active.power_w == prof.power_peak_w
        assert t.idle.power_w == prof.power_idle_w
        assert 0.0 < t.sleep.power_w < prof.power_idle_w
        assert t.off.power_w == 0.0
        assert t.off.wake_s > t.sleep.wake_s > 0.0
        assert t.off.wake_j > t.sleep.wake_j > 0.0
        # profile accessors: derived table when none attached, instance watts
        assert prof.states() == t
        assert prof.state_power("sleep") == prof.chips * t.sleep.power_w
    with pytest.raises(KeyError):
        default_power_states(PERF).state("hibernate")


def test_explicit_power_states_override():
    from dataclasses import replace
    table = PowerStateTable(
        active=PowerState("active", PERF.power_peak_w),
        idle=PowerState("idle", PERF.power_idle_w),
        sleep=PowerState("sleep", 1.0, wake_s=2.0, wake_j=10.0),
        off=PowerState("off", 0.0, wake_s=9.0, wake_j=99.0))
    prof = replace(PERF, name="perf-custom", power_states=table)
    assert prof.states() is table
    assert prof.state_power("sleep") == prof.chips * 1.0


def test_pool_spec_validates_power_fields():
    with pytest.raises(ValueError):
        PoolSpec(PERF, 1, 1, sleep_state="hibernate")
    with pytest.raises(ValueError):
        PoolSpec(PERF, 1, 1, linger_s=-1.0)


# ------------------------------------------------- static-fleet equivalence
def test_equivalence_invariant_linger_inf_no_autoscaler():
    """Acceptance: power states enabled — an explicit table attached to the
    profile — but linger=inf and autoscaler off reproduces the plain
    fleet's per-request energies and fleet totals to <1e-9 rel (they are in
    fact bit-for-bit: the machine never engages)."""
    from dataclasses import replace
    qs = sample_workload(80, seed=7, spec=WorkloadSpec(rate_qps=3.0),
                         arrival_process="mmpp")
    eff_t = replace(EFF, power_states=default_power_states(EFF))
    perf_t = replace(PERF, power_states=default_power_states(PERF))
    plain = simulate_fleet(cfg=CFG, queries=qs,
                           pools={"eff": PoolSpec(EFF, 3, 2),
                                  "perf": PoolSpec(PERF, 2, 4)},
                           scheduler=ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    armed = simulate_fleet(cfg=CFG, queries=qs,
                           pools={"eff": PoolSpec(eff_t, 3, 2, linger_s=math.inf),
                                  "perf": PoolSpec(perf_t, 2, 4, linger_s=math.inf)},
                           scheduler=ThresholdScheduler(CFG, eff_t, perf_t,
                                                        t_in=32))
    assert abs(armed.fleet_energy_j - plain.fleet_energy_j) \
        <= 1e-9 * plain.fleet_energy_j
    assert abs(armed.idle_energy_j - plain.idle_energy_j) \
        <= 1e-9 * max(plain.idle_energy_j, 1.0)
    for a, b in zip(armed.records, plain.records):
        assert a.energy_j == b.energy_j
        assert a.t_done == b.t_done
    for p in armed.per_pool.values():
        assert p.wake_count == 0 and p.sleep_s == 0.0


# ------------------------------------------------------- sleep/wake mechanics
def test_linger_descent_and_demand_wake():
    """One instance, two queries separated by a gap >> linger: the instance
    sleeps in between, the second request pays the wake latency, and the
    wake energy lands in idle_energy_j."""
    gap = 200.0
    qs = [Query(32, 32, 0.0), Query(32, 32, gap)]
    spec = PoolSpec(PERF, 1, 1, linger_s=10.0)
    r = simulate_fleet(CFG, qs, {"perf": spec}, SingleSystemScheduler(CFG, PERF))
    p = r.per_pool["perf"]
    table = PERF.states()
    assert p.wake_count == 1
    assert p.sleep_s > 100.0                       # slept through most of the gap
    assert p.wake_energy_j == table.sleep.wake_j
    # second request waits exactly the wake latency (no queue otherwise)
    second = max(r.records, key=lambda x: x.t_arrival)
    assert second.wait_s == pytest.approx(table.sleep.wake_s, rel=1e-9)
    # energy-proportionality: strictly cheaper than the static fleet, by
    # roughly the sleep window's idle-vs-sleep power gap minus the wake cost
    st = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 1)},
                        SingleSystemScheduler(CFG, PERF))
    assert r.fleet_energy_j < st.fleet_energy_j
    saved = p.sleep_s * (PERF.power(0.0) - PERF.state_power("sleep"))
    extra = table.sleep.wake_j + table.sleep.wake_s * PERF.power(0.0)
    assert r.fleet_energy_j == pytest.approx(
        st.fleet_energy_j - saved + extra, rel=1e-6)


def test_sleep_state_off_uses_off_row():
    gap = 400.0
    qs = [Query(32, 32, 0.0), Query(32, 32, gap)]
    spec = PoolSpec(PERF, 1, 1, linger_s=10.0, sleep_state="off")
    r = simulate_fleet(CFG, qs, {"perf": spec}, SingleSystemScheduler(CFG, PERF))
    p = r.per_pool["perf"]
    table = PERF.states()
    assert p.wake_count == 1
    assert p.wake_energy_j == table.off.wake_j
    second = max(r.records, key=lambda x: x.t_arrival)
    assert second.wait_s == pytest.approx(table.off.wake_s, rel=1e-9)


def test_all_queries_complete_under_power_management():
    qs = sample_workload(100, seed=2, spec=WorkloadSpec(rate_qps=4.0),
                         arrival_process="mmpp")
    r = simulate_fleet(CFG, qs,
                       {"eff": PoolSpec(EFF, 3, 2, linger_s=5.0),
                        "perf": PoolSpec(PERF, 2, 4, linger_s=5.0)},
                       ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    assert len(r.records) == len(qs)
    for rec in r.records:
        assert rec.t_done > rec.t_start >= rec.t_arrival
        assert rec.energy_j > 0


# ------------------------------------------------------------------ autoscaler
def test_autoscaler_lowers_fleet_j_per_token_at_equal_slo():
    """Acceptance: under the diurnal workload the autoscaler strictly lowers
    fleet J/token vs. the static fleet at equal p99 SLO attainment."""
    qs = _diurnal()
    st = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 4, 2)},
                        SingleSystemScheduler(CFG, PERF))
    au = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 4, 2, linger_s=20.0)},
                        SingleSystemScheduler(CFG, PERF),
                        autoscaler=TargetUtilizationAutoscaler(
                            period_s=10.0, min_instances=1, target_util=0.6))
    assert len(au.records) == len(qs)
    assert au.slo_attainment(SLO_S) >= st.slo_attainment(SLO_S)
    assert au.fleet_j_per_token < st.fleet_j_per_token
    assert au.per_pool["perf"].sleep_s > 0


def test_queue_depth_autoscaler_scales_and_completes():
    qs = _diurnal(n=100, seed=9)
    r = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 4, 2)},
                       SingleSystemScheduler(CFG, PERF),
                       autoscaler=QueueDepthAutoscaler(period_s=10.0,
                                                       min_instances=1))
    assert len(r.records) == len(qs)
    assert r.per_pool["perf"].sleep_s > 0
    st = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 4, 2)},
                        SingleSystemScheduler(CFG, PERF))
    assert r.fleet_energy_j < st.fleet_energy_j


def test_autoscaler_min_instances_floor():
    """min_instances = instances: the control loop runs (machine engaged)
    but can never scale down — the fleet must stay bit-for-bit static."""
    qs = _diurnal(n=60, seed=3)
    au = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 3, 2)},
                        SingleSystemScheduler(CFG, PERF),
                        autoscaler=TargetUtilizationAutoscaler(
                            period_s=10.0, min_instances=3, target_util=0.6))
    st = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 3, 2)},
                        SingleSystemScheduler(CFG, PERF))
    p = au.per_pool["perf"]
    assert p.sleep_s == 0.0 and p.wake_count == 0
    assert au.fleet_energy_j == st.fleet_energy_j
    for a, b in zip(au.records, st.records):
        assert a.energy_j == b.energy_j and a.t_done == b.t_done


def test_autoscaler_handles_long_idle_gaps():
    """A sparse trace with a multi-hour lull: the control loop skips the
    drained gap (no tick storm) and the demand wake still serves the late
    arrival."""
    qs = [Query(32, 32, 0.0), Query(32, 32, 5.0e4)]
    r = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 2, 1, linger_s=10.0)},
                       SingleSystemScheduler(CFG, PERF),
                       autoscaler=QueueDepthAutoscaler(period_s=10.0,
                                                       min_instances=0))
    assert len(r.records) == 2
    assert r.per_pool["perf"].wake_count >= 1
    assert r.per_pool["perf"].sleep_s > 4.0e4


def test_autoscaler_unknown_pool_rejected():
    with pytest.raises(KeyError):
        FleetSimulator(CFG, {"perf": PoolSpec(PERF, 1, 1)},
                       SingleSystemScheduler(CFG, PERF),
                       autoscaler={"nope": QueueDepthAutoscaler()})


# --------------------------------------------------- snapshot / dispatch plumbing
def test_snapshot_reports_awake_counts_and_wake_delay():
    sim = FleetSimulator(CFG, {"perf": PoolSpec(PERF, 2, 1, linger_s=5.0)},
                         SingleSystemScheduler(CFG, PERF))
    pool = sim.pools["perf"]
    snap = pool.snapshot(sim.model, 0.0)
    assert snap.awake_instances == 2 and snap.asleep_instances == 0
    assert snap.wake_delay_s == 0.0
    # put one instance to sleep: still a free awake slot -> no wake delay
    pool.instances[0].go_sleep(0.0, SLEEP)
    snap = pool.snapshot(sim.model, 0.0)
    assert snap.awake_instances == 1 and snap.asleep_instances == 1
    assert snap.wake_delay_s == 0.0
    # both asleep: the only path to capacity is a demand wake
    pool.instances[1].go_sleep(0.0, SLEEP)
    snap = pool.snapshot(sim.model, 0.0)
    assert snap.awake_instances == 0 and snap.asleep_instances == 2
    assert snap.wake_delay_s == PERF.states().sleep.wake_s
    assert snap.est_wait_s >= snap.wake_delay_s
    assert snap.provisioned_instances == 0 and snap.awake_slots == 0
    # waking: the remaining wake time, not the full latency
    pool.instances[0].begin_wake(0.0)
    snap = pool.snapshot(sim.model, 2.0)
    assert snap.wake_delay_s == pytest.approx(
        PERF.states().sleep.wake_s - 2.0)


def test_dispatch_prices_cold_pool_honestly():
    """Twin pools, one fully asleep: est_wait carries the wake delay, so the
    capacity-aware policy routes to the warm pool under a latency objective."""
    from dataclasses import replace
    warm = replace(PERF, name="twin-warm")
    cold = replace(PERF, name="twin-cold")
    cp = normalized_cost_params(CFG, warm, lam=0.0)    # pure latency
    sched = CapacityAwareScheduler(CFG, [warm, cold],
                                   {warm.name: 1, cold.name: 1}, cp)
    wake_s = PERF.states().sleep.wake_s
    fleet = FleetState(pools={
        "warm": PoolSnapshot(system=warm, awake_instances=1,
                             asleep_instances=0, est_wait_s=0.0),
        "cold": PoolSnapshot(system=cold, awake_instances=0,
                             asleep_instances=1, est_wait_s=wake_s,
                             wake_delay_s=wake_s)})
    assert sched.dispatch(Query(16, 16), fleet).pool == warm.name


def test_router_mirrors_awake_count_view():
    from repro.serving.router import FleetRouter
    router = FleetRouter(CFG, {"eff": EFF, "perf": PERF}, {},
                         policy="capacity_aware",
                         counts={EFF.name: 3, PERF.name: 2})
    router.batchers = {}            # no execution backend: route()-only flow
    state = router._fleet_state(0.0)
    for name, n in (("eff", 3), ("perf", 2)):
        snap = state.pools[name]
        assert snap.awake_instances == n
        assert snap.asleep_instances == 0
        assert snap.wake_delay_s == 0.0


def test_demand_wake_on_block_bound_stall():
    """A free slot on a block-saturated awake instance is not capacity: the
    stalled head must demand-wake a sleeping instance instead of waiting
    out the resident's (long) decode."""
    # q1 pins all 36 blocks of instance A for minutes (m1-pro decode);
    # q2 arrives after instance B has lingered to sleep
    qs = [Query(64, 512, 0.0), Query(8, 8, 20.0)]
    spec = PoolSpec(EFF, 2, 2, kv_blocks=36, block_size=16, linger_s=10.0)
    r = simulate_fleet(CFG, qs, {"eff": spec}, SingleSystemScheduler(CFG, EFF))
    first = min(r.records, key=lambda x: x.t_arrival)
    second = max(r.records, key=lambda x: x.t_arrival)
    assert r.per_pool["eff"].wake_count == 1
    assert second.wait_s == pytest.approx(EFF.states().sleep.wake_s, rel=1e-9)
    assert second.t_start < first.t_done       # far before the decode frees


def test_snapshot_free_blocks_counts_wakeable_capacity():
    """Sleeping instances' free blocks ARE admissible capacity — a demand
    wake reaches them within wake_delay_s, which est_wait_s already prices.
    Reporting a cold pool as block-starved would stack mem_wait_s (~a full
    service time) on top of the wake latency: a double penalty."""
    sim = FleetSimulator(CFG, {"perf": PoolSpec(PERF, 2, 2, kv_blocks=16,
                                                block_size=16, linger_s=5.0)},
                         SingleSystemScheduler(CFG, PERF))
    pool = sim.pools["perf"]
    pool.instances[1].go_sleep(0.0, SLEEP)
    pool.instances[0].blocks_in_use = 16       # awake instance saturated
    snap = pool.snapshot(sim.model, 0.0)
    assert snap.free_blocks == 16              # the sleeping instance's pool
    # a (64, 64) request needs 8 <= 16 blocks: no scarcity surcharge on top
    # of the wake path
    assert snap.mem_wait_s(64, 64, 100.0) == 0.0


# ------------------------------------------------------ satellite: refill fix
def test_refill_uses_capacity_freed_in_same_tick():
    """Two instances, tight kv_blocks: the head-of-line request fits only
    after a completion due at exactly `now` on an instance whose event is
    still in the heap — _refill must settle it and admit in the same tick
    instead of leaving the head queued."""
    spec = PoolSpec(PERF, 2, 2, kv_blocks=8, block_size=16)
    sim = FleetSimulator(CFG, {"perf": spec}, SingleSystemScheduler(CFG, PERF))
    pool = sim.pools["perf"]
    a, b = pool.instances
    now = 50.0
    # instance A: free slot but zero free blocks (long-running resident)
    ra = _Resident(sim.model, _rec(0, Query(64, 64), 0.0), PERF, 0.0, blocks=8)
    ra.rem_tokens = 40.0
    a.residents.append(ra)
    a.blocks_in_use = 8
    a.last_t = now
    # instance B: resident holding all 8 blocks, finished by `now` but its
    # completion event not yet processed (B not advanced since admission)
    rb = _Resident(sim.model, _rec(1, Query(64, 64), 0.0), PERF, 0.0, blocks=8)
    rb.rem_tokens = 0.0
    b.residents.append(rb)
    b.blocks_in_use = 8
    b.last_t = now - 1.0
    # head request needs 8 blocks: no instance fits until B completes
    head = _rec(2, Query(64, 64), now)
    pool.enqueue(now, 0, head, 1.0)
    sim._horizon = 0.0
    events, seq = [], iter(range(100))
    sim._refill(pool, now, events, seq)
    assert not pool.queue, "head skipped capacity freed in the same tick"
    assert head.t_start == now
    assert rb.rec.t_done == now          # the due completion was settled
    assert head in [r.rec for r in b.residents]


def _rec(rid, q, t):
    from repro.core.fleet import RequestRecord
    return RequestRecord(rid, q, "perf", t_arrival=t)


def test_refill_regression_end_to_end_tight_blocks():
    """Same-arrival bursts on two block-tight instances drain without loss
    and respect the per-instance block bound."""
    qs = [Query(64, 64, float(i // 4)) for i in range(24)]
    spec = PoolSpec(PERF, 2, 4, kv_blocks=16, block_size=16)
    r = simulate_fleet(CFG, qs, {"perf": spec}, SingleSystemScheduler(CFG, PERF))
    assert len(r.records) == 24
    # each request holds ceil(128/16)=8 blocks -> 2 per instance, 4 total
    assert r.per_pool["perf"].peak_residents <= 4
    assert all(rec.t_done > rec.t_start for rec in r.records)


# --------------------------------------- satellite: idle-inclusive J/token
def test_j_per_token_and_fleet_j_per_token_pinned():
    qs = sample_workload(40, seed=11, spec=WorkloadSpec(rate_qps=2.0))
    r = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 2, 2)},
                       SingleSystemScheduler(CFG, PERF))
    tokens = sum(q.m + q.n for q in qs)
    attributed = sum(rec.energy_j for rec in r.records)
    idle = sum(p.idle_energy_j for p in r.per_pool.values())
    assert idle > 0
    # the old field: request-attributed only (kept, still excludes idle)
    assert r.j_per_token == pytest.approx(attributed / tokens, rel=1e-12)
    # the headline field: idle-inclusive
    assert r.fleet_j_per_token == pytest.approx((attributed + idle) / tokens,
                                                rel=1e-12)
    assert r.fleet_j_per_token > r.j_per_token


def test_fleet_j_per_token_reranks_underutilized_fleet():
    """A hugely overprovisioned fleet looks identical on j_per_token but
    strictly worse on fleet_j_per_token — the understated-idle bug."""
    qs = sample_workload(30, seed=1, spec=WorkloadSpec(rate_qps=0.5))
    lean = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 2, 2)},
                          SingleSystemScheduler(CFG, PERF))
    fat = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 30, 2)},
                         SingleSystemScheduler(CFG, PERF))
    assert fat.j_per_token == pytest.approx(lean.j_per_token, rel=0.2)
    assert fat.fleet_j_per_token > lean.fleet_j_per_token * 2


# ----------------------------------------------- satellite: flat summary()
def test_summary_is_flat_scalar_dict():
    qs = sample_workload(20, seed=4, spec=WorkloadSpec(rate_qps=2.0))
    r = simulate_fleet(CFG, qs,
                       {"eff": PoolSpec(EFF, 2, 1), "perf": PoolSpec(PERF, 2, 1)},
                       ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    s = r.summary()
    assert all(isinstance(v, float) for v in s.values()), \
        f"summary must be flat Dict[str, float], got {s}"
    assert "util_eff" in s and "util_perf" in s
    assert "utilization" not in s
    assert s["util_eff"] == r.per_pool["eff"].utilization
    assert s["fleet_j_per_token"] == r.fleet_j_per_token
    # a flat CSV writer round-trips it
    header = ",".join(s)
    row = ",".join(str(v) for v in s.values())
    assert len(header.split(",")) == len(row.split(","))


# ------------------------------- satellite: float-dust consistency at large now
def test_snap_and_pop_thresholds_consistent_at_large_now():
    """advance()'s 4*spacing(now) snap and pop_finished's rem<=1e-6 must not
    leave a gap in the supported horizon range: any resident the snap leaves
    unsnapped schedules an event strictly after `now` (no livelock), and any
    snapped remainder is below the pop threshold (no lost tokens)."""
    model_t_tok = []
    from repro.core.pricing import CostModel
    m = CostModel(CFG)
    for sys in (EFF, PERF):
        for mm, nn in ((8, 8), (64, 64), (512, 512)):
            ph = m.phases(mm, nn, sys)
            model_t_tok.append(ph.t_decode / nn)
    t_tok_min = min(model_t_tok)
    for now in (1e5, 3e5, 1e6):
        # unsnapped => rem*t_tok > 4*spacing(now) => the next event time
        # now + rem*t_tok lands strictly after now (progress is guaranteed)
        assert 4.0 * np.spacing(now) > np.spacing(now)
        assert float(now + 4.0 * np.spacing(now)) > now
        # the pop threshold covers everything the snap can zero: a snapped
        # remainder is at most 4*spacing(now)/t_tok tokens, far below 1e-6
        assert 4.0 * np.spacing(now) / t_tok_min < 1e-6, \
            f"snap can kill >1e-6 tokens at now={now:g} (t_tok={t_tok_min:g})"


def test_no_livelock_and_no_drift_at_diurnal_horizon():
    """The same workload simulated near t=0 and shifted to t>=1e5 s (a
    diurnal horizon) must complete (no livelock) with identical per-request
    token accounting and energies up to float dust."""
    offset = 3.0e5
    base = sample_workload(60, seed=13, spec=WorkloadSpec(rate_qps=2.0),
                           arrival_process="mmpp")
    shifted = [Query(q.m, q.n, q.arrival_s + offset) for q in base]
    pools = lambda: {"eff": PoolSpec(EFF, 2, 2), "perf": PoolSpec(PERF, 1, 4)}
    r0 = simulate_fleet(CFG, base, pools(),
                        ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    r1 = simulate_fleet(CFG, shifted, pools(),
                        ThresholdScheduler(CFG, EFF, PERF, t_in=32))
    assert len(r1.records) == len(base)              # completed: no livelock
    assert r1.horizon_s >= offset
    for a, b in zip(r0.records, r1.records):
        assert a.query.m == b.query.m and a.query.n == b.query.n
        assert b.energy_j == pytest.approx(a.energy_j, rel=1e-6)
        assert (b.t_done - offset) == pytest.approx(a.t_done, abs=1e-4)


def test_power_machine_stable_at_large_now():
    """Sleep/wake timestamps at now>=1e5 s: linger deadlines and wake
    completions must still fire and the fleet must drain."""
    offset = 2.0e5
    qs = [Query(32, 32, offset), Query(32, 32, offset + 300.0)]
    r = simulate_fleet(CFG, qs, {"perf": PoolSpec(PERF, 1, 1, linger_s=10.0)},
                       SingleSystemScheduler(CFG, PERF))
    assert len(r.records) == 2
    assert r.per_pool["perf"].wake_count >= 1
    assert r.per_pool["perf"].sleep_s > 100.0
