"""Fleet-sim core throughput benchmark: vectorized vs legacy event engine.

Full mode drives the vectorized core through a million-request,
two-thousand-instance diurnal day (the scale the paper's fleet studies
need) and measures the legacy per-event engine on a prefix of the same
stream — at ~2k events/s it would need hours for the full run. The
prefix is sized past the diurnal warmup (where an idle fleet flatters
the event engine) into its steady-state regime, but still stops
short of the midday peak that the vectorized number fully includes, so
the recorded speedup remains a conservative lower bound. Results land in
``BENCH_fleet.json`` at the repo root.

``--smoke`` is the CI gate: a small fixed-seed config must (a) produce
bit-for-bit identical ``summary()`` dicts from both engines, (b) clear a
vectorized events/sec floor, and (c) find a well-formed
``BENCH_fleet.json`` recording the >= 20x full-scale speedup.

Run: PYTHONPATH=src python benchmarks/fleet_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import time
from typing import Dict

from repro.configs import get_config
from repro.core.fleet import FleetSimulator, PoolSpec
from repro.core.fleet_vec import VectorizedFleetSimulator
from repro.core.scheduler import CostOptimalScheduler
from repro.core.systems import SystemProfile
from repro.core.workload import WorkloadSpec, sample_workload

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_fleet.json")

# The full-scale operating point: a diurnal day at 12000 queries/s across
# 2000 eff + 2000 perf instances (8 slots each). Deep enough that both
# engines do real queueing work, shallow enough that the vectorized
# engine's per-stuck-arrival settlement does not dominate; the wide
# fleet is where the legacy engine's O(instances)-per-arrival scans
# bind, which is exactly the regime the vectorized core exists for.
FULL_REQUESTS = 1_000_000
FULL_EVENT_PREFIX = 30_000
FULL_RATE_QPS = 12000.0
FULL_INSTANCES_PER_POOL = 2000
SLOTS = 8

# CI floor for the smoke config (events/sec, vectorized engine). Measured
# ~20x higher on the reference container; the floor only has to catch an
# order-of-magnitude regression, not enforce the full-scale number.
SMOKE_EVENTS_PER_S_FLOOR = 2000.0

REQUIRED_KEYS = ("config", "vectorized", "event", "speedup_events_per_s")
ENGINE_KEYS = ("requests", "events", "wall_s", "events_per_s",
               "requests_per_s", "peak_rss_mb")


def _bench_fleet(model: str):
    """The probe fleet: an efficiency system (bandwidth-lean, low power,
    saturating context) against a performance system, both sized so a
    3B-class model leaves headroom for 8 resident requests."""
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=90e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=220.0,
                        power_idle_w=60.0, overhead_s=0.02, sat_ctx=4096.0)
    perf = SystemProfile(name="perf", kind="perf", chips=2, peak_flops=200e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=60.0, overhead_s=0.01, sat_ctx=None)
    return get_config(model), eff, perf


def _peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _run(sim, qs) -> Dict:
    t0 = time.perf_counter()
    sim.run(qs)
    wall_s = time.perf_counter() - t0
    return {
        "requests": len(qs),
        "events": sim.events_processed,
        "wall_s": round(wall_s, 3),
        "events_per_s": round(sim.events_processed / wall_s, 1),
        "requests_per_s": round(len(qs) / wall_s, 1),
        "peak_rss_mb": round(_peak_rss_mb(), 1),
    }


def bench(n_requests: int = FULL_REQUESTS,
          n_event: int = FULL_EVENT_PREFIX,
          rate_qps: float = FULL_RATE_QPS,
          instances: int = FULL_INSTANCES_PER_POOL,
          model: str = "qwen2.5-3b", seed: int = 0) -> Dict:
    """Measure both engines and write ``BENCH_fleet.json``."""
    cfg, eff, perf = _bench_fleet(model)
    qs = sample_workload(n_requests, seed=seed,
                         spec=WorkloadSpec(rate_qps=rate_qps),
                         arrival_process="diurnal")
    pools = {"eff": PoolSpec(eff, instances=instances, slots=SLOTS),
             "perf": PoolSpec(perf, instances=instances, slots=SLOTS)}

    # Event engine first (prefix): ru_maxrss is a process-wide high-water
    # mark, so the small run must not inherit the big run's footprint.
    print(f"event engine: {n_event} requests (prefix) ...", flush=True)
    evt = _run(FleetSimulator(cfg, pools, CostOptimalScheduler(cfg, [eff, perf])),
               qs[:n_event])
    print(f"  {evt['wall_s']}s  {evt['events_per_s']} ev/s  "
          f"{evt['requests_per_s']} req/s")

    print(f"vectorized engine: {n_requests} requests ...", flush=True)
    vec = _run(VectorizedFleetSimulator(cfg, pools,
                                        CostOptimalScheduler(cfg, [eff, perf])),
               qs)
    print(f"  {vec['wall_s']}s  {vec['events_per_s']} ev/s  "
          f"{vec['requests_per_s']} req/s")

    out = {
        "config": {
            "model": model, "seed": seed, "arrival_process": "diurnal",
            "rate_qps": rate_qps, "instances_per_pool": instances,
            "pools": 2, "slots": SLOTS, "requests": n_requests,
            "event_engine_prefix": n_event,
        },
        "vectorized": vec,
        "event": evt,
        "speedup_events_per_s": round(
            vec["events_per_s"] / evt["events_per_s"], 1),
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"speedup: {out['speedup_events_per_s']}x events/s  "
          f"-> {os.path.relpath(BENCH_PATH)}")
    return out


def smoke(model: str = "qwen2.5-3b") -> None:
    """CI gate: engine equivalence bit-for-bit, a vectorized events/sec
    floor, and a well-formed recorded BENCH_fleet.json."""
    cfg, eff, perf = _bench_fleet(model)
    qs = sample_workload(2000, seed=0, spec=WorkloadSpec(rate_qps=40.0),
                         arrival_process="diurnal")
    pools = {"eff": PoolSpec(eff, instances=8, slots=4),
             "perf": PoolSpec(perf, instances=8, slots=4)}
    vec_sim = VectorizedFleetSimulator(cfg, pools,
                                       CostOptimalScheduler(cfg, [eff, perf]))
    t0 = time.perf_counter()
    r_vec = vec_sim.run(qs)
    ev_per_s = vec_sim.events_processed / (time.perf_counter() - t0)
    r_evt = FleetSimulator(cfg, pools,
                           CostOptimalScheduler(cfg, [eff, perf])).run(qs)
    s_vec, s_evt = r_vec.summary(), r_evt.summary()
    assert s_vec == s_evt, (
        "engine summaries diverge:\n"
        + "\n".join(f"  {k}: vec={s_vec[k]!r} evt={s_evt.get(k)!r}"
                    for k in s_vec if s_vec[k] != s_evt.get(k)))
    assert ev_per_s >= SMOKE_EVENTS_PER_S_FLOOR, (
        f"vectorized engine too slow: {ev_per_s:.0f} ev/s "
        f"< floor {SMOKE_EVENTS_PER_S_FLOOR:.0f}")

    assert os.path.exists(BENCH_PATH), \
        "BENCH_fleet.json missing: run benchmarks/fleet_bench.py (full mode)"
    with open(BENCH_PATH) as f:
        rec = json.load(f)
    for k in REQUIRED_KEYS:
        assert k in rec, f"BENCH_fleet.json missing key {k!r}"
    for side in ("vectorized", "event"):
        for k in ENGINE_KEYS:
            assert k in rec[side], f"BENCH_fleet.json {side} missing {k!r}"
    assert rec["speedup_events_per_s"] >= 20.0, (
        f"recorded full-scale speedup {rec['speedup_events_per_s']}x "
        "below the 20x bar")
    assert rec["config"]["requests"] >= 1_000_000
    assert rec["config"]["instances_per_pool"] * rec["config"]["pools"] >= 1000
    print(f"fleet-bench smoke OK: engines bit-identical on "
          f"{len(qs)} requests, vec {ev_per_s:.0f} ev/s, recorded "
          f"full-scale speedup {rec['speedup_events_per_s']}x")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=FULL_REQUESTS)
    ap.add_argument("--event-requests", type=int, default=FULL_EVENT_PREFIX,
                    help="prefix length for the legacy event engine")
    ap.add_argument("--rate", type=float, default=FULL_RATE_QPS)
    ap.add_argument("--instances", type=int,
                    default=FULL_INSTANCES_PER_POOL,
                    help="instances per pool (two pools)")
    ap.add_argument("--model", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: equivalence + events/sec floor + "
                         "recorded-artifact schema")
    args = ap.parse_args()
    if args.smoke:
        smoke(args.model)
        return
    bench(args.requests, args.event_requests, args.rate, args.instances,
          args.model)


if __name__ == "__main__":
    main()
