"""Benchmarks reproducing the paper's figures/tables from the calibrated
analytic model. One function per figure; each returns CSV rows
(name, value, derived...) and writes experiments/bench/<name>.csv.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.configs import get_config, list_paper_archs
from repro.core import (CostOptimalScheduler, CapacityAwareScheduler, Query,
                        SingleSystemScheduler, ThresholdScheduler, alpaca_like,
                        crossover_threshold, energy, energy_per_token_in,
                        energy_per_token_out, headline, optimal_threshold,
                        paper_fleet, runtime, simulate, threshold_sweep,
                        throughput, token_histogram, tpu_fleet)

try:
    from benchmarks.bench_util import write_csv as _write
except ImportError:                      # standalone: benchmarks/ on sys.path
    from bench_util import write_csv as _write

INPUT_SIZES = [8, 16, 32, 64, 128, 256, 512, 1024, 2048]      # paper 5.2.1
OUTPUT_SIZES = [8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096]  # paper 5.2.2
PAPER_MODELS = ("llama2-7b", "mistral-7b", "falcon-7b")


def fig1_input_tokens() -> List[List]:
    """Fig 1: runtime / throughput / J-per-token vs input tokens (out=32)."""
    eff, perf = paper_fleet()
    rows = []
    for model in PAPER_MODELS:
        cfg = get_config(model)
        for s in (eff, perf):
            for m in INPUT_SIZES:
                rows.append([model, s.name, m,
                             f"{runtime(cfg, m, 32, s):.4f}",
                             f"{throughput(cfg, m, 32, s):.2f}",
                             f"{energy_per_token_in(cfg, m, s):.4f}"])
    _write("fig1_input_tokens",
           ["model", "system", "input_tokens", "runtime_s", "tok_per_s", "j_per_tok"],
           rows)
    return rows


def fig2_output_tokens() -> List[List]:
    """Fig 2: runtime / throughput / J-per-token vs output tokens (in=32).
    M1-Pro rows stop at 512 (paper: generation cap)."""
    eff, perf = paper_fleet()
    rows = []
    for model in PAPER_MODELS:
        cfg = get_config(model)
        for s in (eff, perf):
            for n in OUTPUT_SIZES:
                if s.max_out_tokens and n > s.max_out_tokens:
                    continue
                rows.append([model, s.name, n,
                             f"{runtime(cfg, 32, n, s):.4f}",
                             f"{throughput(cfg, 32, n, s):.2f}",
                             f"{energy_per_token_out(cfg, n, s):.4f}"])
    _write("fig2_output_tokens",
           ["model", "system", "output_tokens", "runtime_s", "tok_per_s", "j_per_tok"],
           rows)
    return rows


def fig3_token_distribution() -> List[List]:
    """Fig 3: Alpaca token-count distributions (52K prompts)."""
    qs = alpaca_like(52_000, seed=0)
    rows = []
    for axis in ("in", "out"):
        freq, centers = token_histogram(qs, axis=axis,
                                        bins=np.array([1, 8, 16, 32, 64, 128,
                                                       256, 512, 1024, 2048, 4096]))
        for f, c in zip(freq, centers):
            rows.append([axis, int(c), int(f)])
    ms = [q.m for q in qs]
    ns = [q.n for q in qs]
    rows.append(["in_median", int(np.median(ms)), len(qs)])
    rows.append(["out_median", int(np.median(ns)), len(qs)])
    _write("fig3_token_distribution", ["axis", "bin_start", "count"], rows)
    return rows


def fig4_input_threshold_sweep() -> List[List]:
    """Fig 4: hybrid energy/runtime vs T_in, with single-hardware dashed lines."""
    return _threshold_fig("fig4_input_threshold", axis="in")


def fig5_output_threshold_sweep() -> List[List]:
    """Fig 5: hybrid energy/runtime vs T_out (<=512 per the M1 cap)."""
    return _threshold_fig("fig5_output_threshold", axis="out")


def _threshold_fig(name: str, axis: str) -> List[List]:
    eff, perf = paper_fleet()
    cfg = get_config("llama2-7b")
    qs = alpaca_like(10_000, seed=0)
    pinned = [Query(q.m, 32) if axis == "in" else Query(32, q.n) for q in qs]
    rows = []
    for pol, sched in (("all_eff", SingleSystemScheduler(cfg, eff)),
                       ("all_perf", SingleSystemScheduler(cfg, perf))):
        r = simulate(cfg, pinned, sched, pol)
        rows.append([pol, "-", f"{r.total_energy_j:.1f}", f"{r.total_runtime_s:.1f}"])
    sweep = threshold_sweep(cfg, qs, eff, perf, axis=axis)
    for p in sweep:
        rows.append([f"hybrid_T{axis}", p.threshold, f"{p.energy_j:.1f}",
                     f"{p.runtime_s:.1f}"])
    best = optimal_threshold(sweep)
    rows.append([f"optimal_T{axis}", best.threshold, f"{best.energy_j:.1f}",
                 f"{best.runtime_s:.1f}"])
    _write(name, ["policy", "threshold", "energy_j", "runtime_s"], rows)
    return rows


def headline_table() -> List[List]:
    """The paper's headline: hybrid savings vs workload-unaware baselines —
    plus our beyond-paper schedulers, on paper fleet AND TPU fleet."""
    rows = []
    qs = alpaca_like(10_000, seed=0)
    for fleet_name, (eff, perf) in (("paper_m1+a100", paper_fleet()),
                                    ("tpu_v5litex+v5e", tpu_fleet())):
        for model in ("llama2-7b",):
            cfg = get_config(model)
            hd = headline(cfg, qs, eff, perf, t_in=32, axis="in")
            rows.append([fleet_name, model, "threshold_in32_eq9",
                         f"{hd.hybrid.total_energy_j:.0f}",
                         f"{hd.savings_vs_best_baseline:.4f}",
                         f"{hd.savings_vs_all_perf:.4f}",
                         f"{hd.runtime_penalty_frac_vs_all_perf:.4f}"])
            hd2 = headline(cfg, qs, eff, perf, t_in=32, axis="both",
                           paper_faithful=False)
            rows.append([fleet_name, model, "threshold_both32_joint",
                         f"{hd2.hybrid.total_energy_j:.0f}",
                         f"{hd2.savings_vs_best_baseline:.4f}",
                         f"{hd2.savings_vs_all_perf:.4f}",
                         f"{hd2.runtime_penalty_frac_vs_all_perf:.4f}"])
            co = simulate(cfg, qs, CostOptimalScheduler(cfg, [eff, perf]))
            ap = simulate(cfg, qs, SingleSystemScheduler(cfg, perf))
            rows.append([fleet_name, model, "cost_optimal_joint",
                         f"{co.total_energy_j:.0f}",
                         f"{(ap.total_energy_j - co.total_energy_j) / ap.total_energy_j:.4f}",
                         f"{(ap.total_energy_j - co.total_energy_j) / ap.total_energy_j:.4f}",
                         f"{(co.total_runtime_s - ap.total_runtime_s) / ap.total_runtime_s:.4f}"])
    _write("headline_table",
           ["fleet", "model", "policy", "energy_j", "savings_vs_best",
            "savings_vs_all_perf", "runtime_penalty"], rows)
    return rows


def crossover_table() -> List[List]:
    """Per-architecture crossover thresholds on both fleets — shows the
    technique generalizing across all 10 assigned architectures."""
    from repro.configs import list_archs
    rows = []
    for fleet_name, (eff, perf) in (("paper", paper_fleet()), ("tpu", tpu_fleet())):
        for arch in list_archs():
            cfg = get_config(arch)
            t_in = crossover_threshold(cfg, eff, perf, axis="in", hi=8192)
            t_out = crossover_threshold(cfg, eff, perf, axis="out", hi=8192)
            rows.append([fleet_name, arch, t_in, t_out])
    _write("crossover_table", ["fleet", "arch", "t_in_crossover", "t_out_crossover"], rows)
    return rows
