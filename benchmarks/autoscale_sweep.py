"""Autoscale sweep: arrival process x linger x autoscaler vs. the static
fleet — the energy-proportionality frontier.

The paper's 7.5% saving routes against a fixed fleet; its own power model
(P = P_idle + (P_peak - P_idle)*util) makes allocated-idle draw the larger
lever at low utilization. This sweep runs the discrete-event simulator with
the power-state machine armed (``PoolSpec.linger_s``) and each
``AutoscalerPolicy`` variant, against the identical static fleet, and
records fleet energy (idle-inclusive), fleet J/token, p99 latency, SLO
attainment, wakes, and sleep fraction — the data behind the
fleet-energy-vs-p99 frontier plot in EXPERIMENTS.md §Autoscaling.

``--smoke`` is the CI regression gate (scripts/ci.sh). It asserts:
  * static-fleet equivalence: power states enabled but ``linger_s=inf`` and
    autoscaler off reproduces the plain fleet's energy bit-for-bit
    (per-request AND fleet totals);
  * energy proportionality: under the diurnal workload the autoscaled fleet
    strictly lowers fleet J/token vs. the static fleet at equal-or-better
    p99 SLO attainment.

Run: PYTHONPATH=src python benchmarks/autoscale_sweep.py [--queries N]
"""
from __future__ import annotations

import argparse
import math
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.core import (AutoscalerPolicy, CapacityAwareScheduler, PoolSpec,
                        QueueDepthAutoscaler, SingleSystemScheduler,
                        TargetUtilizationAutoscaler, WorkloadSpec,
                        paper_fleet, sample_workload, simulate_fleet)
from repro.core.pricing import normalized_cost_params

try:
    from benchmarks.bench_util import write_csv as _write
except ImportError:                      # standalone: benchmarks/ on sys.path
    from bench_util import write_csv as _write

PROCESSES = ("poisson", "diurnal", "mmpp")
LINGERS_S = (math.inf, 60.0, 15.0)
SLO_S = 30.0            # generous TTLT bound: wake latencies must hide in it
DIURNAL_PERIOD_S = 240.0  # compressed day/night cycle so a sweep-sized
DIURNAL_AMPLITUDE = 0.9   # workload spans multiple troughs


def _workload(process: str, n_queries: int, rate: float, seed: int):
    kwargs = {}
    if process == "diurnal":
        kwargs = dict(period_s=DIURNAL_PERIOD_S, amplitude=DIURNAL_AMPLITUDE)
    return sample_workload(n_queries, seed=seed, spec=WorkloadSpec(rate_qps=rate),
                           arrival_process=process, **kwargs)


def _scalers(period_s: float = 10.0) -> Dict[str, Optional[AutoscalerPolicy]]:
    return {
        "none": None,
        "target_util": TargetUtilizationAutoscaler(
            period_s=period_s, min_instances=1, target_util=0.6),
        "queue_depth": QueueDepthAutoscaler(
            period_s=period_s, min_instances=1, high=2, low=0),
    }


def autoscale_sweep(n_queries: int = 400, model: str = "llama2-7b",
                    rate: float = 1.0, seed: int = 0,
                    engine: str = "vectorized") -> List[List]:
    """process x linger x autoscaler over the hybrid fleet, identical
    workload per process so the frontier is apples-to-apples."""
    cfg = get_config(model)
    eff, perf = paper_fleet()
    cp = normalized_cost_params(cfg, perf, lam=0.9)
    rows = []
    for process in PROCESSES:
        qs = _workload(process, n_queries, rate, seed)
        for linger in LINGERS_S:
            for scaler_name, scaler in _scalers().items():
                if not math.isfinite(linger) and scaler is None:
                    label = "static"
                else:
                    label = f"linger{linger:g}+{scaler_name}"
                pools = {"eff": PoolSpec(eff, 4, 2, linger_s=linger),
                         "perf": PoolSpec(perf, 2, 4, linger_s=linger)}
                sched = CapacityAwareScheduler(
                    cfg, [eff, perf], {eff.name: 4, perf.name: 2}, cp)
                r = simulate_fleet(cfg, qs, pools, sched, policy_name=label,
                                   autoscaler=scaler, engine=engine)
                sleep_s = sum(p.sleep_s for p in r.per_pool.values())
                inst_s = sum(s.instances for s in pools.values()) * r.horizon_s
                rows.append([
                    process, f"{linger:g}", scaler_name,
                    f"{r.fleet_energy_j:.1f}", f"{r.fleet_j_per_token:.4f}",
                    f"{r.j_per_token:.4f}",
                    f"{r.p50_latency_s:.3f}", f"{r.p99_latency_s:.3f}",
                    f"{r.slo_attainment(SLO_S):.4f}",
                    sum(p.wake_count for p in r.per_pool.values()),
                    f"{sleep_s / max(inst_s, 1e-9):.3f}",
                ])
    _write("autoscale_sweep",
           ["process", "linger_s", "autoscaler", "fleet_energy_j",
            "fleet_j_per_tok", "j_per_tok", "p50_s", "p99_s",
            f"slo_att_{SLO_S:g}s", "wakes", "sleep_frac"], rows)
    return rows


def frontier(n_queries: int = 400, model: str = "llama2-7b",
             rate: float = 1.0, seed: int = 0,
             engine: str = "vectorized") -> List[List]:
    """Fleet-energy vs p99 frontier under the diurnal workload: one point
    per (linger, autoscaler) config on a single perf pool, so the effect is
    pure provisioning (no routing confound)."""
    cfg = get_config(model)
    _, perf = paper_fleet()
    qs = _workload("diurnal", n_queries, rate, seed)
    rows = []
    for linger in LINGERS_S:
        for scaler_name, scaler in _scalers().items():
            r = simulate_fleet(
                cfg, qs, {"perf": PoolSpec(perf, 4, 2, linger_s=linger)},
                SingleSystemScheduler(cfg, perf),
                policy_name=f"linger{linger:g}+{scaler_name}",
                autoscaler=scaler, engine=engine)
            rows.append([f"{linger:g}", scaler_name,
                         f"{r.fleet_energy_j:.1f}",
                         f"{r.fleet_j_per_token:.4f}",
                         f"{r.p99_latency_s:.3f}",
                         f"{r.slo_attainment(SLO_S):.4f}"])
    _write("autoscale_frontier",
           ["linger_s", "autoscaler", "fleet_energy_j", "fleet_j_per_tok",
            "p99_s", f"slo_att_{SLO_S:g}s"], rows)
    return rows


def smoke(n_queries: int = 120, model: str = "llama2-7b",
          engine: str = "vectorized") -> None:
    """CI gate (scripts/ci.sh): the two acceptance invariants, fixed seed."""
    from dataclasses import replace

    from repro.core import default_power_states

    cfg = get_config(model)
    _, perf = paper_fleet()
    qs = _workload("diurnal", n_queries, rate=1.0, seed=5)
    sched = lambda s=perf: SingleSystemScheduler(cfg, s)  # noqa: E731

    # 1. static-fleet equivalence. Two non-trivial armed variants against the
    # plain fleet: (a) an explicit power-state table attached to the profile
    # with linger=inf and no autoscaler; (b) an ENGAGED machine (autoscaler
    # ticking) whose min_instances floor equals the pool size, so it may
    # never act. Both must be bit-for-bit the plain run.
    plain = simulate_fleet(cfg, qs, {"perf": PoolSpec(perf, 4, 2)}, sched(),
                           engine=engine)
    tabled = replace(perf, power_states=default_power_states(perf))
    variants = {
        "power-states attached, linger=inf": simulate_fleet(
            cfg, qs, {"perf": PoolSpec(tabled, 4, 2, linger_s=math.inf)},
            sched(tabled), engine=engine),
        "autoscaler engaged but floored": simulate_fleet(
            cfg, qs, {"perf": PoolSpec(perf, 4, 2)}, sched(),
            autoscaler=TargetUtilizationAutoscaler(period_s=10.0,
                                                   min_instances=4),
            engine=engine),
    }
    rel = 0.0
    for name, armed in variants.items():
        rel = abs(armed.fleet_energy_j - plain.fleet_energy_j) \
            / plain.fleet_energy_j
        assert rel < 1e-9, f"equivalence broken ({name}): rel={rel:.2e}"
        for a, b in zip(armed.records, plain.records):
            assert a.energy_j == b.energy_j, \
                f"per-request energy drifted ({name}): rid={a.rid}"

    # 2. energy proportionality: autoscaled diurnal fleet strictly cheaper
    # per token at equal-or-better SLO attainment
    auto = simulate_fleet(
        cfg, qs, {"perf": PoolSpec(perf, 4, 2, linger_s=20.0)}, sched(),
        autoscaler=TargetUtilizationAutoscaler(period_s=10.0, min_instances=1,
                                               target_util=0.6),
        engine=engine)
    assert len(auto.records) == len(qs), "autoscaled fleet lost requests"
    att_s, att_a = plain.slo_attainment(SLO_S), auto.slo_attainment(SLO_S)
    assert att_a >= att_s, f"SLO attainment regressed: {att_a} < {att_s}"
    assert auto.fleet_j_per_token < plain.fleet_j_per_token, (
        f"autoscaler failed to lower fleet J/token: "
        f"{auto.fleet_j_per_token:.4f} >= {plain.fleet_j_per_token:.4f}")
    saving = 1 - auto.fleet_j_per_token / plain.fleet_j_per_token
    print(f"autoscale smoke OK: equivalence rel={rel:.1e}, diurnal fleet "
          f"J/token -{saving:.0%} at SLO attainment {att_a:.2f} "
          f"(static {att_s:.2f})")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--model", default="llama2-7b")
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-seed CI gate; asserts invariants")
    ap.add_argument("--engine", default="vectorized",
                    choices=("event", "vectorized"),
                    help="fleet-sim core (bit-for-bit equivalent engines)")
    args = ap.parse_args()

    if args.smoke:
        smoke(min(args.queries, 120), args.model, engine=args.engine)
        return

    print("== energy-vs-p99 frontier (diurnal, single perf pool) ==")
    for row in frontier(args.queries, args.model, args.rate):
        print(",".join(str(x) for x in row))

    print("== process x linger x autoscaler sweep (hybrid fleet) ==")
    for row in autoscale_sweep(args.queries, args.model, args.rate):
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
