"""Wall-clock microbenchmarks of the real JAX serving/training steps
(reduced configs — CPU container; TPU numbers come from the roofline)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import make_train_step


def _bench(fn, *args, iters: int = 5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def serving_microbench() -> List:
    rows = []
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_len=128)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    logits, cache = eng.prefill(batch)
    rows.append(["prefill_b4_s32", _bench(lambda: eng.prefill(batch)[0])])
    tok = jnp.zeros((4, 1), jnp.int32)
    rows.append(["decode_b4", _bench(lambda: eng.decode(tok, cache)[0])])

    opt = OPT.AdamWConfig()
    step = jax.jit(make_train_step(cfg, opt))
    state = OPT.init_state(params)
    tb = next(D.uniform_stream(cfg, 4, 64, 1))
    rows.append(["train_step_b4_s64",
                 _bench(lambda: step(params, state, tb)[2]["loss"])])
    return rows
