"""Wall-clock microbenchmarks of the real JAX serving/training steps
(reduced configs — CPU container; TPU numbers come from the roofline), plus
``kernel_phase_samples``: timed invocations of the shipped attention/SSM
kernels with their analytic work counts attached — the measurement feed for
``core.pricing.fit_calibration`` / ``CalibratedOracle``."""
from __future__ import annotations

import functools
import statistics
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pricing import KernelSample
from repro.kernels import ops
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import make_train_step


def _time(fn, *args, iters: int = 5) -> Tuple[float, float]:
    """(best seconds, noise_frac) per call, compile + warmup excluded.

    Every timed repetition blocks on the result INSIDE its own timed region
    (async dispatch would otherwise attribute one call's device time to a
    later iteration). Best-of-k, not mean: shared-host scheduling noise is
    strictly additive, so the minimum is the best estimator of the kernel's
    own time. noise_frac = (median - best) / best is the spread the
    calibration fit uses to down-weight noisy samples.
    """
    for _ in range(2):
        jax.block_until_ready(fn(*args))  # compile + warmup
    reps = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        reps.append(time.perf_counter() - t0)
    best = min(reps)
    med = statistics.median(reps)
    return best, (med - best) / best if best > 0 else 0.0


def _time_s(fn, *args, iters: int = 5) -> float:
    """Best wall seconds per call (see ``_time``)."""
    return _time(fn, *args, iters=iters)[0]


def _bench(fn, *args, iters: int = 5):
    """Best-of-k microseconds per call (same hygiene as ``_time``)."""
    return _time(fn, *args, iters=iters)[0] * 1e6


# ------------------------------------------------------- calibration samples
def time_kernel(kernel: str, shape: Mapping[str, int], *,
                params: Optional[Mapping[str, object]] = None,
                backend: Optional[str] = None, iters: int = 5, seed: int = 0,
                heads: int = 4, kv_heads: int = 2, head_dim: int = 64,
                state_dim: int = 64, ssm_head_dim: int = 64,
                page_block: int = 16) -> KernelSample:
    """Time ONE kernel cell through ``kernels.ops`` dispatch.

    ``kernel`` is one of "flash_attention" (shape {"s", ["b"]}),
    "decode_attention" / "paged_decode_quant" (shape {"b", "c"}), or
    "ssm_scan" (shape {"s", ["b"]}). ``params`` are the tile/impl kwargs to
    pin for this measurement (the autotuner's candidate grid; None = the
    dispatch defaults). Returns a ``KernelSample`` carrying the cell's
    analytic work counts and the best-of-k time + noise — the unit the
    autotuner (``kernels.autotune``) and ``kernel_phase_samples`` are built
    on.
    """
    rng = np.random.default_rng(seed)
    bk = {"backend": backend} if backend else {}
    p: Dict[str, object] = dict(params) if params else {}
    isz = 4  # float32
    Hq, Hkv, Dh = heads, kv_heads, head_dim
    # The jnp stand-in path (non-TPU hosts) materializes the (Sq, Sk) score
    # matrix that the fused Pallas kernel keeps in VMEM — count those bytes
    # when that is the variant actually being timed, so the fit targets the
    # measured kernel, not an idealized one.
    materializes_scores = ops.resolve_backend(backend or "auto") == "ref"

    if kernel == "flash_attention":
        B, S = int(shape.get("b", 1)), int(shape["s"])
        fa = jax.jit(functools.partial(ops.flash_attention, causal=True,
                                       **p, **bk))
        q = jnp.asarray(rng.normal(size=(B, Hq, S, Dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
        t, nf = _time(fa, q, k, v, iters=iters)
        if materializes_scores:
            # the jnp path computes the FULL unmasked S x S einsums and masks
            # afterward — no causal halving in executed FLOPs
            flops = 4.0 * B * Hq * S * S * Dh
        else:
            flops = 2.0 * B * Hq * S * S * Dh          # QK^T + PV, causal-halved
        byts = isz * (2.0 * B * Hq * S * Dh + 2.0 * B * Hkv * S * Dh)
        if materializes_scores:
            byts += isz * 3.0 * B * Hq * S * S         # scores: write, softmax, read
        return KernelSample("flash_attention", flops, byts, float(S), t, nf)

    if kernel == "decode_attention":
        B, ctx = int(shape["b"]), int(shape["c"])
        da = jax.jit(functools.partial(ops.decode_attention, **p, **bk))
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, Dh)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(B, Hkv, ctx, Dh)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(B, Hkv, ctx, Dh)), jnp.float32)
        kv_len = jnp.full((B,), ctx, jnp.int32)
        t, nf = _time(da, q, kc, vc, kv_len, iters=iters)
        flops = 4.0 * B * Hq * ctx * Dh                # QK^T + PV at length ctx
        byts = isz * (2.0 * B * Hkv * ctx * Dh + 2.0 * B * Hq * Dh)
        if materializes_scores:
            byts += isz * 3.0 * B * Hq * ctx
        return KernelSample("decode_attention", flops, byts, float(ctx), t, nf)

    if kernel == "paged_decode_quant":
        B, ctx = int(shape["b"]), int(shape["c"])
        if ctx % page_block:
            raise ValueError(f"ctx {ctx} not a multiple of page_block "
                             f"{page_block}")
        mb = ctx // page_block
        nb = 1 + B * mb                                # block 0 = null block
        pq = jax.jit(functools.partial(ops.paged_decode_attention_quant,
                                       **p, **bk))
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, Dh)), jnp.float32)
        kp = jnp.asarray(rng.integers(-127, 128, size=(nb, Hkv, page_block, Dh)),
                         jnp.int8)
        vp = jnp.asarray(rng.integers(-127, 128, size=(nb, Hkv, page_block, Dh)),
                         jnp.int8)
        ks = jnp.asarray(rng.uniform(0.005, 0.02,
                                     size=(nb, Hkv, page_block, 1)), jnp.float32)
        vs = jnp.asarray(rng.uniform(0.005, 0.02,
                                     size=(nb, Hkv, page_block, 1)), jnp.float32)
        tables = jnp.asarray(np.arange(1, 1 + B * mb).reshape(B, mb), jnp.int32)
        kv_len = jnp.full((B,), ctx, jnp.int32)
        t, nf = _time(pq, q, kp, vp, ks, vs, tables, kv_len, iters=iters)
        # attention matmuls + the per-element dequantize multiplies
        flops = 4.0 * B * Hq * ctx * Dh + 4.0 * B * Hkv * ctx * Dh
        byts = (1.0 * 2.0 * B * Hkv * ctx * Dh        # int8 K/V pool reads
                + isz * 2.0 * B * Hkv * ctx           # scale columns
                + isz * 2.0 * B * Hq * Dh)            # q + out
        if p.get("impl", "gather") == "gather":
            # gather-dequantize materializes f32 copies of BOTH caches
            # (write + re-read by the dense kernel)
            byts += isz * 4.0 * B * Hkv * ctx * Dh
        if materializes_scores:
            byts += isz * 3.0 * B * Hq * ctx
        return KernelSample("paged_decode_quant", flops, byts, float(ctx), t, nf)

    if kernel == "kv_migrate":
        # Device-side KV-block migration (serving.batching.migrate_kv_blocks):
        # gather `blocks` K/V blocks from a source paged pool, scatter them
        # into a destination pool. Pure data movement — flops 0, so the
        # sample constrains the calibration's effective memory bandwidth
        # (mem_eff), which migration_seconds reads through oracle.resolve.
        nblk = int(shape.get("blocks", shape.get("b", 8)))
        nb = 1 + nblk                                  # block 0 = null block
        sk = jnp.asarray(rng.normal(size=(nb, Hkv, page_block, Dh)), jnp.float32)
        sv = jnp.asarray(rng.normal(size=(nb, Hkv, page_block, Dh)), jnp.float32)
        dk = jnp.zeros_like(sk)
        dv = jnp.zeros_like(sv)
        ids = jnp.arange(1, 1 + nblk, dtype=jnp.int32)

        @jax.jit
        def mv(sk, sv, dk, dv, ids):
            return dk.at[ids].set(sk[ids]), dv.at[ids].set(sv[ids])

        t, nf = _time(mv, sk, sv, dk, dv, ids, iters=iters)
        # K+V payload, read once from the source pool + written once into
        # the destination pool
        byts = isz * 2.0 * 2.0 * nblk * Hkv * page_block * Dh
        return KernelSample("kv_migrate", 0.0, byts, 0.0, t, nf)

    if kernel == "ssm_scan":
        B, S = int(shape.get("b", 1)), int(shape["s"])
        H, P, N = heads, ssm_head_dim, state_dim
        chunk = int(p.get("chunk", 128))               # executed-FLOPs driver
        ss = jax.jit(functools.partial(ops.ssd_scan, chunk=chunk, **bk))
        x = jnp.asarray(rng.normal(size=(B, H, S, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.2, size=(B, H, S)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 4.0, size=(H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        t, nf = _time(ss, x, dt, A, Bm, Cm, iters=iters)
        # chunked dual form: CB^T + att@x per chunk, C@state + state update
        flops = 2.0 * B * H * S * (chunk * N + chunk * P + 2.0 * N * P)
        byts = isz * (2.0 * B * H * S * P + 2.0 * B * S * N + B * H * S)
        return KernelSample("ssm_scan", flops, byts, 0.0, t, nf)

    raise KeyError(f"unknown kernel {kernel!r}")


def kernel_phase_samples(*, prefill_lens: Sequence[int] = (128, 256, 512, 1024),
                         decode_ctxs: Sequence[int] = (128, 256, 512, 1024,
                                                       2048, 4096),
                         ssm_lens: Sequence[int] = (256, 512, 1024),
                         paged_ctxs: Sequence[int] = (),
                         migrate_blocks: Sequence[int] = (),
                         batch: int = 1, heads: int = 4, kv_heads: int = 2,
                         head_dim: int = 64, state_dim: int = 64,
                         ssm_head_dim: int = 64, iters: int = 5,
                         backend: Optional[str] = None,
                         seed: int = 0, tuned=None) -> List[KernelSample]:
    """Time the real kernels behind the serving stack and return samples the
    roofline calibration can fit (``fit_calibration``).

    Kernels go through ``kernels.ops`` backend dispatch: compiled Pallas on
    TPU, the structurally identical jnp path elsewhere — so the same command
    calibrates whichever hardware it runs on. FLOPs/bytes are the kernel's
    analytic work for the timed shape; ``ctx`` is the context length that
    drives ``SystemProfile.sat_ctx`` degradation (0 for the SSD scan, whose
    running state is constant-size).

    ``tuned`` (an ``autotune.AutotuneCache``) re-measures every cell with its
    autotuned parameters pinned explicitly — the re-measurement feed for the
    oracle-refresh parity gate. None keeps the dispatch defaults.
    """
    from repro.kernels import autotune as AT
    b = ops.resolve_backend(backend or "auto")

    def tuned_params(kernel: str, **dims) -> Optional[Dict[str, object]]:
        if tuned is None:
            return None
        return tuned.resolve(kernel, b, AT.shape_bucket(kernel, **dims))

    dims = dict(heads=heads, kv_heads=kv_heads, head_dim=head_dim,
                state_dim=state_dim, ssm_head_dim=ssm_head_dim)
    out: List[KernelSample] = []
    for S in prefill_lens:
        out.append(time_kernel("flash_attention", {"b": batch, "s": S},
                               params=tuned_params("flash_attention", s=S),
                               backend=backend, iters=iters, seed=seed, **dims))
    for ctx in decode_ctxs:
        out.append(time_kernel("decode_attention", {"b": batch, "c": ctx},
                               params=tuned_params("decode_attention",
                                                   b=batch, c=ctx),
                               backend=backend, iters=iters, seed=seed, **dims))
    for ctx in paged_ctxs:
        out.append(time_kernel("paged_decode_quant", {"b": batch, "c": ctx},
                               params=tuned_params("paged_decode_quant",
                                                   b=batch, c=ctx),
                               backend=backend, iters=iters, seed=seed, **dims))
    for nblk in migrate_blocks:
        out.append(time_kernel("kv_migrate", {"blocks": nblk},
                               backend=backend, iters=iters, seed=seed, **dims))
    for S in ssm_lens:
        out.append(time_kernel("ssm_scan", {"b": batch, "s": S},
                               params=tuned_params("ssm_scan", s=S),
                               backend=backend, iters=iters, seed=seed, **dims))
    return out


def serving_microbench() -> List:
    rows = []
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_len=128)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    logits, cache = eng.prefill(batch)
    rows.append(["prefill_b4_s32", _bench(lambda: eng.prefill(batch)[0])])
    tok = jnp.zeros((4, 1), jnp.int32)
    rows.append(["decode_b4", _bench(lambda: eng.decode(tok, cache)[0])])

    opt = OPT.AdamWConfig()
    step = jax.jit(make_train_step(cfg, opt))
    state = OPT.init_state(params)
    tb = next(D.uniform_stream(cfg, 4, 64, 1))
    rows.append(["train_step_b4_s64",
                 _bench(lambda: step(params, state, tb)[2]["loss"])])
    return rows
