"""Wall-clock microbenchmarks of the real JAX serving/training steps
(reduced configs — CPU container; TPU numbers come from the roofline), plus
``kernel_phase_samples``: timed invocations of the shipped attention/SSM
kernels with their analytic work counts attached — the measurement feed for
``core.pricing.fit_calibration`` / ``CalibratedOracle``."""
from __future__ import annotations

import functools
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pricing import KernelSample
from repro.kernels import ops
from repro.models import model as M
from repro.serving.engine import InferenceEngine
from repro.training import data as D
from repro.training import optimizer as OPT
from repro.training.train import make_train_step


def _bench(fn, *args, iters: int = 5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _time_s(fn, *args, iters: int = 5) -> float:
    """Min wall seconds per call (compile + warmup excluded). Min, not mean:
    shared-host scheduling noise is strictly additive, so the minimum is the
    best estimator of the kernel's own time."""
    for _ in range(2):
        out = fn(*args)                   # compile + warmup
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ------------------------------------------------------- calibration samples
def kernel_phase_samples(*, prefill_lens: Sequence[int] = (128, 256, 512, 1024),
                         decode_ctxs: Sequence[int] = (128, 256, 512, 1024,
                                                       2048, 4096),
                         ssm_lens: Sequence[int] = (256, 512, 1024),
                         batch: int = 1, heads: int = 4, kv_heads: int = 2,
                         head_dim: int = 64, state_dim: int = 64,
                         ssm_head_dim: int = 64, iters: int = 5,
                         backend: Optional[str] = None,
                         seed: int = 0) -> List[KernelSample]:
    """Time the real kernels behind the serving stack and return samples the
    roofline calibration can fit (``fit_calibration``).

    Kernels go through ``kernels.ops`` backend dispatch: compiled Pallas on
    TPU, the structurally identical jnp path elsewhere — so the same command
    calibrates whichever hardware it runs on. FLOPs/bytes are the kernel's
    analytic work for the timed shape; ``ctx`` is the context length that
    drives ``SystemProfile.sat_ctx`` degradation (0 for the SSD scan, whose
    running state is constant-size).
    """
    rng = np.random.default_rng(seed)
    bk = {"backend": backend} if backend else {}
    isz = 4  # float32
    B, Hq, Hkv, Dh = batch, heads, kv_heads, head_dim
    out: List[KernelSample] = []
    # The jnp stand-in path (non-TPU hosts) materializes the (Sq, Sk) score
    # matrix that the fused Pallas kernel keeps in VMEM — count those bytes
    # when that is the variant actually being timed, so the fit targets the
    # measured kernel, not an idealized one.
    materializes_scores = ops.resolve_backend(backend or "auto") == "ref"

    # ---- flash attention (prefill phase) ----
    fa = jax.jit(functools.partial(ops.flash_attention, causal=True, **bk))
    for S in prefill_lens:
        q = jnp.asarray(rng.normal(size=(B, Hq, S, Dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Hkv, S, Dh)), jnp.float32)
        t = _time_s(fa, q, k, v, iters=iters)
        if materializes_scores:
            # the jnp path computes the FULL unmasked S x S einsums and masks
            # afterward — no causal halving in executed FLOPs
            flops = 4.0 * B * Hq * S * S * Dh
        else:
            flops = 2.0 * B * Hq * S * S * Dh          # QK^T + PV, causal-halved
        byts = isz * (2.0 * B * Hq * S * Dh + 2.0 * B * Hkv * S * Dh)
        if materializes_scores:
            byts += isz * 3.0 * B * Hq * S * S         # scores: write, softmax, read
        out.append(KernelSample("flash_attention", flops, byts, float(S), t))

    # ---- decode attention (per-token decode phase) ----
    da = jax.jit(functools.partial(ops.decode_attention, **bk))
    for ctx in decode_ctxs:
        q = jnp.asarray(rng.normal(size=(B, Hq, 1, Dh)), jnp.float32)
        kc = jnp.asarray(rng.normal(size=(B, Hkv, ctx, Dh)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(B, Hkv, ctx, Dh)), jnp.float32)
        kv_len = jnp.full((B,), ctx, jnp.int32)
        t = _time_s(da, q, kc, vc, kv_len, iters=iters)
        flops = 4.0 * B * Hq * ctx * Dh                # QK^T + PV at length ctx
        byts = isz * (2.0 * B * Hkv * ctx * Dh + 2.0 * B * Hq * Dh)
        if materializes_scores:
            byts += isz * 3.0 * B * Hq * ctx
        out.append(KernelSample("decode_attention", flops, byts, float(ctx), t))

    # ---- SSD scan (SSM prefill phase) ----
    H, P, N, chunk = heads, ssm_head_dim, state_dim, 128
    ss = jax.jit(functools.partial(ops.ssd_scan, chunk=chunk, **bk))
    for S in ssm_lens:
        x = jnp.asarray(rng.normal(size=(B, H, S, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.001, 0.2, size=(B, H, S)), jnp.float32)
        A = jnp.asarray(-rng.uniform(0.5, 4.0, size=(H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, N)), jnp.float32)
        t = _time_s(ss, x, dt, A, Bm, Cm, iters=iters)
        # chunked dual form: CB^T + att@x per chunk, C@state + state update
        flops = 2.0 * B * H * S * (chunk * N + chunk * P + 2.0 * N * P)
        byts = isz * (2.0 * B * H * S * P + 2.0 * B * S * N + B * H * S)
        out.append(KernelSample("ssm_scan", flops, byts, 0.0, t))

    return out


def serving_microbench() -> List:
    rows = []
    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = InferenceEngine(cfg, params, max_len=128)
    batch = {"tokens": jnp.zeros((4, 32), jnp.int32)}
    logits, cache = eng.prefill(batch)
    rows.append(["prefill_b4_s32", _bench(lambda: eng.prefill(batch)[0])])
    tok = jnp.zeros((4, 1), jnp.int32)
    rows.append(["decode_b4", _bench(lambda: eng.decode(tok, cache)[0])])

    opt = OPT.AdamWConfig()
    step = jax.jit(make_train_step(cfg, opt))
    state = OPT.init_state(params)
    tb = next(D.uniform_stream(cfg, 4, 64, 1))
    rows.append(["train_step_b4_s64",
                 _bench(lambda: step(params, state, tb)[2]["loss"])])
    return rows
