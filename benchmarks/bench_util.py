"""Shared helpers for the benchmark scripts.

Import works both ways the scripts are run: standalone
(``python benchmarks/foo.py`` puts this directory on ``sys.path``) and as a
package module (``from benchmarks import foo`` via ``benchmarks/run.py``).
"""
from __future__ import annotations

import os
from typing import List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def write_csv(name: str, header: List[str], rows: List[List],
              out_dir: str = OUT_DIR) -> str:
    """Write one benchmark artifact ``<out_dir>/<name>.csv``; returns path."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
