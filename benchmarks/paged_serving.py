"""Paged-serving smoke gate: dense/paged parity + block reuse.

Runs a tiny model through both continuous-batching runtimes on the same
greedy workload (budget-capped, EOS-retired, and shared-prefix requests) and
asserts:

  * token-for-token parity between the dense ``ContinuousBatcher`` and the
    ``PagedContinuousBatcher`` (chunked prefill + block tables);
  * non-zero prefix-block reuse on the shared-prefix portion, with fresh
    allocations strictly below the no-sharing block total;
  * memory-aware admission never exceeds the pool (peak <= total blocks).

Failures here mean the paged runtime broke, not just a benchmark.

Run: PYTHONPATH=src python benchmarks/paged_serving.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serving.batching import (ContinuousBatcher, PagedContinuousBatcher,
                                    Request)
from repro.serving.engine import InferenceEngine

BLOCK = 8
CHUNK = 8


def _workload(cfg, n_plain: int, n_shared: int, budget: int, eos_id=None):
    reqs = []
    for i in range(n_plain):
        reqs.append(Request(len(reqs), np.arange(4 + 5 * i) % cfg.vocab_size,
                            budget, eos_id=eos_id))
    prefix = (np.arange(3 * BLOCK) * 2 + 1) % cfg.vocab_size
    for i in range(n_shared):
        prompt = np.concatenate([prefix, np.array([i + 1, i + 2])]) % cfg.vocab_size
        reqs.append(Request(len(reqs), prompt, budget, eos_id=eos_id))
    return reqs


def _run(batcher, reqs):
    for r in reqs:
        batcher.submit(r)
    t0 = time.perf_counter()
    batcher.run()
    return time.perf_counter() - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed workload (the CI gate)")
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--budget", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    engine = InferenceEngine(cfg, params, max_len=96)
    n_plain, n_shared = (3, 4) if args.smoke else (6, 8)

    # EOS chosen from an unconstrained run so some requests retire early
    probe = engine.generate(
        {"tokens": jnp.asarray(np.arange(8) % cfg.vocab_size, jnp.int32)[None]},
        args.budget)
    eos_id = int(probe.tokens[0][-1])

    dense_reqs = _workload(cfg, n_plain, n_shared, args.budget, eos_id)
    paged_reqs = _workload(cfg, n_plain, n_shared, args.budget, eos_id)

    t_dense = _run(ContinuousBatcher(engine, slots=2), dense_reqs)
    paged = PagedContinuousBatcher(engine, slots=2, num_blocks=64,
                                   block_size=BLOCK, chunk=CHUNK)
    t_paged = _run(paged, paged_reqs)

    mismatches = sum(a.out_tokens != b.out_tokens
                     for a, b in zip(dense_reqs, paged_reqs))
    st = paged.stats()
    no_share = sum(-(-(len(r.tokens) + r.max_new_tokens) // BLOCK)
                   for r in paged_reqs)

    print(f"paged_serving smoke: {len(paged_reqs)} requests "
          f"(budget={args.budget}, eos={eos_id})")
    print(f"  dense  {t_dense:6.2f}s | paged {t_paged:6.2f}s")
    print(f"  parity: {len(paged_reqs) - mismatches}/{len(paged_reqs)} identical")
    print(f"  blocks: fresh={st['fresh_allocs']} no-share-total={no_share} "
          f"prefix_hits={st['prefix_hits']} peak={st['peak_used']}/"
          f"{st['total_blocks']}")

    assert all(r.done for r in paged_reqs), "paged runtime left requests undone"
    assert mismatches == 0, f"{mismatches} requests diverged from dense path"
    assert st["prefix_hits"] > 0, "shared-prefix workload produced no block reuse"
    assert st["fresh_allocs"] < no_share, "no allocation saving from sharing"
    assert st["peak_used"] <= st["total_blocks"], "admission exceeded the pool"
    print("  OK")


if __name__ == "__main__":
    main()
