"""§Perf hillclimb driver: measure roofline terms for one (arch x shape) pair
under a set of implementation-variant env flags, WITHOUT touching the cached
baseline artifacts.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb --arch deepseek-7b \
      --shape decode_32k --set REPRO_CACHE_MODE=carry
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="VAR=value env flags for the variant under test")
    ap.add_argument("--tag", default="variant")
    args = ap.parse_args()
    for kv in args.set:
        k, v = kv.split("=", 1)
        os.environ[k] = v

    from repro.configs import get_config, get_shape
    from repro.launch.dryrun import (analyze, component_analysis,
                                     lower_and_compile)
    from repro.launch.mesh import make_production_mesh

    PEAK, HBM, ICI = 197e12, 819e9, 50e9
    cfg = get_config(args.arch)
    shape = get_shape(args.shape)
    if os.environ.get("REPRO_MESH") == "moe" and cfg.moe:
        mesh = make_production_mesh(moe_experts=cfg.moe.num_experts)
    else:
        mesh = make_production_mesh()
    compiled, times = lower_and_compile(cfg, shape, mesh)
    full = analyze(compiled)
    del compiled
    ex = component_analysis(cfg, shape, mesh)
    rec = {"arch": args.arch, "shape": args.shape, "tag": args.tag,
           "env": args.set, "full": full, "extrapolated": ex,
           "t_compute": ex["hlo_flops"] / PEAK,
           "t_memory": ex["hlo_bytes"] / HBM,
           "t_collective": ex["collective_bytes"] / ICI}
    out_dir = os.path.join(os.path.dirname(__file__), "..", "experiments", "perf")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"{args.tag}: t_compute={rec['t_compute']:.4e}s "
          f"t_memory={rec['t_memory']:.4e}s t_collective={rec['t_collective']:.4e}s")
    print(f"  temp/dev={full.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB "
          f"args/dev={full.get('argument_size_in_bytes', 0) / 2**30:.2f}GiB")
    print(f"  coll detail: " + " ".join(
        f"{k}={v:.3e}" for k, v in ex.items() if k.startswith("coll_")))
    print(f"  -> {path}")


if __name__ == "__main__":
    main()
