"""Calibrate `SystemProfile` roofline constants against kernel timings.

Two modes, both writing per-profile artifacts under `experiments/calibration/`:

  * **measured** (default): time the real kernels via
    `benchmarks.microbench.kernel_phase_samples` (compiled Pallas on TPU, the
    structurally identical jnp path elsewhere) and fit
    `compute_eff` / `mem_eff` / `sat_ctx` / `overhead_s` for the profile the
    host represents (`--profile`, default the local `host-cpu` profile).
  * **--synthetic**: validate the fitting pipeline per shipped fleet profile —
    generate timings from the analytic model at perturbed ground-truth
    constants (+ seeded noise), fit, and assert both the fit error and the
    parameter recovery are below the documented bounds (exit 1 otherwise).
    This is the CI smoke (`scripts/ci.sh`).

Fit-error bounds (documented in EXPERIMENTS.md §Calibration):
  synthetic recovery rel-RMSE < 0.08 (noise floor 3%), measured < 0.35
  (CPU wall clocks are noisy and the container is shared).

Run: PYTHONPATH=src python benchmarks/calibrate.py [--synthetic] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import asdict, replace
from typing import List, Optional, Sequence

import numpy as np

from repro.core.pricing import (Calibration, CalibratedOracle, KernelSample,
                                _predict, fit_calibration)
from repro.core.systems import PROFILES, SystemProfile

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "calibration")

SYNTH_REL_RMSE_BOUND = 0.08   # noise floor is 3%; fit must sit near it
MEASURED_REL_RMSE_BOUND = 0.35

# What this container's host looks like as a SystemProfile: nominal CPU
# peak/bandwidth; the fitted efficiencies absorb the real achievable
# fractions, which is the whole point of calibrating.
HOST_CPU = SystemProfile(
    name="host-cpu", kind="eff", chips=1,
    peak_flops=2.0e11, hbm_bw=5.0e10, ici_bw=0.0,
    power_peak_w=65.0, power_idle_w=10.0, overhead_s=1e-3,
)


def _seed_constants(s: SystemProfile) -> dict:
    return {"compute_eff": s.compute_eff, "mem_eff": s.mem_eff,
            "sat_ctx": s.sat_ctx, "overhead_s": s.overhead_s}


def _write_artifact(profile: SystemProfile, cal: Calibration,
                    samples: Sequence[KernelSample], mode: str,
                    out_dir: str = OUT_DIR) -> str:
    os.makedirs(out_dir, exist_ok=True)
    seed_pred = _predict(samples, profile, profile.compute_eff,
                         profile.mem_eff, profile.sat_ctx, profile.overhead_s)
    t = np.array([s.t_s for s in samples])
    seed_rmse = float(np.sqrt(np.mean(((seed_pred - t) / t) ** 2)))
    path = os.path.join(out_dir, f"{profile.name}.json")
    with open(path, "w") as f:
        json.dump({
            "mode": mode,
            "calibrations": [asdict(cal)],           # CalibratedOracle.load format
            "seed_constants": _seed_constants(profile),
            "seed_rel_rmse": seed_rmse,
            "fit_rel_rmse": cal.fit_rel_rmse,
            "samples": [asdict(s) for s in samples],
        }, f, indent=2, sort_keys=True)
    return path


# ----------------------------------------------------------------- synthetic
def synthetic_samples(profile: SystemProfile, truth: SystemProfile, *,
                      n: int = 40, noise: float = 0.03,
                      seed: int = 0) -> List[KernelSample]:
    """Timings the analytic model would produce at ``truth``'s constants,
    with seeded multiplicative noise — ground-truth recovery harness."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        # straddle the machine-balance ridge so BOTH efficiencies bind on
        # some samples (otherwise compute_eff is unidentifiable on
        # bandwidth-rich profiles): base is the bound phase's seconds, r the
        # log10 distance from the roofline knee (sign picks the regime)
        base = float(10 ** rng.uniform(-3.0, 0.0))
        r = float(rng.uniform(-1.5, 1.5))
        f = base * truth.compute_eff * profile.instance_peak_flops \
            / (10 ** max(0.0, -r))
        b = base * truth.mem_eff * profile.instance_hbm_bw \
            / (10 ** max(0.0, r))
        ctx = float(rng.integers(0, 4096))
        t = _predict([KernelSample("synthetic", f, b, ctx, 1.0)], profile,
                     truth.compute_eff, truth.mem_eff, truth.sat_ctx,
                     truth.overhead_s)[0]
        t *= float(1.0 + rng.normal(0.0, noise))
        out.append(KernelSample("synthetic", f, b, ctx, max(t, 1e-9)))
    return out


def run_synthetic(profiles: Sequence[str], *, n: int = 40,
                  seed: int = 0, out_dir: str = OUT_DIR) -> bool:
    """Per-profile ground-truth recovery; returns True iff all in bounds."""
    ok = True
    for name in profiles:
        p = PROFILES[name]
        truth = replace(p,
                        compute_eff=p.compute_eff * 0.8,
                        mem_eff=p.mem_eff * 0.85,
                        sat_ctx=(p.sat_ctx * 1.3) if p.sat_ctx else None,
                        overhead_s=p.overhead_s * 1.5)
        samples = synthetic_samples(p, truth, n=n, seed=seed)
        cal = fit_calibration(p, samples, fit_sat_ctx=p.sat_ctx is not None)
        path = _write_artifact(p, cal, samples, "synthetic", out_dir)
        good = cal.fit_rel_rmse < SYNTH_REL_RMSE_BOUND
        ce_err = abs(cal.compute_eff - truth.compute_eff) / truth.compute_eff
        good &= ce_err < 0.25
        ok &= good
        print(f"[synthetic] {name}: rel_rmse={cal.fit_rel_rmse:.4f} "
              f"(bound {SYNTH_REL_RMSE_BOUND}), ce {truth.compute_eff:.3f}"
              f"->{cal.compute_eff:.3f}, {'OK' if good else 'FAIL'} -> {path}")
    return ok


# ------------------------------------------------------------------ measured
def run_measured(profile: Optional[str], *, iters: int = 10,
                 smoke: bool = False, out_dir: str = OUT_DIR) -> bool:
    from benchmarks.microbench import kernel_phase_samples
    p = PROFILES.get(profile) if profile else HOST_CPU
    if p is None:
        p = HOST_CPU
    kw = dict(prefill_lens=(128, 256), decode_ctxs=(128, 512),
              ssm_lens=(256,), iters=2) if smoke else dict(iters=iters)
    samples = kernel_phase_samples(**kw)
    # sat_ctx is fit too: host caches make long-context decode superlinear,
    # which is precisely the degradation term the profile carries
    cal = fit_calibration(p, samples)
    path = _write_artifact(p, cal, samples, "measured", out_dir)
    ok = cal.fit_rel_rmse < MEASURED_REL_RMSE_BOUND
    print(f"[measured] {p.name}: rel_rmse={cal.fit_rel_rmse:.4f} "
          f"(bound {MEASURED_REL_RMSE_BOUND}), ce={cal.compute_eff:.2e}, "
          f"me={cal.mem_eff:.2e}, overhead={cal.overhead_s * 1e3:.3f}ms, "
          f"{'OK' if ok else 'FAIL'} -> {path}")
    # show the oracle loads back
    oracle = CalibratedOracle.load(path)
    print(f"           loaded {oracle!r}")
    return ok


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", default=None,
                    help="SystemProfile to calibrate (default: host-cpu)")
    ap.add_argument("--synthetic", action="store_true",
                    help="ground-truth recovery validation per fleet profile")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI)")
    ap.add_argument("--samples", type=int, default=40,
                    help="synthetic sample count")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # smoke runs validate the pipeline but must not clobber the recorded
    # full-sample artifacts
    out_dir = OUT_DIR
    if args.smoke:
        import tempfile
        out_dir = tempfile.mkdtemp(prefix="calibration-smoke-")

    if args.synthetic:
        profiles = ([args.profile] if args.profile
                    else ["m1-pro", "swing-a100", "tpu-v5e-perf",
                          "tpu-v5lite-eff"])
        n = 16 if args.smoke else args.samples
        return 0 if run_synthetic(profiles, n=n, seed=args.seed,
                                  out_dir=out_dir) else 1
    return 0 if run_measured(args.profile, smoke=args.smoke,
                             out_dir=out_dir) else 1


if __name__ == "__main__":
    sys.exit(main())
