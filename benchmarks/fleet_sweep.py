"""Fleet sweep: rate x instance-mix x policy under the discrete-event
simulator. Reports energy (request-attributed and fleet-level with
allocated-idle), J/token, p50/p99 latency, and per-pool utilization.

The zero-load special case (rate -> 0, capacity >> load) reduces to the
paper's static Fig. 4/5 accounting: ``zero_load_threshold_sweep`` checks the
event-driven totals against ``simulator.threshold_sweep`` point by point.

Run: PYTHONPATH=src python benchmarks/fleet_sweep.py [--queries N]
"""
from __future__ import annotations

import argparse
from typing import Dict, List, Tuple

from repro.configs import get_config
from repro.core import (AnalyticOracle, CapacityAwareScheduler, CostModel,
                        CostOptimalScheduler, PoolSpec, Query, Scheduler,
                        ThresholdScheduler, WorkloadSpec, paper_fleet,
                        sample_workload, simulate_fleet, threshold_sweep)
from repro.core.pricing import CostParams, normalized_cost_params

# Hot-path pricing: one shared CostModel with a quantized-(m, n) LRU memo.
# Quantizing to 8-token buckets makes repeated sweep cells hit the memo at
# >90% while perturbing per-query phase times well under the policy-decision
# scale (the analytic roofline is locally smooth in m and n).
SWEEP_QUANT = 8


def _sweep_model(cfg, cp: CostParams = CostParams()) -> CostModel:
    return CostModel(cfg, AnalyticOracle(), cp, quant=SWEEP_QUANT)

try:
    from benchmarks.bench_util import write_csv as _write
except ImportError:                      # standalone: benchmarks/ on sys.path
    from bench_util import write_csv as _write

RATES_QPS = (0.5, 2.0, 8.0)
INSTANCE_MIXES: Tuple[Tuple[int, int], ...] = ((4, 1), (2, 2), (8, 2))  # (eff, perf)
SLOTS = {"eff": 2, "perf": 4}


def _policies(cfg, eff, perf, n_eff: int, n_perf: int, *,
              model: CostModel = None,
              model_cp: CostModel = None) -> Dict[str, Scheduler]:
    """Schedulers are per-cell (capacity counts differ); the CostModels are
    shared across cells/policies so the memo actually carries."""
    if model is None:
        model = _sweep_model(cfg)
    if model_cp is None:
        model_cp = _sweep_model(cfg, normalized_cost_params(cfg, perf, lam=0.9))
    return {
        "threshold_in32": ThresholdScheduler(cfg, eff, perf, t_in=32,
                                             model=model),
        "cost_optimal": CostOptimalScheduler(cfg, [eff, perf], model=model),
        "capacity_aware": CapacityAwareScheduler(
            cfg, [eff, perf], {eff.name: n_eff, perf.name: n_perf},
            model=model_cp),
    }


def fleet_sweep(n_queries: int = 400, model: str = "llama2-7b",
                arrival_process: str = "mmpp", seed: int = 0,
                engine: str = "vectorized") -> List[List]:
    """rate x mix x policy grid under identical queueing dynamics."""
    cfg = get_config(model)
    eff, perf = paper_fleet()
    shared = _sweep_model(cfg)       # one memo across every cell and policy
    shared_cp = _sweep_model(cfg, normalized_cost_params(cfg, perf, lam=0.9))
    rows = []
    for rate in RATES_QPS:
        qs = sample_workload(n_queries, seed=seed,
                             spec=WorkloadSpec(rate_qps=rate),
                             arrival_process=arrival_process)
        for n_eff, n_perf in INSTANCE_MIXES:
            pools = {"eff": PoolSpec(eff, n_eff, SLOTS["eff"]),
                     "perf": PoolSpec(perf, n_perf, SLOTS["perf"])}
            for pol, sched in _policies(cfg, eff, perf, n_eff, n_perf,
                                        model=shared,
                                        model_cp=shared_cp).items():
                r = simulate_fleet(cfg, qs, pools, sched,
                                   policy_name=pol, engine=engine)
                # headline metric: fleet_j_per_tok (idle-INCLUSIVE J/token).
                # The request-attributed j_per_tok is kept for comparison
                # with static accounting but understates poorly-utilized
                # fleets, so it must not rank policies.
                rows.append([
                    arrival_process, rate, f"{n_eff}x{n_perf}", pol,
                    f"{r.total_energy_j:.1f}", f"{r.fleet_energy_j:.1f}",
                    f"{r.fleet_j_per_token:.4f}", f"{r.j_per_token:.4f}",
                    f"{r.p50_latency_s:.3f}", f"{r.p99_latency_s:.3f}",
                    f"{r.mean_wait_s:.3f}",
                    f"{r.per_pool['eff'].utilization:.3f}",
                    f"{r.per_pool['perf'].utilization:.3f}",
                ])
    _write("fleet_sweep",
           ["process", "rate_qps", "mix_effxperf", "policy", "energy_j",
            "fleet_energy_j", "fleet_j_per_tok", "j_per_tok", "p50_s",
            "p99_s", "mean_wait_s", "util_eff", "util_perf"], rows)
    return rows


def zero_load_threshold_sweep(n_queries: int = 200,
                              model: str = "llama2-7b", *,
                              persist: bool = True,
                              engine: str = "vectorized") -> List[List]:
    """Fig. 4 as the event-driven zero-load limit: with rate -> 0 and
    capacity >> load, the fleet totals equal the static sweep's (rel 1e-6)."""
    cfg = get_config(model)
    eff, perf = paper_fleet()
    qs = sample_workload(n_queries, seed=0, spec=WorkloadSpec(rate_qps=1e-3))
    pinned = [Query(q.m, 32, q.arrival_s) for q in qs]   # Eq. 9 protocol
    static = threshold_sweep(cfg, qs, eff, perf, axis="in",
                             thresholds=(8, 32, 128))
    rows = []
    for point in static:
        sched = ThresholdScheduler(cfg, eff, perf, t_in=point.threshold,
                                   t_out=point.threshold, axis="in")
        pools = {"eff": PoolSpec(eff, n_queries, 1),
                 "perf": PoolSpec(perf, n_queries, 1)}
        r = simulate_fleet(cfg, pinned, pools, sched,
                           policy_name=f"T={point.threshold}", engine=engine)
        rel = abs(r.total_energy_j - point.energy_j) / point.energy_j
        rows.append([point.threshold, f"{point.energy_j:.2f}",
                     f"{r.total_energy_j:.2f}", f"{rel:.2e}",
                     "OK" if rel < 1e-6 else "MISMATCH"])
    if persist:
        _write("fleet_zero_load_check",
               ["threshold", "static_energy_j", "fleet_energy_j", "rel_err",
                "status"], rows)
    return rows


def burst_policy_comparison(n_queries: int = 400,
                            model: str = "llama2-7b",
                            engine: str = "vectorized") -> List[List]:
    """The tentpole claim: under bursty (MMPP) arrivals, queue-aware dispatch
    beats the static threshold policy on p99 latency at equal-or-lower
    fleet energy (idle-inclusive, over each policy's own makespan)."""
    cfg = get_config(model)
    eff, perf = paper_fleet()
    qs = sample_workload(n_queries, seed=7, spec=WorkloadSpec(rate_qps=3.0),
                         arrival_process="mmpp")
    pools = {"eff": PoolSpec(eff, 4, 2), "perf": PoolSpec(perf, 2, 4)}
    cp = normalized_cost_params(cfg, perf, lam=0.9)
    policies = {
        "threshold_in32": ThresholdScheduler(cfg, eff, perf, t_in=32),
        "capacity_aware": CapacityAwareScheduler(
            cfg, [eff, perf], {eff.name: 4, perf.name: 2}, cp),
    }
    rows = []
    for pol, sched in policies.items():
        r = simulate_fleet(cfg, qs, pools, sched, policy_name=pol,
                           engine=engine)
        rows.append([pol, f"{r.total_energy_j:.1f}", f"{r.fleet_energy_j:.1f}",
                     f"{r.fleet_j_per_token:.4f}",
                     f"{r.p50_latency_s:.3f}", f"{r.p99_latency_s:.3f}",
                     f"{r.horizon_s:.1f}"])
    _write("fleet_burst_policy",
           ["policy", "energy_j", "fleet_energy_j", "fleet_j_per_tok",
            "p50_s", "p99_s", "horizon_s"], rows)
    return rows


def smoke(n_queries: int = 40, model: str = "llama2-7b",
          engine: str = "vectorized") -> None:
    """CI gate (scripts/ci.sh): tiny fixed-seed grid. Asserts the zero-load
    invariant (fleet == static at <1e-6 rel) and that the quantized-memo
    CostModel actually serves the hot path (hit rate + bounded skew vs exact
    pricing), so oracle regressions fail CI instead of only benchmarks."""
    cfg = get_config(model)
    eff, perf = paper_fleet()
    # persist=False: the smoke must not clobber the recorded 200-query artifact
    for row in zero_load_threshold_sweep(n_queries, model, persist=False,
                                         engine=engine):
        assert row[-1] == "OK", f"zero-load invariant broken: {row}"
    qs = sample_workload(n_queries, seed=3, spec=WorkloadSpec(rate_qps=2.0),
                         arrival_process="mmpp")
    pools = {"eff": PoolSpec(eff, 2, 2), "perf": PoolSpec(perf, 2, 4)}
    model_q = _sweep_model(cfg)
    # The memo gate targets the scalar pricing path, which only the event
    # engine exercises query-by-query; the vectorized engine settles through
    # CostModel.*_batch (bit-for-bit equal, gated by fleet_bench --smoke) and
    # never touches the memo, so this sub-check is pinned to engine="event".
    r_q = simulate_fleet(cfg, qs, pools,
                         ThresholdScheduler(cfg, eff, perf, t_in=32,
                                            model=model_q), engine="event")
    r_x = simulate_fleet(cfg, qs, pools,
                         ThresholdScheduler(cfg, eff, perf, t_in=32),
                         engine="event")
    info = model_q.memo_info()
    hit_rate = info["hits"] / max(1, info["hits"] + info["misses"])
    skew = abs(r_q.total_energy_j - r_x.total_energy_j) / r_x.total_energy_j
    assert hit_rate >= 0.4, f"memo ineffective: {info}"
    assert skew < 0.02, f"quantized pricing skew {skew:.4f} too large"
    print(f"fleet-sweep smoke OK: memo hit rate {hit_rate:.2f}, "
          f"quantization skew {skew:.2e}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--model", default="llama2-7b")
    ap.add_argument("--process", default="mmpp",
                    choices=("poisson", "diurnal", "mmpp"))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fixed-seed CI gate; asserts invariants")
    ap.add_argument("--engine", default="vectorized",
                    choices=("event", "vectorized"),
                    help="fleet-sim core (bit-for-bit equivalent engines)")
    args = ap.parse_args()

    if args.smoke:
        smoke(min(args.queries, 40), args.model, engine=args.engine)
        return

    print("== zero-load check (event-driven == static Fig 4) ==")
    for row in zero_load_threshold_sweep(min(args.queries, 200), args.model,
                                         engine=args.engine):
        print(",".join(str(x) for x in row))

    print("== burst policy comparison ==")
    for row in burst_policy_comparison(args.queries, args.model,
                                       engine=args.engine):
        print(",".join(str(x) for x in row))

    print("== rate x mix x policy sweep ==")
    for row in fleet_sweep(args.queries, args.model, args.process,
                           engine=args.engine):
        print(",".join(str(x) for x in row))


if __name__ == "__main__":
    main()
