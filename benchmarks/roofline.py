"""Roofline analysis over the dry-run artifacts (deliverable g).

Reads experiments/dryrun/*.json and derives, per (arch x shape x mesh):

    compute term    = HLO_FLOPs_per_device   / peak_FLOP/s per chip
    memory term     = HLO_bytes_per_device   / HBM bandwidth per chip
    collective term = collective_bytes/devc  / ICI link bandwidth

(The SPMD module is per-device, so per-device work over per-chip rates is the
step-time lower bound; multiplying both sides by #chips gives the global
formulation from the brief.) FLOPs/bytes come from the unrolled L=1/L=2
component extrapolation because XLA's cost analysis counts while-loop bodies
once (verified); collective bytes likewise.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16e9          # v5e 16 GB

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    status: str
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    dominant: str = ""
    model_flops: float = 0.0
    hlo_flops_global: float = 0.0
    useful_ratio: float = 0.0
    bytes_per_device: float = 0.0
    fits: bool = True
    note: str = ""


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D forward-only."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n = cfg.active_param_count()
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    toks = shape.global_batch  # one token per request
    return 2.0 * n * toks


def _recommendation(row: RooflineRow) -> str:
    if row.dominant == "compute":
        if row.useful_ratio < 0.5:
            return ("compute-bound with low useful ratio: cut redundant/remat "
                    "FLOPs (attention mask rectangle, MoE dead capacity)")
        return "compute-bound near roof: only larger mesh or lower precision helps"
    if row.dominant == "memory":
        return ("memory-bound: widen batch to amortize weight streaming, or "
                "shard the dominant resident tensor (KV/optimizer) further")
    return ("collective-bound: reshard to cut all-gathers (activation vs "
            "weight layout), overlap collectives with compute")


def analyze_all(mesh: str = "16x16") -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        rec = json.load(open(path))
        row = RooflineRow(rec["arch"], rec["shape"], rec["mesh"], rec["status"])
        if rec["status"] != "OK":
            row.note = rec.get("reason", rec.get("error", ""))[:120]
            rows.append(row)
            continue
        ex = rec.get("extrapolated") or {}
        full = rec["full"]
        flops_dev = ex.get("hlo_flops", full["hlo_flops_raw"])
        bytes_dev = ex.get("hlo_bytes", full["hlo_bytes_raw"])
        coll_dev = ex.get("collective_bytes", 0.0)
        row.t_compute = flops_dev / PEAK_FLOPS
        row.t_memory = bytes_dev / HBM_BW
        row.t_collective = coll_dev / ICI_BW
        terms = {"compute": row.t_compute, "memory": row.t_memory,
                 "collective": row.t_collective}
        row.dominant = max(terms, key=terms.get)
        row.model_flops = model_flops(rec["arch"], rec["shape"])
        chips = 512 if mesh == "2x16x16" else 256
        row.hlo_flops_global = flops_dev * chips
        row.useful_ratio = (row.model_flops / row.hlo_flops_global
                            if row.hlo_flops_global else 0.0)
        resident = (full.get("argument_size_in_bytes", 0)
                    + full.get("temp_size_in_bytes", 0))
        row.bytes_per_device = resident
        row.fits = resident <= HBM_PER_CHIP
        row.note = _recommendation(row)
        rows.append(row)
    return rows


def write_csv(rows: List[RooflineRow], name: str = "roofline") -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write("arch,shape,mesh,status,t_compute_s,t_memory_s,t_collective_s,"
                "dominant,model_flops,hlo_flops_global,useful_ratio,"
                "resident_bytes_per_dev,fits_16GB,note\n")
        for r in rows:
            f.write(f"{r.arch},{r.shape},{r.mesh},{r.status},{r.t_compute:.6e},"
                    f"{r.t_memory:.6e},{r.t_collective:.6e},{r.dominant},"
                    f"{r.model_flops:.4e},{r.hlo_flops_global:.4e},"
                    f"{r.useful_ratio:.4f},{r.bytes_per_device:.4e},"
                    f"{int(r.fits)},\"{r.note}\"\n")
    return path


def markdown_table(rows: List[RooflineRow]) -> str:
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | fits |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.status != "OK":
            out.append(f"| {r.arch} | {r.shape} | - | - | - | {r.status} | - | - |")
            continue
        out.append(f"| {r.arch} | {r.shape} | {r.t_compute:.2e} | "
                   f"{r.t_memory:.2e} | {r.t_collective:.2e} | {r.dominant} | "
                   f"{r.useful_ratio:.2f} | {'Y' if r.fits else 'N'} |")
    return "\n".join(out)
