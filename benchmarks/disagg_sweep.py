"""Disaggregated prefill/decode sweep: split routing vs per-query policies.

The paper routes whole queries to the pool that minimizes Eq. 1; its own
phenomenology (prefill compute-bound, decode memory-bound) says the two
phases have opposite hardware affinities. ``DisaggregatedScheduler`` prices,
per query, prefill on one pool + priced KV-block migration
(``CostModel.migration_terms``) + decode on another, against every
single-pool plan. This sweep runs that policy and the per-query baselines
(single-system, cost-optimal, capacity-aware) through the fleet simulator
under identical diurnal arrivals and records the frontier to
``BENCH_disagg.json``.

Cells:
  * prompt_heavy — long prompts, moderate outputs: the split's home turf
    (prefill dominated by the fast pool, long decode tail on the low-power
    pool, migration amortized over many decode tokens).
  * short_output — long prompts, few output tokens: migration is paid on the
    full prompt KV but buys only a handful of decode tokens, so per-query
    routing stays competitive (recorded for the EXPERIMENTS.md frontier
    discussion; the headline gate is the prompt_heavy cell).

Each cell is judged against its OWN documented bar (``CELL_BARS``):
prompt_heavy must undercut the best per-query policy's fleet J/token by
>= 3% (ratio <= 0.97); short_output must merely not lose (ratio <= 1.0) —
the split should price itself out of cells where it can't win, not regress
them. Both bars also require equal-or-better p99 TTFT. The recorded
per-cell verdict (``gate_ok``) is computed on the recorded 4-decimal ratio
against the recorded bar, so the artifact is self-consistent; ``--smoke``
asserts that agreement for every recorded gate.

``--smoke`` (scripts/ci.sh) asserts on a small fixed-seed prompt_heavy
config: (1) the disaggregated policy's fleet J/token undercuts the best
per-query policy by >= 3% at equal-or-better p99 TTFT; (2) the event and
vectorized engines stay bit-for-bit identical under split dispatch; (3) the
serving live path (prefill lanes, ``migrate_kv_blocks``, decode-pool
adoption) is token-for-token identical to non-disaggregated generation; and
(4) the tracked ``BENCH_disagg.json`` is well-formed with its recorded gate
intact.

Run: PYTHONPATH=src python benchmarks/disagg_sweep.py [--queries N] [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.configs import get_config
from repro.core import (CapacityAwareScheduler, CostModel,
                        CostOptimalScheduler, DisaggregatedScheduler,
                        PoolSpec, Scheduler, SingleSystemScheduler,
                        WorkloadSpec, sample_workload, simulate_fleet)
from repro.core.systems import SystemProfile

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_disagg.json")
BENCH_MODEL = "qwen2.5-3b"

# Probe pair for the split frontier: the eff pool idles near-dark (8 W) but
# saturates on long prompts (sat_ctx); the perf pool prefills fast at a high
# idle floor. Both advertise an inter-pool link, so the scheduler may price
# prefill-on-perf -> migrate -> decode-on-eff against every single-pool plan.
DISAGG_EFF = SystemProfile(
    name="eff", kind="eff", chips=1, peak_flops=90e12, hbm_bw=0.8e12,
    ici_bw=50e9, power_peak_w=220.0, power_idle_w=8.0, overhead_s=0.02,
    sat_ctx=2048.0, link_bw_gbps=100.0)
DISAGG_PERF = SystemProfile(
    name="perf", kind="perf", chips=2, peak_flops=200e12, hbm_bw=1.25e12,
    ici_bw=100e9, power_peak_w=350.0, power_idle_w=60.0, overhead_s=0.01,
    sat_ctx=None, link_bw_gbps=100.0)

WORKLOADS: Dict[str, WorkloadSpec] = {
    # median ~245 prompt / ~55 output tokens
    "prompt_heavy": WorkloadSpec(mu_in=5.5, sigma_in=0.7, mu_out=4.0,
                                 sigma_out=0.8, rate_qps=20.0),
    # same prompts, median ~7 output tokens: migration can't amortize
    "short_output": WorkloadSpec(mu_in=5.5, sigma_in=0.7, mu_out=2.0,
                                 sigma_out=0.8, rate_qps=20.0),
}
PER_QUERY_POLICIES = ("single_eff", "single_perf", "cost_optimal",
                      "capacity_aware")
# Documented per-cell bars on disagg/best-per-query fleet J/token: the
# headline cell must win by >= 3%; the adversarial cell must not lose.
CELL_BARS = {"prompt_heavy": 0.97, "short_output": 1.0}
INSTANCES, SLOTS, KV_BLOCKS = 4, 4, 4096


def _pools() -> Dict[str, PoolSpec]:
    return {"eff": PoolSpec(DISAGG_EFF, instances=INSTANCES, slots=SLOTS,
                            kv_blocks=KV_BLOCKS),
            "perf": PoolSpec(DISAGG_PERF, instances=INSTANCES, slots=SLOTS,
                             kv_blocks=KV_BLOCKS)}


def _policies(cfg, model: CostModel) -> Dict[str, Scheduler]:
    eff, perf = DISAGG_EFF, DISAGG_PERF
    counts = {eff.name: INSTANCES, perf.name: INSTANCES}
    return {
        "single_eff": SingleSystemScheduler(cfg, eff, model=model),
        "single_perf": SingleSystemScheduler(cfg, perf, model=model),
        "cost_optimal": CostOptimalScheduler(cfg, [eff, perf], model=model),
        "capacity_aware": CapacityAwareScheduler(cfg, [eff, perf], counts,
                                                 model=model),
        "disaggregated": DisaggregatedScheduler(cfg, [eff, perf], model=model),
    }


def _run_cell(cfg, spec: WorkloadSpec, n_queries: int, seed: int,
              engine: str) -> Dict[str, Dict]:
    qs = sample_workload(n_queries, seed=seed, spec=spec,
                         arrival_process="diurnal")
    model = CostModel(cfg)
    out: Dict[str, Dict] = {}
    for pol, sched in _policies(cfg, model).items():
        r = simulate_fleet(cfg, qs, _pools(), sched, policy_name=pol,
                           engine=engine)
        out[pol] = {
            "fleet_j_per_token": r.fleet_j_per_token,
            "j_per_token": r.j_per_token,
            "fleet_energy_j": r.fleet_energy_j,
            "p99_ttft_s": r.p99_ttft_s,
            "p99_latency_s": r.p99_latency_s,
            "mean_wait_s": r.mean_wait_s,
            "mig_bytes": r.mig_bytes,
            "splits": sum(1 for rec in r.records if rec.pool_decode),
            "horizon_s": r.horizon_s,
        }
    return out


def _gate(cell: Dict[str, Dict], bar: float) -> Dict[str, object]:
    """One cell's verdict against its documented ``bar`` (``CELL_BARS``):
    disagg/best-per-query fleet J/token (idle-inclusive) must stay at or
    under the bar at equal-or-better p99 TTFT. The verdict is computed on
    the ROUNDED ratio that gets recorded, so ``gate_ok`` always agrees with
    the artifact's own fields."""
    best = min(PER_QUERY_POLICIES,
               key=lambda p: cell[p]["fleet_j_per_token"])
    d, b = cell["disaggregated"], cell[best]
    ratio = round(d["fleet_j_per_token"] / b["fleet_j_per_token"], 4)
    ttft_ok = d["p99_ttft_s"] <= b["p99_ttft_s"]
    return {"best_per_query": best, "j_per_token_ratio": ratio, "bar": bar,
            "ttft_ok": ttft_ok, "gate_ok": ratio <= bar and ttft_ok}


def disagg_sweep(n_queries: int = 2000, seed: int = 0,
                 engine: str = "vectorized", *,
                 persist: bool = True) -> Dict:
    cfg = get_config(BENCH_MODEL)
    record: Dict[str, object] = {
        "config": {"model": BENCH_MODEL, "seed": seed, "queries": n_queries,
                   "arrival_process": "diurnal", "engine": engine,
                   "instances_per_pool": INSTANCES, "slots": SLOTS,
                   "kv_blocks": KV_BLOCKS,
                   "eff_link_gbps": DISAGG_EFF.link_bw_gbps,
                   "perf_link_gbps": DISAGG_PERF.link_bw_gbps},
        "cells": {}, "gates": {},
    }
    for name, spec in WORKLOADS.items():
        cell = _run_cell(cfg, spec, n_queries, seed, engine)
        record["cells"][name] = cell
        record["gates"][name] = _gate(cell, CELL_BARS[name])
    if persist:
        with open(BENCH_PATH, "w") as f:
            json.dump(record, f, indent=1, sort_keys=True)
            f.write("\n")
    return record


# ----------------------------------------------------------------- smoke gates
def _smoke_engine_equivalence(cfg, n_queries: int, seed: int) -> None:
    """Split dispatch through both fleet engines must stay bit-for-bit
    identical: summary dicts and full per-record tuples, migration fields
    included."""
    qs = sample_workload(n_queries, seed=seed, spec=WORKLOADS["prompt_heavy"],
                         arrival_process="diurnal")
    runs = {}
    for engine in ("event", "vectorized"):
        runs[engine] = simulate_fleet(
            cfg, qs, _pools(),
            DisaggregatedScheduler(cfg, [DISAGG_EFF, DISAGG_PERF]),
            engine=engine)
    se, sv = runs["event"].summary(), runs["vectorized"].summary()
    assert se == sv, {k: (se[k], sv[k]) for k in se if se[k] != sv[k]}
    te = [(x.rid, x.pool, x.pool_decode, x.t_arrival, x.t_start, x.t_decode,
           x.t_done, x.energy_j, x.mig_bytes) for x in runs["event"].records]
    tv = [(x.rid, x.pool, x.pool_decode, x.t_arrival, x.t_start, x.t_decode,
           x.t_done, x.energy_j, x.mig_bytes)
          for x in runs["vectorized"].records]
    assert te == tv, "disagg record mismatch between engines"
    assert any(x[2] for x in te), "config produced no splits"


def _smoke_serving_parity() -> None:
    """Live path: route with the disaggregated policy over paged batchers on
    two pools, force split plans, and check every emitted token equals the
    solo (non-disaggregated) generation — across a real
    ``migrate_kv_blocks`` handoff."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.pricing import CostParams
    from repro.models import model as M
    from repro.serving.engine import InferenceEngine
    from repro.serving.router import FleetRouter

    cfg = get_config("smollm-360m").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    eng = InferenceEngine(cfg, params, max_len=96)
    # price with the UNREDUCED config: the reduced test model's decode is so
    # small that migration always dominates and no split plan could win
    eff = SystemProfile(name="eff", kind="eff", chips=1, peak_flops=5e12,
                        hbm_bw=0.8e12, ici_bw=50e9, power_peak_w=120.0,
                        power_idle_w=8.0, overhead_s=0.02, sat_ctx=2048.0,
                        link_bw_gbps=400.0)
    perf = SystemProfile(name="perf", kind="perf", chips=4, peak_flops=400e12,
                         hbm_bw=1.25e12, ici_bw=100e9, power_peak_w=350.0,
                         power_idle_w=100.0, overhead_s=0.0005,
                         link_bw_gbps=400.0)
    pricing = CostModel(get_config("smollm-360m"), None, CostParams(lam=1.0))
    router = FleetRouter(cfg, {"eff": eff, "perf": perf},
                         {"eff": eng, "perf": eng}, policy="disaggregated",
                         model=pricing)
    router.attach_batchers(slots=2, paged=True, num_blocks=48, block_size=8,
                           chunk=8)
    prompts = [np.arange(40 + 7 * i) % cfg.vocab_size for i in range(3)]
    routed = [router.submit(p, 6) for p in prompts]
    assert router._handoffs, "no split plans armed — pricing drifted"
    router.drain()
    assert not router._handoffs, "handoffs left pending after drain"
    for rr, p in zip(routed, prompts):
        assert rr.request.done
        solo = eng.generate({"tokens": jnp.asarray(p, jnp.int32)[None]}, 6)
        np.testing.assert_array_equal(np.asarray(rr.request.out_tokens[:6]),
                                      solo.tokens[0])


def smoke(n_queries: int = 300, seed: int = 0) -> None:
    """CI gate (scripts/ci.sh): fixed-seed prompt_heavy cell. Asserts the
    energy win, engine equivalence, serving token parity, and the recorded
    artifact (see module docstring)."""
    cfg = get_config(BENCH_MODEL)
    cell = _run_cell(cfg, WORKLOADS["prompt_heavy"], n_queries, seed,
                     "vectorized")
    gate = _gate(cell, CELL_BARS["prompt_heavy"])
    assert gate["gate_ok"], (
        f"disaggregation gate failed: {gate} "
        f"(disagg={cell['disaggregated']}, "
        f"best={cell[gate['best_per_query']]})")
    _smoke_engine_equivalence(cfg, min(n_queries, 200), seed)
    _smoke_serving_parity()
    assert os.path.exists(BENCH_PATH), (
        "BENCH_disagg.json missing: run benchmarks/disagg_sweep.py to "
        "record the sweep artifact")
    with open(BENCH_PATH) as f:
        rec = json.load(f)
    for k in ("config", "cells", "gates"):
        assert k in rec, f"BENCH_disagg.json missing key {k!r}"
    for name, g in rec["gates"].items():
        # the artifact must be self-consistent: the recorded verdict is the
        # recorded ratio judged against the recorded bar
        assert g["gate_ok"] == (g["j_per_token_ratio"] <= g["bar"]
                                and g["ttft_ok"]), (
            f"recorded {name} verdict disagrees with its own fields: {g}")
    assert rec["gates"]["prompt_heavy"]["gate_ok"], (
        "recorded prompt_heavy gate no longer passes")
    print(f"disagg smoke OK: fleet J/token ratio "
          f"{gate['j_per_token_ratio']} vs {gate['best_per_query']}, "
          f"{cell['disaggregated']['splits']}/{n_queries} split, "
          f"engines bit-identical, serving token parity across handoff")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--queries", type=int, default=2000)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", default="vectorized",
                    choices=("event", "vectorized"))
    ap.add_argument("--smoke", action="store_true",
                    help="fixed-seed CI gate; asserts the energy win, "
                         "engine equivalence, and serving token parity")
    args = ap.parse_args()
    if args.smoke:
        smoke(min(args.queries, 300), args.seed)
        return
    record = disagg_sweep(args.queries, args.seed, args.engine)
    for name, gate in record["gates"].items():
        cell = record["cells"][name]
        print(f"== {name}: gate_ok={gate['gate_ok']} "
              f"ratio={gate['j_per_token_ratio']} "
              f"best={gate['best_per_query']} ==")
        for pol, row in cell.items():
            print(f"  {pol:15s} fleetJ/tok={row['fleet_j_per_token']:.4f} "
                  f"p99_ttft={row['p99_ttft_s']:.4f} "
                  f"p99_lat={row['p99_latency_s']:.3f} "
                  f"splits={row['splits']}")


if __name__ == "__main__":
    main()
