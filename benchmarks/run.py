"""Benchmark driver: one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
bench itself; derived = the figure's headline quantity).
"""
from __future__ import annotations

import sys
import time


def _timed(name, fn, derive):
    t0 = time.perf_counter()
    rows = fn()
    us = (time.perf_counter() - t0) * 1e6
    print(f"{name},{us:.0f},{derive(rows)}")
    return rows


def main() -> None:
    from benchmarks import paper_figs as F
    from benchmarks import roofline as R

    print("name,us_per_call,derived")

    _timed("fig1_input_tokens", F.fig1_input_tokens,
           lambda rows: f"rows={len(rows)}")
    _timed("fig2_output_tokens", F.fig2_output_tokens,
           lambda rows: f"rows={len(rows)}")
    _timed("fig3_token_distribution", F.fig3_token_distribution,
           lambda rows: f"bins={len(rows)}")

    def best_T(rows):
        return "T*=" + str(next(r[1] for r in rows if str(r[0]).startswith("optimal")))
    _timed("fig4_input_threshold", F.fig4_input_threshold_sweep, best_T)
    _timed("fig5_output_threshold", F.fig5_output_threshold_sweep, best_T)

    def headline_savings(rows):
        eq9 = next(r for r in rows if r[2] == "threshold_in32_eq9")
        return f"savings_vs_best={float(eq9[4]):.1%}(paper:7.5%)"
    _timed("headline_table", F.headline_table, headline_savings)

    _timed("crossover_table", F.crossover_table,
           lambda rows: f"archs={len(rows)}")

    # discrete-event fleet simulator (PR 1): zero-load check + burst sweep
    from benchmarks import fleet_sweep as FS
    _timed("fleet_zero_load_check",
           lambda: FS.zero_load_threshold_sweep(100),
           lambda rows: "status=" + ("OK" if all(r[-1] == "OK" for r in rows)
                                     else "MISMATCH"))
    def burst_derive(rows):
        by = {r[0]: r for r in rows}
        thr, cap = by["threshold_in32"], by["capacity_aware"]
        return (f"p99 {float(cap[4]):.1f}s vs {float(thr[4]):.1f}s; "
                f"fleetE {float(cap[2]):.0f}J vs {float(thr[2]):.0f}J")
    _timed("fleet_burst_policy", lambda: FS.burst_policy_comparison(300),
           burst_derive)

    # roofline from dry-run artifacts (if present)
    def roof(rows=None):
        rows = R.analyze_all("16x16")
        R.write_csv(rows)
        ok = [r for r in rows if r.status == "OK"]
        dom = {}
        for r in ok:
            dom[r.dominant] = dom.get(r.dominant, 0) + 1
        return rows, f"ok={len(ok)} dominant={dom}"

    t0 = time.perf_counter()
    rows, derived = roof()
    print(f"roofline,{(time.perf_counter() - t0) * 1e6:.0f},\"{derived}\"")

    # serving microbench: real jitted steps on a reduced config (CPU wall time)
    from benchmarks.microbench import serving_microbench
    _timed("serving_microbench", serving_microbench,
           lambda rows: ";".join(f"{r[0]}={r[1]:.0f}us" for r in rows))


if __name__ == "__main__":
    main()
