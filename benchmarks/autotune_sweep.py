"""Autotune the serving kernels and regenerate the pricing oracle from the
tuned timings.

Full mode runs the grid search (``repro.kernels.autotune``) over the tracked
kernel/shape cells on the benchmark backend ("ref" — the jnp execution path
this container actually serves with; on a TPU host the same command tunes the
compiled Pallas kernels), then:

  * persists the winners to ``experiments/autotune/<profile>__<backend>.json``
    (env-fingerprinted; stale-env caches refuse to load),
  * rebuilds the pricing grid via ``TableOracle.from_autotune`` and checks the
    refreshed grid prices RE-MEASURED tuned kernels within the measured
    calibration tolerance (the measure -> fit -> route loop, closed),
  * records per-cell tuned-vs-default times in ``BENCH_kernels.json`` at the
    repo root, gated at a >= 1.15x geometric-mean speedup.

``--smoke`` is the CI gate: a tiny grid in a temp dir must round-trip the
cache schema (including the stale-env refusal), satisfy per-cell
no-regression (winner never slower than the default on the measured grid),
refresh the oracle within tolerance, and find a well-formed committed
``BENCH_kernels.json`` whose recorded geomean clears the bar.

Run: PYTHONPATH=src python benchmarks/autotune_sweep.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import tempfile
from typing import Dict, List, Optional, Sequence

import numpy as np

try:
    from benchmarks.calibrate import HOST_CPU, MEASURED_REL_RMSE_BOUND
    from benchmarks.microbench import time_kernel
except ImportError:                      # standalone: benchmarks/ on sys.path
    from calibrate import HOST_CPU, MEASURED_REL_RMSE_BOUND
    from microbench import time_kernel
from repro.configs import get_config
from repro.core.pricing import KernelSample, TableOracle, _predict, _rel_rmse
from repro.kernels import autotune as AT
from repro.launch import envcfg

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_kernels.json")

GEOMEAN_SPEEDUP_GATE = 1.15
BENCH_BACKEND = "ref"
BENCH_MODEL = "qwen2.5-3b"

# The tracked configuration: the shape buckets the reduced serving stack
# actually hits, one cell per (kernel, bucket). decode_attention is absent
# on the ref backend (its only tunable, the split-KV tile, is a Pallas
# grid parameter) — the tuner skips kernels with empty candidate spaces.
TRACKED_SHAPES: Dict[str, Sequence[Dict[str, int]]] = {
    "flash_attention": ({"s": 1024}, {"s": 2048}),
    "ssm_scan": ({"s": 512}, {"s": 1024}),
    "paged_decode_quant": ({"b": 8, "c": 1024}, {"b": 8, "c": 4096}),
}

SMOKE_SHAPES: Dict[str, Sequence[Dict[str, int]]] = {
    "flash_attention": ({"s": 128},),
    "ssm_scan": ({"s": 128},),
    "paged_decode_quant": ({"b": 2, "c": 128},),
}

REQUIRED_KEYS = ("config", "env_digest", "cells", "geomean_speedup",
                 "oracle_refresh")
CELL_KEYS = ("kernel", "bucket", "params", "default_params", "t_default_s",
             "t_tuned_s", "speedup")


def _remeasure(cache: AT.AutotuneCache, *, iters: int,
               seed: int) -> List[KernelSample]:
    """Time every cache entry again with its WINNING params pinned — the
    independent measurement the refreshed oracle is gated against."""
    out = []
    for e in sorted(cache.entries.values(), key=lambda e: e.key()):
        out.append(time_kernel(e.kernel, e.shape, params=e.params,
                               backend=cache.backend, iters=iters,
                               seed=seed + 1))   # fresh data, same shapes
    return out


def _oracle_refresh(cache: AT.AutotuneCache, *, iters: int,
                    seed: int) -> Dict:
    """Rebuild the pricing grid from tuned timings and bound its error
    against re-measured tuned kernels."""
    cfg = get_config(BENCH_MODEL)
    oracle = TableOracle.from_autotune(cfg, HOST_CPU, cache)
    cal = oracle.calibration
    remeasured = _remeasure(cache, iters=iters, seed=seed)
    pred = _predict(remeasured, HOST_CPU, cal.compute_eff, cal.mem_eff,
                    cal.sat_ctx, cal.overhead_s)
    t = np.array([s.t_s for s in remeasured])
    remeasured_rmse = _rel_rmse(pred, t)
    return {
        "fit_rel_rmse": cal.fit_rel_rmse,
        "remeasured_rel_rmse": remeasured_rmse,
        "bound": MEASURED_REL_RMSE_BOUND,
        "compute_eff": cal.compute_eff,
        "mem_eff": cal.mem_eff,
        "sat_ctx": cal.sat_ctx,
        "overhead_s": cal.overhead_s,
        "n_samples": cal.n_samples,
    }


def _cells(cache: AT.AutotuneCache) -> List[Dict]:
    rows = []
    for e in sorted(cache.entries.values(), key=lambda e: e.key()):
        rows.append({
            "kernel": e.kernel, "bucket": e.bucket, "shape": e.shape,
            "params": e.params,
            "default_params": AT.default_params(e.kernel, e.backend),
            "t_default_s": e.t_default_s, "t_tuned_s": e.t_s,
            "noise_frac": round(e.noise_frac, 4),
            "speedup": round(e.speedup, 3),
        })
    return rows


def bench(*, iters: int = 7, seed: int = 0,
          out_dir: Optional[str] = None) -> Dict:
    """Tune the tracked cells, refresh the oracle, write both artifacts."""
    cache_dir = out_dir if out_dir is not None else AT.CACHE_DIR
    print(f"autotuning {sum(len(v) for v in TRACKED_SHAPES.values())} cells "
          f"on backend {BENCH_BACKEND!r} (iters={iters}) ...", flush=True)
    cache = AT.autotune(TRACKED_SHAPES, profile=HOST_CPU.name,
                        backend=BENCH_BACKEND, iters=iters, seed=seed,
                        verbose=True)
    cpath = cache.dump(AT.cache_path(HOST_CPU.name, BENCH_BACKEND, cache_dir))
    print(f"cache -> {os.path.relpath(cpath)}")

    refresh = _oracle_refresh(cache, iters=iters, seed=seed)
    geo = cache.geomean_speedup()
    out = {
        "config": {
            "model": BENCH_MODEL, "profile": HOST_CPU.name,
            "backend": BENCH_BACKEND, "seed": seed, "iters": iters,
            "shapes": {k: list(v) for k, v in TRACKED_SHAPES.items()},
            "gate_geomean": GEOMEAN_SPEEDUP_GATE,
        },
        "env_digest": envcfg.fingerprint_digest(cache.env),
        "cells": _cells(cache),
        "geomean_speedup": round(geo, 3),
        "oracle_refresh": refresh,
    }
    bench_path = os.path.join(out_dir, "BENCH_kernels.json") \
        if out_dir is not None else BENCH_PATH
    with open(bench_path, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    for c in out["cells"]:
        print(f"  {c['kernel']}/{c['bucket']}: {c['params']} "
              f"{c['t_default_s'] * 1e3:.2f} -> {c['t_tuned_s'] * 1e3:.2f} ms "
              f"({c['speedup']}x)")
    print(f"geomean speedup {geo:.3f}x (gate {GEOMEAN_SPEEDUP_GATE}x); "
          f"oracle refresh rel-RMSE fit={refresh['fit_rel_rmse']:.3f} "
          f"remeasured={refresh['remeasured_rel_rmse']:.3f} "
          f"(bound {MEASURED_REL_RMSE_BOUND}) -> "
          f"{os.path.relpath(bench_path)}")
    assert geo >= GEOMEAN_SPEEDUP_GATE, (
        f"tuned geomean speedup {geo:.3f}x below the "
        f"{GEOMEAN_SPEEDUP_GATE}x gate")
    assert refresh["remeasured_rel_rmse"] < MEASURED_REL_RMSE_BOUND, (
        f"tuned-grid pricing off by {refresh['remeasured_rel_rmse']:.3f} "
        f"rel-RMSE vs re-measured tuned kernels "
        f"(bound {MEASURED_REL_RMSE_BOUND})")
    return out


def smoke() -> None:
    """CI gate: schema round-trip + stale-env refusal + no-regression +
    oracle-refresh parity on a tiny grid, plus the committed artifact."""
    with tempfile.TemporaryDirectory() as tmp:
        cache = AT.autotune(SMOKE_SHAPES, profile=HOST_CPU.name,
                            backend=BENCH_BACKEND, iters=2, seed=0)
        n_cells = sum(len(v) for v in SMOKE_SHAPES.values())
        assert len(cache.entries) == n_cells, (len(cache.entries), n_cells)

        # schema round-trip: dump -> load -> identical resolution
        path = cache.dump(AT.cache_path(HOST_CPU.name, BENCH_BACKEND, tmp))
        loaded = AT.AutotuneCache.load(path)
        for e in cache.entries.values():
            assert loaded.resolve(e.kernel, e.backend, e.bucket) == e.params
        assert loaded.to_json() == cache.to_json()

        # stale-env refusal: perturb the fingerprint, reload must raise
        with open(path) as f:
            data = json.load(f)
        data["env"]["jax"] = "0.0.0-stale"
        data["env_digest"] = envcfg.fingerprint_digest(data["env"])
        stale_path = os.path.join(tmp, "stale.json")
        with open(stale_path, "w") as f:
            json.dump(data, f)
        try:
            AT.AutotuneCache.load(stale_path)
        except AT.StaleCacheError:
            pass
        else:
            raise AssertionError("stale-env cache loaded without error")
        AT.AutotuneCache.load(stale_path, require_env=False)  # escape hatch

        # no-regression: the default is in every candidate grid, so the
        # winner can never be slower than it on the measured grid
        for e in cache.entries.values():
            assert e.t_s <= e.t_default_s, (
                f"{e.key()}: tuned {e.t_s} > default {e.t_default_s}")

        # oracle-refresh parity on the tiny grid
        refresh = _oracle_refresh(cache, iters=2, seed=0)
        assert refresh["remeasured_rel_rmse"] < MEASURED_REL_RMSE_BOUND, (
            f"smoke oracle refresh rel-RMSE "
            f"{refresh['remeasured_rel_rmse']:.3f} >= "
            f"{MEASURED_REL_RMSE_BOUND}")

    # the committed tracked artifact must exist, be well-formed, and clear
    # the recorded gate (the full sweep is too slow for CI)
    assert os.path.exists(BENCH_PATH), (
        "BENCH_kernels.json missing: run benchmarks/autotune_sweep.py "
        "(full mode)")
    with open(BENCH_PATH) as f:
        rec = json.load(f)
    for k in REQUIRED_KEYS:
        assert k in rec, f"BENCH_kernels.json missing key {k!r}"
    assert rec["cells"], "BENCH_kernels.json has no cells"
    for c in rec["cells"]:
        for k in CELL_KEYS:
            assert k in c, f"BENCH_kernels.json cell missing {k!r}"
        assert c["t_tuned_s"] <= c["t_default_s"] * 1.0001, (
            f"recorded cell {c['kernel']}/{c['bucket']} regressed")
    geo = math.exp(sum(math.log(c["speedup"]) for c in rec["cells"])
                   / len(rec["cells"]))
    assert abs(geo - rec["geomean_speedup"]) < 0.01, (
        "recorded geomean inconsistent with its cells")
    assert rec["geomean_speedup"] >= GEOMEAN_SPEEDUP_GATE, (
        f"recorded geomean {rec['geomean_speedup']}x below "
        f"{GEOMEAN_SPEEDUP_GATE}x")
    assert rec["oracle_refresh"]["remeasured_rel_rmse"] < \
        MEASURED_REL_RMSE_BOUND
    print(f"autotune smoke OK: {len(rec['cells'])} tracked cells, recorded "
          f"geomean {rec['geomean_speedup']}x, oracle refresh rel-RMSE "
          f"{rec['oracle_refresh']['remeasured_rel_rmse']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iters", type=int, default=7)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=None,
                    help="redirect both artifacts (default: tracked paths)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny grid in a temp dir + committed "
                         "artifact schema")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    bench(iters=args.iters, seed=args.seed, out_dir=args.out_dir)


if __name__ == "__main__":
    main()
