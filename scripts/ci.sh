#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): the suite must COLLECT cleanly and pass
# with or without the optional test deps (hypothesis). A hard import of an
# optional dep in a test module kills collection of the entire suite — this
# script exists so that regression can't recur silently.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# collection must produce zero errors even before running anything
python -m pytest -q --collect-only >/dev/null

python -m pytest -x -q
