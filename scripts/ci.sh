#!/usr/bin/env bash
# Tier-1 verification (ROADMAP.md): the suite must COLLECT cleanly and pass
# with or without the optional test deps (hypothesis). A hard import of an
# optional dep in a test module kills collection of the entire suite — this
# script exists so that regression can't recur silently.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# collection must produce zero errors even before running anything
python -m pytest -q --collect-only >/dev/null

python -m pytest -x -q

# Static-analysis gate (repro.analysis): dimensional analysis over the
# unit-suffix convention, JAX hot-path host-sync/trace hazards, and
# scheduler purity. The committed baseline is EMPTY — new findings must be
# fixed or carry an inline `# repro-lint: allow[rule]` justification.
python -m repro.analysis --fail-on warning src benchmarks

# Oracle regression gates (fast, fixed seeds): the calibration fit must
# recover ground-truth roofline constants within its documented bound, and
# the fleet sweep's quantized-memo pricing must preserve the zero-load
# invariant with bounded skew. Failures here mean the pricing layer broke,
# not just the benchmarks.
python benchmarks/calibrate.py --synthetic --smoke
python benchmarks/fleet_sweep.py --smoke

# Paged-serving gate: the paged runtime (block tables + chunked prefill +
# prefix sharing) must stay token-for-token identical to the dense batcher
# and show non-zero block reuse on a shared-prefix workload.
python benchmarks/paged_serving.py --smoke

# Vectorized fleet-sim gate: the default engine must stay bit-for-bit
# identical to the legacy event engine on a fixed-seed diurnal config,
# clear an events/sec floor, and the tracked BENCH_fleet.json must be
# well-formed with its >= 20x full-scale speedup intact.
python benchmarks/fleet_bench.py --smoke

# Energy-proportionality gate: with power states enabled but linger=inf and
# the autoscaler off, the fleet must reproduce static-fleet energy
# bit-for-bit (per-request and totals); under the diurnal workload the
# autoscaled fleet must strictly lower fleet J/token vs the static fleet at
# equal-or-better SLO attainment.
python benchmarks/autoscale_sweep.py --smoke

# Kernel-autotune gate: a tiny grid search must round-trip the cache schema
# (incl. the stale-env refusal), never pick a winner slower than the default
# on the measured grid, and refresh the TableOracle within the measured
# calibration tolerance; the tracked BENCH_kernels.json must be well-formed
# with its >= 1.15x geomean speedup intact.
python benchmarks/autotune_sweep.py --smoke

# Disaggregation gate: on the prompt-heavy diurnal cell the disaggregated
# policy must beat the best per-query policy by >= 3% fleet J/token at
# equal-or-better p99 TTFT, both fleet engines must simulate splits
# bit-for-bit, and a live router handoff (migrate_kv_blocks + adopt_lane)
# must stay token-for-token identical to solo generation; the tracked
# BENCH_disagg.json must be well-formed with its recorded gate intact.
python benchmarks/disagg_sweep.py --smoke
